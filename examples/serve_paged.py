"""Serve a small model with batched requests through the FMMU paged-KV
engine: continuous batching, page-table translation per step, a
deliberately undersized device pool to show CondUpdate-guarded
swap-out/swap-in preemption, and the GC victim-eviction walk + CTP
segment prefetch (the paper's GCM/CTP) reclaiming fragmented blocks
at macro boundaries.

  PYTHONPATH=src python examples/serve_paged.py
"""
import jax
import numpy as np
import jax.numpy as jnp

from repro.configs import get_arch, smoke_config
from repro.models import Runtime, build_model
from repro.serving.config import GCConfig, ServeConfig
from repro.serving.engine import ServeEngine


def main():
    cfg = smoke_config(get_arch("gemma2-9b"))   # local/global + softcaps
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 remat="none", page_size=8, capacity_factor=100.0)
    model = build_model(cfg, rt)
    params = model.init(jax.random.key(0))
    # undersized device pool + host overflow tier -> preemption happens;
    # macro_k=4 runs fused 4-token macro-steps whenever the pool can
    # provably cover them and falls back to single-step mode (which owns
    # the preempt/swap machinery) when it can't — both paths exercised.
    # gc= arms the boundary victim walk: when a channel's free count
    # drops under the watermark, the engine relocates live pages out of
    # the most-dead erase block (CondUpdate, stale lanes skipped) and
    # reclaims it; prefetch=True warms CMT segments for upcoming growth
    eng = ServeEngine(model, params, config=ServeConfig(
        n_slots=3, max_ctx=96, n_device_blocks=14, n_host_blocks=24,
        macro_k=4,
        gc=GCConfig(watermark=8, pages_per_boundary=4, block_pages=2,
                    prefetch=True)))
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(2, cfg.vocab_size,
                                    int(rng.integers(20, 60))).tolist(),
                       max_new=10) for _ in range(5)]
    done = eng.run()
    print("completed:", sorted(done))
    print("engine metrics:", eng.metrics)
    print("FMMU map stats:", eng.kvm.hit_stats())
    print("pool stats:", eng.kvm.pool.stats)
    assert len(done) == 5


if __name__ == "__main__":
    main()
