"""Quickstart: build an assigned architecture at smoke scale, train a few
steps, then serve it with FMMU-paged KV.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch, smoke_config
from repro.data.pipeline import DataConfig, data_iter
from repro.models import Runtime, build_model
from repro.serving.engine import ServeEngine
from repro.training import optimizer as opt
from repro.training.train_loop import TrainerConfig, train


def main():
    cfg = smoke_config(get_arch("llama3.2-1b"))
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 remat="none", page_size=16, capacity_factor=100.0)
    model = build_model(cfg, rt)

    # --- train a few steps on the synthetic pipeline ---
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                      pack=False)
    it = data_iter(dcfg, prefetch=False)
    state, summary = train(
        model, it, opt.AdamWConfig(lr=1e-2, weight_decay=0.0,
                                   warmup_steps=5, decay_steps=40),
        TrainerConfig(total_steps=40, log_every=10, ckpt_every=0))
    print("loss:", summary["history"][0][1], "->", summary["history"][-1][1])

    # --- serve the trained weights through the FMMU-paged engine ---
    eng = ServeEngine(model, state.params, n_slots=2, max_ctx=128)
    rid = eng.submit(list(range(2, 30)), max_new=12)
    done = eng.run()
    print("generated:", done[rid])
    print("FMMU map stats:", eng.kvm.hit_stats())


if __name__ == "__main__":
    main()
