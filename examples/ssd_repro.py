"""Paper reproduction demo: the FMMU inside the DiskSim-style SSD
simulator vs DFTL/CDFTL on 4KB random reads, plus the hardware engine's
MSHR-merging behaviour on a burst of lookups to one translation page.

  PYTHONPATH=src python examples/ssd_repro.py
"""
import dataclasses

from repro.configs.fmmu_paper import PAPER_SSD
from repro.core.fmmu.oracle import FMMUOracle
from repro.core.fmmu.types import LOOKUP, UPDATE, Request, small_geometry
from repro.core.sim.ssd import SSDSim
from repro.core.sim import workloads as W


def main():
    cfg = dataclasses.replace(PAPER_SSD, capacity_gb=2, channels=8, ways=4)
    print("4KB random read, 8ch/4way, 2GB (schemes vs ideal):")
    for scheme, cores in [("ideal", 1), ("fmmu", 1), ("dftl", 1),
                          ("dftl", 4), ("cdftl", 1), ("cdftl", 4)]:
        sim = SSDSim(cfg, scheme=scheme, n_cores=cores)
        sim.precondition_sequential()
        r = sim.run_closed_loop(W.rand_read_4k(cfg), 15000, outstanding=256)
        print(f"  {scheme}-{cores}c: {r['iops']/1e3:7.1f} KIOPS "
              f"(ftl util {r['util_ftl']:.2f})")

    print("\nFMMU non-blocking MSHR merge (one flash read, many requests):")
    g = small_geometry()
    o = FMMUOracle(g)
    o.push_request(Request(UPDATE, 0, dppn=1234, req_id=0))
    o.run(auto_flash=True)
    o.flush_all()
    for i in range(1, g.n_tvpns):
        o.push_request(Request(UPDATE, i * g.entries_per_tp, dppn=i,
                               req_id=i))
    o.run(auto_flash=True)
    o.flush_all()
    for j in range(g.mshr_cap):
        o.push_request(Request(LOOKUP, j, req_id=100 + j))
    o.run(auto_flash=False)
    resps, fc, _ = o.drain_outputs()
    print(f"  {g.mshr_cap} concurrent lookups -> {len(fc)} flash read(s), "
          f"{o.stats['mshr_merge']} MSHR merges")
    for t, s, w in fc:
        o.push_flash_response(t, s, w)
    o.run()
    resps, _, _ = o.drain_outputs()
    print(f"  responses delivered: {len(resps)}; "
          f"dppn of DLPN 0 = {[r.dppn for r in resps if r.req_id == 100]}")


if __name__ == "__main__":
    main()
