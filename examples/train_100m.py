"""End-to-end driver: train a ~100M-parameter llama-style model for a few
hundred steps on the synthetic pipeline, with checkpointing, resume, and
straggler monitoring. (CPU: takes a while; pass --steps 60 to shorten.)

  PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import dataclasses
import json
import tempfile

import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, data_iter
from repro.models import Runtime, build_model
from repro.training import optimizer as opt
from repro.training.train_loop import TrainerConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # ~100M params: llama3.2-1b geometry shrunk in width/depth
    cfg = dataclasses.replace(
        get_arch("llama3.2-1b"), name="llama-100m", n_layers=8,
        d_model=512, n_heads=8, n_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32768)
    total, _ = cfg.count_params()
    print(f"params: {total/1e6:.1f}M")

    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 remat="dots")
    model = build_model(cfg, rt)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    it = data_iter(dcfg)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro100m_")
    state, summary = train(
        model, it,
        opt.AdamWConfig(lr=3e-3, warmup_steps=20, decay_steps=args.steps),
        TrainerConfig(total_steps=args.steps, log_every=10, ckpt_every=100,
                      ckpt_dir=ckpt),
        on_step=lambda s, m: (s % 25 == 0) and print(
            f"step {s}: loss={float(m['loss']):.3f}"))
    if hasattr(it, "close"):
        it.close()
    print(json.dumps({"history": summary["history"],
                      "mean_step_s": summary["mean_step_s"],
                      "ckpt_dir": ckpt}))


if __name__ == "__main__":
    main()
