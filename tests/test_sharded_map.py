"""Channel-sharded FMMU map (ISSUE 5).

The paper's headline scalability claim — translation stays off the
critical path up to a 32-channel, 8-way SSD — rests on partitioning the
map state per channel. These tests pin the serving adaptation of that
partitioning to the single-device oracle:

  * property sweep: sharded ``translate_sharded`` vs the single-device
    serving path on identical random mixed LOOKUP/UPDATE/COND_UPDATE
    batches (duplicate/overflow keys included) — outputs, ok masks and
    the materialized table bit-identical, plus shadow-dict semantics
    (tests/fmmu_lockstep.sharded_lockstep);
  * shard_map lowering == vmap lowering bit-identically (in-process
    when the session has >= C devices — CI's tier1-sharded lane runs
    with XLA_FLAGS=--xla_force_host_platform_device_count=8 — and via
    an 8-virtual-device subprocess otherwise);
  * per-channel allocator stacks mirror the per-channel BlockPool free
    lists exactly; channel-dry raises per-channel OutOfBlocks / oob;
  * KVPageManager churn (new/extend/free/swap/precommit) against the
    retranslation oracle, the host-numpy swap oracle, and the mirror;
  * ServeEngine(channels=N): sharded K-step macro scan vs K single
    steps vs the unsharded engine — tokens bit-identical, per-channel
    pool free lists equal in non-retiring scans — plus the macro
    counter contract and zero fallbacks under per-channel pressure.

Every test here carries the ``sharded`` marker: CI's tier1-sharded lane
selects them under an 8-device host platform so the mesh lowering runs
for real; the normal lanes run them too (vmap lowering).
"""
import os
import random
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import example, given, settings, st

from fmmu_lockstep import sharded_geometries, sharded_lockstep
from repro.core.fmmu import batch as B
from repro.core.fmmu.types import (HOST_BASE, LOOKUP, NIL, UPDATE,
                                   small_geometry)
from repro.paging import kv_manager as KM
from repro.paging.kv_manager import KVPageManager
from repro.paging.pool import BlockPool, OutOfBlocks

pytestmark = pytest.mark.sharded

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ----------------------------------------------------------- core oracle
def test_sharded_lockstep_channels():
    """Sharded translate vs single-device oracle, C in {1, 2, 4, 8}:
    outputs, ok masks, and the materialized table bit-identical under
    random mixed batches with duplicate/overflow keys."""
    for C in (1, 2, 4, 8):
        res = sharded_lockstep(3, C, n_batches=20)
        assert res.startswith("OK"), f"C={C}: {res}"


def test_sharded_lockstep_degenerate_geometry():
    """1-way 2-set per-channel CMT (maximal eviction churn) and a
    channel count that does not divide the page space evenly."""
    res = sharded_lockstep(4, 4, n_batches=12,
                           geom_kw=dict(cmt_ways=1, cmt_sets=2))
    assert res.startswith("OK"), res
    res = sharded_lockstep(5, 3, n_batches=12)   # 128 pages % 3 != 0
    assert res.startswith("OK"), res


# pinned regression seeds (replayed by tests/_hyp.py without a wheel):
# the seed/channel pairs that first exercised duplicate-block MSHR
# merges landing in different channels and a COND losing its race in a
# non-owner batch position
@example(11, 2)
@example(23, 8)
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from([1, 2, 4, 8]))
def test_sharded_lockstep_property(seed, channels):
    res = sharded_lockstep(seed, channels, n_batches=12)
    assert res.startswith("OK"), f"C={channels} seed={seed}: {res}"


@pytest.mark.slow
def test_sharded_lockstep_long_interleaving():
    """Long mixed-op interleavings across every channel count — the
    oracle-hardening sweep's endurance case."""
    for C in (2, 4, 8):
        res = sharded_lockstep(7, C, n_batches=60)
        assert res.startswith("OK"), f"C={C}: {res}"


# ------------------------------------------------- shard_map == vmap
def _drive_pair(fj, vj, msS, msV, n_pages, seed, iters=10):
    rng = random.Random(seed)
    nprng = np.random.RandomState(seed)
    for it in range(iters):
        Bq = 16
        dl = np.asarray([rng.randrange(n_pages) if rng.random() < .9
                         else -1 for _ in range(Bq)], np.int32)
        opc = nprng.randint(0, 3, Bq).astype(np.int32)
        seen = set()
        for i in range(Bq):
            if opc[i] != LOOKUP and dl[i] in seen:
                dl[i] = -1
            seen.add(int(dl[i]))
        dp = nprng.randint(0, 10 ** 6, Bq).astype(np.int32)
        old = nprng.randint(0, 10 ** 6, Bq).astype(np.int32)
        msS, outS, okS = fj(msS, opc, dl, dp, old)
        msV, outV, okV = vj(msV, opc, dl, dp, old)
        np.testing.assert_array_equal(np.asarray(outS),
                                      np.asarray(outV), f"iter {it}")
        np.testing.assert_array_equal(np.asarray(okS),
                                      np.asarray(okV), f"iter {it}")
    for fld, a, b in zip(msV._fields, msV, msS):
        if fld == "fmmu":
            for f2, x, y in zip(a._fields, a, b):
                np.testing.assert_array_equal(np.asarray(x),
                                              np.asarray(y), f2)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          fld)


def test_shard_map_lowering_equals_vmap_inprocess():
    """With >= 2 devices in-process (the tier1-sharded CI lane forces
    8), the shard_map lowering over the channel mesh must be
    bit-identical to the portable vmap lowering — state pytree
    included."""
    import functools

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.parallel.sharding import channel_mesh, shard_map
    C = jax.device_count()
    if C < 2:
        pytest.skip("needs >= 2 devices (tier1-sharded lane has 8)")
    C = 1 << (C.bit_length() - 1)       # largest pow2 <= device count
    _, gC = sharded_geometries(C)
    n_pages = 128
    msV = B.init_sharded_state(gC, C, n_device_blocks=16,
                               n_host_blocks=8, n_lanes=2)
    mesh = channel_mesh(C)
    msS = jax.device_put(msV, NamedSharding(mesh, P("channel")))
    fj = jax.jit(shard_map(
        B.make_sharded_shard_body(gC, C), mesh=mesh,
        in_specs=(P("channel"), P(), P(), P(), P()),
        out_specs=(P("channel"), P(), P())), donate_argnums=(0,))
    vj = jax.jit(functools.partial(B.translate_sharded, gC, C),
                 donate_argnums=(0,))
    _drive_pair(fj, vj, msS, msV, n_pages, seed=1)


@pytest.mark.slow
def test_shard_map_lowering_equals_vmap_subprocess():
    """Same bit-identity proven on a real 8-device host platform via a
    subprocess (the default test session sees 1 CPU device)."""
    prog = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = \
        "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, os.path.join(%r, "src"))
    sys.path.insert(0, os.path.join(%r, "tests"))
    import jax
    assert jax.device_count() == 8
    from test_sharded_map import (
        test_shard_map_lowering_equals_vmap_inprocess as t)
    t()
    print("SHARDED_OK")
    """ % (ROOT, ROOT))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("PYTEST_CURRENT_TEST", None)
    proc = subprocess.run([sys.executable, "-c", prog],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_OK" in proc.stdout


def test_parallel_ctx_channel_axis():
    """ParallelCtx grows a 'channel' logical axis (ISSUE-5): specs
    naming it resolve onto the mesh's channel axis, ch_size reports
    its extent, and contexts without one replicate it (pre-ISSUE-5
    behavior preserved)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import channel_ctx, trivial_ctx
    ctx = channel_ctx(1)            # 1 device suffices: mesh (1,1,1)
    assert ctx.ch_size == 1
    assert ctx.resolve(P("channel"), shape=(4,)) == P("channel")
    assert ctx.resolve(P(None, "channel"), shape=(3, 4)) \
        == P(None, "channel")
    sh = ctx.sharding(P("channel"), shape=(4,))
    assert sh.spec == P("channel")
    # no channel axis configured -> 'channel' replicates
    assert trivial_ctx().resolve(P("channel"), shape=(4,)) == P()
    assert trivial_ctx().ch_size == 1


# ------------------------------------------------- allocator sharding
def test_sharded_allocator_stacks_mirror_pool():
    """init_sharded_state stripes both tiers by block id mod C in
    per-channel BlockPool pop order (first pop of channel c = block c),
    bit-equal to BlockPool's per-channel free lists."""
    C = 4
    _, gC = sharded_geometries(C)
    ms = B.init_sharded_state(gC, C, n_device_blocks=10, n_host_blocks=6,
                              n_lanes=2)
    pool = BlockPool(10, 6, n_channels=C)
    for c in range(C):
        n = int(ms.free_n[c])
        assert n == pool.free_device_ch(c)
        np.testing.assert_array_equal(
            np.asarray(ms.free_stack[c, :n]),
            np.asarray(pool._free_dev_ch[c], np.int32))
        h = int(ms.host_n[c])
        assert h == pool.free_host_ch(c)
        np.testing.assert_array_equal(
            np.asarray(ms.host_stack[c, :h]),
            np.asarray(pool._free_host_ch[c], np.int32))


def test_grow_sharded_pops_owner_channel_and_flags_dry_channel():
    """grow_sharded pops each lane's block from the OWNER channel of
    its dlpn; a dry channel fails only its own lanes and raises only
    its own oob flag (per-channel pool pressure)."""
    C = 2
    _, gC = sharded_geometries(C)
    # channel 0 owns {0, 2}, channel 1 owns {1, 3}
    ms = B.init_sharded_state(gC, C, n_device_blocks=4)
    grow = jnp.array([True, True, True])
    dl = jnp.array([0, 1, 2], jnp.int32)     # owners: 0, 1, 0
    ms, blocks, ok = B.grow_sharded(gC, C, ms, grow, dl)
    assert list(np.asarray(blocks)) == [0, 1, 2]
    assert list(np.asarray(ok)) == [True] * 3
    assert not bool(np.asarray(ms.oob).any())
    # channel 0 is now dry; channel 1 still holds block 3
    ms, blocks, ok = B.grow_sharded(gC, C, ms, jnp.array([True, True]),
                                    jnp.array([4, 3], jnp.int32))
    assert list(np.asarray(blocks)) == [-1, 3]   # dlpn 4 -> ch 0: dry
    assert list(np.asarray(ok)) == [False, True]
    assert list(np.asarray(ms.oob)) == [True, False]
    # the committed mappings landed in the owning shards' tables
    tbl = np.asarray(B.dense_table(ms, C, 8))
    assert list(tbl[:5]) == [0, 1, 2, 3, NIL]


def test_pool_alloc_for_per_channel_out_of_blocks():
    pool = BlockPool(4, 0, n_channels=2)
    assert pool.alloc_for([0, 1, 0]) == [0, 1, 2]
    with pytest.raises(OutOfBlocks):
        pool.alloc_for([0])                  # channel 0 dry
    assert pool.free_device == 1             # pre-check popped nothing
    assert pool.alloc_for([1]) == [3]
    pool.free([2, 3])
    assert pool._free_dev_ch[0] == [2] and pool._free_dev_ch[1] == [3]


# ------------------------------------------------- KVPageManager churn
def _oracle_apply_swap(shadow, kvm, pre_pages, post_pages):
    row = lambda b: (kvm.pool.host_row(b) if BlockPool.is_host(b)
                     else b)
    src = [row(a) for a, b in zip(pre_pages, post_pages) if a != b]
    dst = [row(b) for a, b in zip(pre_pages, post_pages) if a != b]
    shadow[dst] = shadow[src]


@pytest.mark.parametrize("channels", [2, 4])
def test_kvm_sharded_churn_vs_oracles(channels):
    """new/extend/free/swap/precommit churn on a channel-sharded
    KVPageManager: pool bytes vs the host-numpy swap oracle, table vs
    the sharded retranslation oracle, per-channel allocator mirror
    exact, channel-lane counters sum to the routed lanes."""
    kvm = KVPageManager(n_slots=4, max_pages=6, n_device_blocks=16,
                        n_host_blocks=10, channels=channels)
    pool = jnp.arange((16 + 10 + 1) * 3.0).reshape(27, 3)
    shadow = np.array(pool)
    rng = random.Random(5)
    live = set()
    for step in range(80):
        ops = ["new"] if len(live) < 4 else []
        if live:
            ops += ["extend", "free", "swap_out", "swap_in", "pre"]
        op = rng.choice(ops)
        try:
            if op == "new":
                s = rng.choice([x for x in range(4) if x not in live])
                kvm.new_seq(s, rng.randint(1, 3))
                live.add(s)
            elif op == "extend":
                s = rng.choice(sorted(live))
                room = max(0, 6 - len(kvm.seq_pages[s]))
                if room:
                    kvm.extend_seq(s, rng.randint(1, room))
            elif op == "pre":
                # the sharded macro boundary's growth pre-commit
                slots = [s for s in sorted(live) if kvm.is_resident(s)
                         and len(kvm.seq_pages[s]) <= 4]
                if slots:
                    kvm.precommit_growth(slots + slots[:1])
            elif op == "free":
                s = rng.choice(sorted(live))
                kvm.free_seq(s)
                live.discard(s)
            else:
                s = rng.choice(sorted(live))
                pre = list(kvm.seq_pages[s])
                fn = kvm.swap_out if op == "swap_out" else kvm.swap_in
                [pool], _ = fn(s, [pool], check=rng.random() < 0.5)
                _oracle_apply_swap(shadow, kvm, pre, kvm.seq_pages[s])
        except OutOfBlocks:
            pass
        np.testing.assert_array_equal(np.asarray(pool), shadow,
                                      f"step {step}: pool diverged")
        if step % 16 == 15:
            np.testing.assert_array_equal(
                np.asarray(kvm.block_tables()),
                np.asarray(kvm.retranslate_tables()), f"step {step}")
            kvm.sync_allocator()
            st_ = kvm.state
            for c in range(channels):
                n = int(st_.free_n[c])
                assert n == kvm.pool.free_device_ch(c), (step, c)
                np.testing.assert_array_equal(
                    np.asarray(st_.free_stack[c, :n]),
                    np.asarray(kvm.pool._free_dev_ch[c], np.int32))
    assert kvm.channel_lanes.sum() > 0
    assert (kvm.channel_lanes > 0).all(), \
        "some channel never serviced a lane: routing is broken"


def test_kvm_sharded_swap_pending_lane_all_channels():
    """The swap_pending residency lane is replicated per channel and
    flips in the same fused call on every shard."""
    kvm = KVPageManager(n_slots=3, max_pages=4, n_device_blocks=8,
                        n_host_blocks=8, channels=2)
    pool = jnp.zeros((8 + 8 + 1, 2))
    kvm.new_seq(0, 2)
    [pool], _ = kvm.swap_out(0, [pool])
    lanes = np.asarray(kvm.state.swap_pending)
    assert lanes.shape == (2, 3)
    assert lanes[:, 0].all() and not lanes[:, 1:].any()
    assert not kvm.is_resident(0)
    [pool], _ = kvm.swap_in(0, [pool])
    assert not np.asarray(kvm.state.swap_pending).any()


# ------------------------------------------------- engine cross-tests
RTT = None
_MODEL = None


def _tiny_model():
    global RTT, _MODEL
    if _MODEL is None:
        from repro.configs import get_arch, smoke_config
        from repro.models import Runtime, build_model
        RTT = Runtime(compute_dtype=jnp.float32,
                      param_dtype=jnp.float32, remat="none",
                      page_size=8, capacity_factor=100.0)
        cfg = smoke_config(get_arch("llama3.2-1b"))
        m = build_model(cfg, RTT)
        params = m.init(jax.random.key(0))
        _MODEL = (m, params)
    return _MODEL


def _pool_state_ch(eng):
    return ([list(ch) for ch in eng.kvm.pool._free_dev_ch],
            [list(ch) for ch in eng.kvm.pool._free_host_ch],
            {s: list(p) for s, p in eng.kvm.seq_pages.items()})


def test_sharded_engine_tokens_match_unsharded():
    """channels=2 single-step AND macro tokens bit-identical to the
    channels=1 engine (retirement mid-scan included)."""
    from repro.serving.engine import ServeEngine
    m, params = _tiny_model()
    t1, t2 = list(range(1, 8)), list(range(50, 73))

    def run(channels, macro_k):
        eng = ServeEngine(m, params, n_slots=2, max_ctx=64,
                          macro_k=macro_k, channels=channels)
        r1 = eng.submit(t1, max_new=10)
        r2 = eng.submit(t2, max_new=7)      # retires mid-scan at K=4
        done = eng.run()
        return done[r1], done[r2], eng

    ref = run(1, 0)
    sh_ss = run(2, 0)
    sh_mk = run(2, 4)
    assert ref[:2] == sh_ss[:2] == sh_mk[:2]
    assert sh_mk[2].metrics["macro_steps"] > 0
    assert sh_mk[2].metrics["macro_fallbacks"] == 0
    np.testing.assert_array_equal(
        np.asarray(ref[2].kvm.block_tables()),
        np.asarray(sh_ss[2].kvm.block_tables()))


def test_sharded_macro_equals_single_steps_bitwise():
    """Non-retiring scans: channels=2 K-step macro == K single steps —
    tokens, block tables, seq_pages AND per-channel pool free lists
    (the pre-committed growth pops in the same step-major order the
    single-step path pops)."""
    from repro.serving.engine import ServeEngine
    m, params = _tiny_model()
    t1, t2 = list(range(1, 8)), list(range(30, 53))

    def run(macro_k):
        eng = ServeEngine(m, params, n_slots=2, max_ctx=64,
                          macro_k=macro_k, channels=2)
        r1 = eng.submit(t1, max_new=8)     # multiples of K: retirement
        r2 = eng.submit(t2, max_new=8)     # only at boundaries
        done = eng.run()
        return (done[r1], done[r2]), eng

    outs_s, eng_s = run(0)
    outs_m, eng_m = run(4)
    assert eng_m.metrics["macro_steps"] > 0
    assert outs_s == outs_m
    assert _pool_state_ch(eng_s) == _pool_state_ch(eng_m)
    np.testing.assert_array_equal(np.asarray(eng_s.kvm.block_tables()),
                                  np.asarray(eng_m.kvm.block_tables()))
    # per-channel allocator mirror agrees after the lazy sync
    eng_m.kvm.sync_allocator()
    st_ = eng_m.kvm.state
    for c in range(2):
        n = int(st_.free_n[c])
        assert n == eng_m.kvm.pool.free_device_ch(c)
        np.testing.assert_array_equal(
            np.asarray(st_.free_stack[c, :n]),
            np.asarray(eng_m.kvm.pool._free_dev_ch[c], np.int32))


def test_sharded_macro_counter_contract():
    """Per K tokens in sharded steady state: exactly 1 macro dispatch +
    1 host sync, at most 1 fused sharded map call (growth boundaries
    only), 0 allocator syncs, 0 full-map retranslations, no translate
    re-trace — and the routed lanes split ~1/N per channel."""
    from repro.serving import engine as E
    from repro.serving.engine import ServeEngine
    m, params = _tiny_model()
    K = 8
    eng = ServeEngine(m, params, n_slots=2, max_ctx=256, macro_k=K,
                      channels=2)
    eng.min_page_bucket = 32
    eng.submit(list(range(1, 9)), max_new=10 ** 6)
    eng.submit(list(range(20, 28)), max_new=10 ** 6)
    done: dict = {}
    eng.step(done)
    for _ in range(3):                 # settle: trace the scan variants
        eng.step(done)
    for _ in range(6):
        d0, s0 = E.MACRO_DISPATCHES[0], E.HOST_SYNCS[0]
        x0, f0, a0 = (KM.XLATE_CALLS[0], KM.FULL_TABLE_CALLS[0],
                      KM.ALLOC_SYNCS[0])
        p0 = B.PROBE_TRACES[0]
        n0 = eng.metrics["decode_steps"]
        eng.step(done)
        assert eng.metrics["decode_steps"] - n0 == K
        assert E.MACRO_DISPATCHES[0] - d0 == 1
        assert E.HOST_SYNCS[0] - s0 == 1
        assert KM.XLATE_CALLS[0] - x0 <= 1
        assert KM.FULL_TABLE_CALLS[0] - f0 == 0
        assert KM.ALLOC_SYNCS[0] - a0 == 0
        assert B.PROBE_TRACES[0] - p0 == 0, "sharded path re-traced"
    assert eng.metrics["macro_fallbacks"] == 0
    lanes = eng.kvm.channel_lanes
    assert lanes.sum() > 0
    # 1/N routing: with page-striped dlpns both channels carry work
    assert lanes.min() >= lanes.sum() // 4, lanes


@pytest.mark.slow
def test_sharded_oversubscribed_zero_fallbacks():
    """ISSUE-5 acceptance: ~2x oversubscription on a channels=2 engine
    (per-channel pools absorb the pressure) keeps every decode round on
    the fused sharded macro path — zero fallbacks, swap traffic
    nonzero, outputs bit-identical to uncontended solo runs."""
    from repro.serving.engine import ServeEngine
    m, params = _tiny_model()
    eng = ServeEngine(m, params, n_slots=4, max_ctx=64,
                      n_device_blocks=10, n_host_blocks=24, macro_k=4,
                      swap_patience=2, channels=2)
    prompts = [list(range(1 + 20 * i, 9 + 20 * i)) for i in range(4)]
    rids = [eng.submit(p, max_new=24) for p in prompts]
    done: dict = {}
    while eng.step(done):
        pass
    assert set(done) == set(rids)
    assert eng.metrics["macro_fallbacks"] == 0, \
        "per-channel pressure dropped the sharded engine off the " \
        "macro path"
    assert eng.metrics["swaps_out"] > 0 and eng.metrics["swaps_in"] > 0
    for p, rid in zip(prompts, rids):
        solo = ServeEngine(m, params, n_slots=1, max_ctx=64,
                           channels=2)
        rs = solo.submit(list(p), max_new=24)
        assert solo.run()[rs] == done[rid], rid
