"""Sudden-power-off recovery at the ENGINE level (ISSUE 7): resumed
decode is bit-identical to an uncrashed oracle, recovery re-arms the
journal (a second crash replays cleanly), and the recovered admission
deque preserves the quarantine-requeue vs recovery-requeue ordering
contract (satellite 2):

    [crash-time front-requeued quarantined requests]
  + [recovered in-flight requests, admission order]
  + [never-admitted arrivals, FIFO]

A quarantined request was deliberately pushed AHEAD of the admission
point before the crash (ISSUE-6 discipline: it already waited once);
recovery must not demote it behind the in-flight requests it had
already overtaken.
"""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.core import faults as flt
from repro.core import journal as jl
from repro.core.faults import FaultPlane, make_plan
from repro.models import Runtime, build_model
from repro.serving.engine import ServeEngine

pytestmark = pytest.mark.recovery

RT = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
             remat="none", page_size=8, capacity_factor=100.0)

PROMPTS = [list(range(3 + 11 * i, 10 + 11 * i)) for i in range(6)]
MAX_NEW = 10
MAX_STEPS = 4000

_CACHE: dict = {}


def _engine(C: int = 2) -> ServeEngine:
    eng = _CACHE.get(C)
    if eng is None:
        m = _CACHE.get("model")
        if m is None:
            cfg = smoke_config(get_arch("llama3.2-1b"))
            cfg = dataclasses.replace(
                cfg, name="spor-tiny", n_layers=cfg.period, d_model=32,
                n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                vocab_size=128)
            model = build_model(cfg, RT)
            m = (model, model.init(jax.random.key(0)))
            _CACHE["model"] = m
        model, params = m
        eng = ServeEngine(model, params, n_slots=4, max_ctx=64,
                          n_device_blocks=12, n_host_blocks=24,
                          macro_k=4, swap_patience=2, channels=C,
                          watchdog_rounds=16)
        _CACHE[C] = eng
    return eng


def _oracle(C: int = 2):
    key = ("oracle", C)
    if key not in _CACHE:
        eng = _engine(C)
        eng.reset(None)
        rids = [eng.submit(list(p), max_new=MAX_NEW) for p in PROMPTS]
        done = eng.run(max_steps=MAX_STEPS)
        assert not eng.active and not eng.queue
        _CACHE[key] = [done[r] for r in rids]
    return _CACHE[key]


def _crash_plan(seed, C, crash_at, tear):
    plan = make_plan(seed, channels=C, crash_at=crash_at)
    return FaultPlane(plan._replace(
        crash_tear=np.full_like(plan.crash_tear, tear)))


def _crash_then_recover(eng, d, C, crash_at, tear, snapshot_every=4):
    """Journaled run to a scheduled power cut, then recover + drain.
    Returns (outputs keyed by prompt index, last_recovery)."""
    eng.reset(_crash_plan(7, C, crash_at, tear))
    eng.attach_journal(d, snapshot_every=snapshot_every)
    try:
        for p in PROMPTS:
            eng.submit(list(p), max_new=MAX_NEW)
        eng.run(max_steps=MAX_STEPS)
        pytest.skip(f"crash_at={crash_at} beyond this workload's "
                    f"commit count")
    except flt.Crash:
        pass
    durable = eng.recover(d, fault_plane=None)
    # a prompt whose SUBMIT never became durable is the client's to
    # re-submit; rids were assigned in prompt order
    present = set(durable) | {r.rid for r in eng.queue}
    remap = {}
    for i in range(len(PROMPTS)):
        if i not in present:
            remap[eng.submit(list(PROMPTS[i]), max_new=MAX_NEW)] = i
    done = eng.run(max_steps=MAX_STEPS)
    assert not eng.active and not eng.queue, "recovered run undrained"
    final = {**durable, **done}
    for nr, i in remap.items():
        final[i] = final.pop(nr)
    return final, eng.last_recovery


@pytest.mark.parametrize("crash_at,tear", [
    (3, 1.0),     # early cut between commits (whole record lands)
    (3, 0.4),     # early torn tail
    (25, 1.0),    # mid-run, map traffic in flight
    (25, 0.4),    # mid-run torn tail -> OOB reverse-map scan
])
def test_recover_resumes_bit_identical(crash_at, tear):
    C = 2
    ref = _oracle(C)
    eng = _engine(C)
    with tempfile.TemporaryDirectory() as d:
        final, info = _crash_then_recover(eng, d, C, crash_at, tear)
        got = [final[i] for i in range(len(PROMPTS))]
        assert got == ref, (crash_at, tear, info)
        assert eng.journal_lane_check()
        assert eng.metrics["recoveries"] == 1
        assert info["replayed"] >= 0 and info["recover_s"] > 0
        if tear < 1.0 and info["torn"]:
            # a torn MAP commit must have been recovered by the scan
            # (engine-lifecycle records tear too — those carry no OOB)
            pass


def test_torn_map_commit_recovers_via_oob_scan():
    """Vacuity guard for the parametrized sweep: at least one scheduled
    cut must tear a map commit mid-record and recover via the OOB
    reverse-map scan, and the resumed outputs still match the oracle."""
    C = 2
    ref = _oracle(C)
    eng = _engine(C)
    seen_scan = False
    for crash_at in (10, 18, 25, 32):
        with tempfile.TemporaryDirectory() as d:
            final, info = _crash_then_recover(eng, d, C, crash_at, 0.5)
            assert [final[i] for i in range(len(PROMPTS))] == ref
            seen_scan |= info["oob_scan"]
        if seen_scan:
            break
    assert seen_scan, "no cut ever exercised the reverse-map scan"


def test_second_crash_after_recovery_replays_cleanly():
    """recover() re-arms the journal with a fresh base snapshot: a
    SECOND power cut after the first recovery must replay to the oracle
    as well (MTTR is bounded per crash, not per lifetime)."""
    C = 2
    ref = _oracle(C)
    eng = _engine(C)
    with tempfile.TemporaryDirectory() as d:
        eng.reset(_crash_plan(7, C, 12, 0.5))
        eng.attach_journal(d, snapshot_every=4)
        with pytest.raises(flt.Crash):
            for p in PROMPTS:
                eng.submit(list(p), max_new=MAX_NEW)
            eng.run(max_steps=MAX_STEPS)
        durable = eng.recover(d, fault_plane=_crash_plan(9, C, 15, 0.7))
        present = set(durable) | {r.rid for r in eng.queue}
        remap = {}
        for i in range(len(PROMPTS)):
            if i not in present:
                remap[eng.submit(list(PROMPTS[i]), max_new=MAX_NEW)] = i
        with pytest.raises(flt.Crash):
            eng.run(max_steps=MAX_STEPS)
        durable2 = eng.recover(d, fault_plane=None)
        present = set(durable2) | {r.rid for r in eng.queue}
        for i in range(len(PROMPTS)):
            if i not in present and i not in remap.values():
                remap[eng.submit(list(PROMPTS[i]), max_new=MAX_NEW)] = i
        done = eng.run(max_steps=MAX_STEPS)
        assert not eng.active and not eng.queue
        final = {**durable, **durable2, **done}
        for nr, i in remap.items():
            if nr in final:
                final[i] = final.pop(nr)
        assert [final[i] for i in range(len(PROMPTS))] == ref
        assert eng.metrics["recoveries"] == 2


# ------------------------------------------------- requeue ordering
def test_requeue_ordering_quarantined_stay_ahead():
    """The satellite-2 contract, isolated from decode: synthesize the
    engine-lifecycle journal of a crash that caught r0/r2 in flight,
    r1 quarantined (front-requeued), r3/r4 never admitted. The
    recovered deque must be [r1, r0, r2, r3, r4] — quarantined first,
    then in-flight in ADMISSION order, then pristine FIFO."""
    eng = _engine(2)
    eng.reset(None)
    with tempfile.TemporaryDirectory() as d:
        eng.attach_journal(d)
        j = eng.journal
        for rid in range(5):
            j.append(jl.SUBMIT, {"rid": rid, "tokens": [7 + rid],
                                 "max_new": 2, "lanes": 0})
        for rid, slot in ((0, 0), (1, 1), (2, 2)):
            j.append(jl.ADMIT, {"rid": rid, "slot": slot, "lanes": 0})
        j.append(jl.QUAR, {"rid": 1, "lanes": 0})
        eng.recover(d)
        assert [r.rid for r in eng.queue] == [1, 0, 2, 3, 4]
        # restart semantics: outputs reset, prompts intact
        assert all(r.out == [] and r.slot == -1 for r in eng.queue)
        assert eng._rid == 5
        assert eng._ever_admitted == {0, 1, 2}


def test_requeue_ordering_readmitted_quarantine_moves_to_end():
    """A quarantined request that was RE-admitted before the crash is
    back in flight: its admission position is its re-admission (end of
    the active order), not its original slot grant."""
    eng = _engine(2)
    eng.reset(None)
    with tempfile.TemporaryDirectory() as d:
        eng.attach_journal(d)
        j = eng.journal
        for rid in range(4):
            j.append(jl.SUBMIT, {"rid": rid, "tokens": [3 + rid],
                                 "max_new": 2, "lanes": 0})
        j.append(jl.ADMIT, {"rid": 0, "slot": 0, "lanes": 0})
        j.append(jl.ADMIT, {"rid": 1, "slot": 1, "lanes": 0})
        j.append(jl.QUAR, {"rid": 0, "lanes": 0})
        j.append(jl.ADMIT, {"rid": 0, "slot": 2, "lanes": 0})
        eng.recover(d)
        # in-flight admission order is r1 then r0 (re-admission); r2/r3
        # pristine
        assert [r.rid for r in eng.queue] == [1, 0, 2, 3]


def test_durably_finished_survive_crash():
    """FINISH records make outputs durable: a request that completed
    before the cut is returned by recover() and never re-run."""
    eng = _engine(2)
    eng.reset(None)
    with tempfile.TemporaryDirectory() as d:
        eng.attach_journal(d)
        j = eng.journal
        j.append(jl.SUBMIT, {"rid": 0, "tokens": [5], "max_new": 2,
                             "lanes": 0})
        j.append(jl.ADMIT, {"rid": 0, "slot": 0, "lanes": 0})
        j.append(jl.FINISH, {"rid": 0, "out": [9, 11], "lanes": 0})
        durable = eng.recover(d)
        assert durable == {0: [9, 11]}
        assert not eng.queue and not eng.active
