"""GC victim-eviction walk + CTP prefetch (ISSUE 9): live-count
bit-identity against a numpy oracle under random churn, walk-vs-oracle
victim selection with data-integrity checks, stale-skip (CondUpdate)
semantics, budget enforcement, journal replay bit-identity, the
gc-disabled jaxpr-identity guarantee, the typed-config shim, the
counters registry, and MapStats typed access."""
import dataclasses
import random
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import journal as jl
from repro.core.counters import COUNTERS, Counters
from repro.core.fmmu import batch as B
from repro.core.fmmu.types import NIL, UPDATE, small_geometry
from repro.paging import kv_manager as KM
from repro.paging.kv_manager import KVPageManager, MapStats
from repro.serving.config import (DurabilityConfig, FaultPolicy,
                                  GCConfig, ServeConfig)

pytestmark = pytest.mark.gc

CHANNELS = (1, 2, 4)


def _kvm(C, n_dev=32, n_host=8, max_pages=8, track_live=True):
    return KVPageManager(n_slots=6, max_pages=max_pages,
                         n_device_blocks=n_dev, n_host_blocks=n_host,
                         channels=C, track_live=track_live)


def _oracle_live(kvm) -> np.ndarray:
    """Per-block live counts recomputed from the host's seq_pages —
    the ground truth the device lane must match bit-for-bit."""
    lv = np.zeros(kvm.pool.n_device, np.int64)
    for _, pages in kvm.seq_pages.items():
        for b in pages:
            if not kvm.pool.is_host(b):
                lv[b] += 1
    return lv


# ---------------------------------------------------------------------
# live-count lane: oracle bit-identity under random churn
# ---------------------------------------------------------------------
@pytest.mark.parametrize("C", CHANNELS)
def test_live_counts_match_oracle_under_churn(C):
    """Random new_seq / extend / free / swap churn: the device-side
    live lane (maintained INSIDE the fused commits — no extra probe)
    must equal the numpy oracle after every operation."""
    kvm = _kvm(C)
    rng = random.Random(100 + C)
    width = kvm.pool.n_device + kvm.pool.n_host + 1
    pools = [jnp.arange(width * 4.0).reshape(width, 4)]
    for step in range(60):
        op = rng.random()
        free_slots = [s for s in range(kvm.n_slots)
                      if s not in kvm.seq_pages]
        if op < 0.35 and free_slots:
            try:
                kvm.new_seq(rng.choice(free_slots), rng.randint(1, 4))
            except KM.OutOfBlocks:
                pass
        elif op < 0.6 and kvm.seq_pages:
            s = rng.choice(list(kvm.seq_pages))
            if kvm.is_resident(s) \
                    and len(kvm.seq_pages[s]) < kvm.max_pages:
                try:
                    kvm.extend_seq(s, 1)
                except KM.OutOfBlocks:
                    pass
        elif op < 0.75 and kvm.seq_pages:
            kvm.free_seq(rng.choice(list(kvm.seq_pages)))
        elif kvm.seq_pages:
            s = rng.choice(list(kvm.seq_pages))
            try:
                if kvm.is_resident(s):
                    pools, _ = kvm.swap_out(s, pools)
                else:
                    pools, _ = kvm.swap_in(s, pools)
            except KM.OutOfBlocks:
                pass
        np.testing.assert_array_equal(kvm.live_counts(),
                                      _oracle_live(kvm), str(step))


# ---------------------------------------------------------------------
# the walk itself: victim selection, relocation integrity, budget
# ---------------------------------------------------------------------
def _fragment(kvm, rng, rounds=12):
    """Alloc/free churn that leaves fragmented erase blocks."""
    for _ in range(rounds):
        free_slots = [s for s in range(kvm.n_slots)
                      if s not in kvm.seq_pages]
        if free_slots and rng.random() < 0.7:
            try:
                kvm.new_seq(rng.choice(free_slots), rng.randint(2, 6))
            except KM.OutOfBlocks:
                pass
        elif kvm.seq_pages:
            kvm.free_seq(rng.choice(list(kvm.seq_pages)))


@pytest.mark.parametrize("C", CHANNELS)
def test_gc_walk_vs_oracle(C):
    """The walk must pick, per channel, the fragmented full erase block
    with the fewest live pages (numpy oracle over pool.erase_blocks +
    the live counts), relocate exactly its live pages, leave the net
    free count unchanged (defrag model), and keep every surviving
    mapping readable through the block table."""
    P = 4
    for seed in range(3):
        kvm = _kvm(C)
        rng = random.Random(7 * seed + C)
        _fragment(kvm, rng)
        lv = kvm.live_counts()
        want = {}
        for c in range(C):
            best = None
            for frames in kvm.pool.erase_blocks(c, P):
                n = int(sum(lv[f] for f in frames))
                if 0 < n < len(frames) \
                        and not any(kvm.pool.is_retired(f)
                                    for f in frames):
                    if best is None or n < best[0]:
                        best = (n, frames)
            if best:
                want[c] = best
        # GC is opportunistic: a channel relocates min(live, eligible
        # destinations) pages, where destinations exclude the victim's
        # own frames — model that in the oracle too
        expect = {}
        for c, (n, frames) in want.items():
            elig = len([b for b in kvm.pool._free_dev_ch[c]
                        if b not in frames])
            if min(n, elig):
                expect[c] = (min(n, elig), n, frames)
        free0 = kvm.pool.free_device
        mapping0 = {s: list(p) for s, p in kvm.seq_pages.items()}
        _, moved, reclaimed = kvm.gc_collect(block_pages=P, budget=64)
        assert moved == sum(m for m, _, _ in expect.values())
        assert kvm.pool.free_device == free0          # defrag: net zero
        # every relocated page: mapping changed, table follows, live ok
        tab = np.asarray(kvm.block_tables())
        for s, pages in kvm.seq_pages.items():
            assert list(tab[s, :len(pages)]) == pages
            assert len(pages) == len(mapping0[s])
        np.testing.assert_array_equal(kvm.live_counts(),
                                      _oracle_live(kvm))
        # each fully-relocated victim's frames are ALL free now; a
        # channel whose destinations ran short reclaims nothing yet
        lv2 = kvm.live_counts()
        full = {c for c, (m, n, _) in expect.items() if m == n}
        for c in full:
            assert all(lv2[f] == 0 for f in expect[c][2]), c
        assert reclaimed == len(full)
        assert kvm.victims_ch == [int(c in full) for c in range(C)]


def test_gc_budget_respected():
    """pages_per_boundary is a hard cap across the whole walk — a
    victim that does not fit relocates partially and finishes later."""
    kvm = _kvm(1)
    rng = random.Random(3)
    _fragment(kvm, rng)
    lv = kvm.live_counts()
    frag = [f for f in kvm.pool.erase_blocks(0, 4)
            if 0 < sum(lv[x] for x in f) < 4]
    assert frag, "churn did not fragment — fixture needs a new seed"
    _, moved, reclaimed = kvm.gc_collect(block_pages=4, budget=1)
    assert moved <= 1
    _, moved0, _ = kvm.gc_collect(block_pages=4, budget=0)
    assert moved0 == 0


def test_gc_stale_mapping_skipped():
    """Relocate-if-still-mapped: when the device map no longer points
    at the block the host planned to move (the page died / was remapped
    mid-walk), the CondUpdate lane must NOT commit and the unused
    destination must return to the free list."""
    kvm = _kvm(1, n_dev=16, n_host=0)
    kvm.new_seq(0, 2)      # blocks 0,1 live
    kvm.new_seq(1, 2)      # blocks 2,3 -> freed below
    kvm.new_seq(2, 4)      # blocks 4..7
    kvm.free_seq(1)        # erase block [0..3]: 2 live, 2 dead
    lv = kvm.live_counts()
    victim = next(f for f in kvm.pool.erase_blocks(0, 4)
                  if 0 < sum(lv[x] for x in f) < 4)
    live_frame = next(f for f in victim if lv[f] > 0)
    # make the device mapping stale BEHIND the walk's back: remap the
    # dlpn to another block via a raw fused UPDATE, then pin the
    # walk's live-count readback to the PRE-remap snapshot — exactly
    # the mid-walk race the CondUpdate guard arbitrates (the live lane
    # itself is maintained by the remap commit, so without the pin the
    # frame would simply drop out of the plan)
    s, i = next((s, i) for s, p in kvm.seq_pages.items()
                for i, b in enumerate(p) if b == live_frame)
    dl = s * kvm.max_pages + i
    kvm._xlate(UPDATE, [dl], [15])
    kvm.live_counts = lambda: lv          # stale snapshot, white-box
    free0 = kvm.pool.free_device
    moves0 = kvm.gc_moves
    _, moved, reclaimed = kvm.gc_collect(block_pages=4, budget=8)
    # the stale lane was skipped: seq_pages untouched there, its
    # unused destination went straight back (free list net unchanged),
    # the victim was NOT counted reclaimed, and only the still-valid
    # lanes moved
    assert kvm.seq_pages[s][i] == live_frame
    assert kvm.pool.free_device == free0
    assert reclaimed == 0
    assert kvm.gc_moves - moves0 == moved < sum(
        1 for f in victim if lv[f] > 0)


# ---------------------------------------------------------------------
# crash consistency: a GC record replays bit-identically
# ---------------------------------------------------------------------
@pytest.mark.parametrize("C", CHANNELS)
def test_gc_journal_replay_bit_identity(C):
    def fresh():
        return _kvm(C)
    with tempfile.TemporaryDirectory() as d:
        kvm = fresh()
        j = jl.Journal(d)
        kvm.journal = j
        j.snapshot(kvm.snapshot_state())
        rng = random.Random(C)
        moved = 0
        for _ in range(8):       # churn until a walk finds real work
            _fragment(kvm, rng)
            _, m, _ = kvm.gc_collect(block_pages=4, budget=8)
            moved += m
            if moved:
                break
        assert moved > 0, "fixture produced no GC work"
        if 0 not in kvm.seq_pages:
            kvm.new_seq(0, 2)                    # traffic after GC
        rec = jl.replay(d)
        k2 = fresh()
        k2.restore_mapping(rec)
        assert {s: list(p) for s, p in kvm.seq_pages.items()} == \
               {s: list(p) for s, p in k2.seq_pages.items()}
        assert kvm.pool.state_dict() == k2.pool.state_dict()
        np.testing.assert_array_equal(np.asarray(kvm.block_tables()),
                                      np.asarray(k2.block_tables()))
        np.testing.assert_array_equal(kvm.live_counts(),
                                      k2.live_counts())
        j.close()


# ---------------------------------------------------------------------
# gc-off jaxpr identity: the live lane is an ABSENT pytree leaf
# ---------------------------------------------------------------------
def _prims(closed):
    from collections import Counter
    return Counter(e.primitive.name for j in _iter(closed.jaxpr)
                   for e in j.eqns)


def _iter(jaxpr):
    yield jaxpr
    for eq in jaxpr.eqns:
        for v in eq.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for x in vs:
                sub = getattr(x, "jaxpr", x)
                if hasattr(sub, "eqns"):
                    yield from _iter(sub)


def test_gc_off_jaxpr_identical_and_on_adds_no_probe():
    """track_live=False leaves live=None — an empty pytree node — so
    the traced fused translate is IDENTICAL to the pre-GC graph (the
    off path cannot regress). track_live=True adds only elementwise +
    scatter-add ops: no extra sort (no second insert pass), no extra
    probe (PROBE_TRACES/INSERT_TRACES still bump exactly once)."""
    import functools
    g = small_geometry()
    dl = jnp.arange(8, dtype=jnp.int32)
    dp = jnp.ones(8, jnp.int32)
    old = jnp.zeros(8, jnp.int32)
    kinds = jnp.array([0, 1, 2, 0, 1, 2, 0, 1], jnp.int32)
    fn = functools.partial(B.translate_serving, g)
    ms_off = B.init_serving_state(g, n_device_blocks=8,
                                  track_live=False)
    ms_on = B.init_serving_state(g, n_device_blocks=8, track_live=True)
    assert ms_off.live is None and ms_on.live is not None
    p0, i0 = B.PROBE_TRACES[0], B.INSERT_TRACES[0]
    jx_off = jax.make_jaxpr(fn)(ms_off, kinds, dl, dp, old)
    jx_on = jax.make_jaxpr(fn)(ms_on, kinds, dl, dp, old)
    assert B.PROBE_TRACES[0] - p0 == 2      # once per trace
    assert B.INSERT_TRACES[0] - i0 == 2
    off, on = _prims(jx_off), _prims(jx_on)
    # the off graph is a sub-multiset of the on graph: arming the lane
    # only ADDS ops, and none of them is a sort or a gather/probe
    assert not (off - on), (off - on)
    extra = on - off
    assert "sort" not in extra, extra
    assert "gather" not in extra, extra


def test_engine_gc_off_carries_no_live_lane():
    """gc=None at the engine API must not arm the lane (the config is
    the ONE switch): the manager's state carries live=None."""
    kvm = _kvm(1, track_live=False)
    assert kvm.state.live is None
    st = kvm.hit_stats()
    assert st.gc_moves == 0 and st.write_amp >= 1.0


# ---------------------------------------------------------------------
# typed config + deprecation shim
# ---------------------------------------------------------------------
def test_serve_config_from_legacy_equivalence():
    """The legacy flat keyword set must build the EXACT config value
    the typed form describes — field for field, nested blocks
    included."""
    got = ServeConfig.from_legacy(
        n_slots=4, max_ctx=64, n_device_blocks=12, n_host_blocks=24,
        macro_k=4, swap_patience=2, channels=2, eos_id=7,
        nonblocking_swap=False, admit_tokens=32, use_mesh=True,
        max_swap_retries=5, swap_backoff_cap=16, watchdog_rounds=9,
        journal_path="/tmp/x", snapshot_every=3)
    want = ServeConfig(
        n_slots=4, max_ctx=64, n_device_blocks=12, n_host_blocks=24,
        macro_k=4, swap_patience=2, channels=2, eos_id=7,
        nonblocking_swap=False, admit_tokens=32, use_mesh=True,
        faults=FaultPolicy(max_swap_retries=5, swap_backoff_cap=16,
                           watchdog_rounds=9),
        durability=DurabilityConfig(journal_path="/tmp/x",
                                    snapshot_every=3))
    assert got == want
    assert dataclasses.asdict(got) == dataclasses.asdict(want)
    with pytest.raises(TypeError, match="unexpected keyword"):
        ServeConfig.from_legacy(n_slots=1, max_ctx=8, bogus=1)


def test_serve_config_frozen_and_validated():
    cfg = ServeConfig(n_slots=2, max_ctx=16)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.n_slots = 3
    with pytest.raises(AssertionError):
        GCConfig(watermark=0)
    assert cfg.gc is None and cfg.faults == FaultPolicy()


def test_engine_legacy_shim_warns_once_and_matches_config():
    """ServeEngine(model, params, <flat kwargs>) emits exactly ONE
    DeprecationWarning and builds the same config value as the typed
    constructor; mixing both forms is a TypeError."""
    import warnings
    from repro.configs import get_arch, smoke_config
    from repro.models import Runtime, build_model
    from repro.serving.engine import ServeEngine
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 remat="none", page_size=8, capacity_factor=100.0)
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, rt)
    params = m.init(jax.random.key(0))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        e1 = ServeEngine(m, params, n_slots=2, max_ctx=32, macro_k=4)
    assert sum(issubclass(x.category, DeprecationWarning)
               for x in w) == 1
    sc = ServeConfig(n_slots=2, max_ctx=32, macro_k=4)
    e2 = ServeEngine(m, params, config=sc)
    assert e1.config == sc == e2.config
    with pytest.raises(TypeError, match="not both"):
        ServeEngine(m, params, config=sc, n_slots=2)
    # bit-equivalent serving behavior, not just equal configs
    toks = list(range(1, 18))
    r1 = e1.submit(toks, max_new=5)
    r2 = e2.submit(toks, max_new=5)
    assert e1.run()[r1] == e2.run()[r2]


# ---------------------------------------------------------------------
# counters registry + typed map stats
# ---------------------------------------------------------------------
def test_counters_registry_semantics():
    reg = Counters()
    a = reg.cell("x.a")
    assert a is reg.cell("x.a")          # one cell per name
    a[0] += 3
    reg.cell("x.b")[0] = 2
    snap = reg.snapshot()
    assert snap == {"x.a": 3, "x.b": 2}
    a[0] += 1
    assert reg.delta(snap) == {"x.a": 1, "x.b": 0}
    reg.reset("x.a")
    assert a[0] == 0 and reg.cell("x.b")[0] == 2   # alias still live
    reg.reset()
    assert reg.snapshot() == {"x.a": 0, "x.b": 0}


def test_legacy_counter_names_alias_registry_cells():
    """The historical module-level counters must BE the registry cells
    (same list object), so `NAME[0] += 1` call sites and
    COUNTERS.snapshot() can never diverge."""
    from repro.serving import engine as E
    assert KM.XLATE_CALLS is COUNTERS.cell("kvm.xlate_calls")
    assert KM.FULL_TABLE_CALLS is COUNTERS.cell("kvm.full_table_calls")
    assert KM.ALLOC_SYNCS is COUNTERS.cell("kvm.alloc_syncs")
    assert B.PROBE_TRACES is COUNTERS.cell("fmmu.probe_traces")
    assert B.INSERT_TRACES is COUNTERS.cell("fmmu.insert_traces")
    assert E.MACRO_DISPATCHES is COUNTERS.cell("engine.macro_dispatches")
    assert E.HOST_SYNCS is COUNTERS.cell("engine.host_syncs")


def test_map_stats_typed_access():
    kvm = _kvm(2)
    kvm.new_seq(0, 3)
    st = kvm.hit_stats()
    assert isinstance(st, MapStats)
    assert st["updates"] == st.updates           # legacy indexing
    assert "gc_moves" in st and "nope" not in st
    with pytest.raises(KeyError):
        st["nope"]
    d = st.as_dict()
    assert d["victims_ch"] == [0, 0]
    assert d["write_amp"] >= 1.0
    assert d["flash_programs"] == d["host_writes"] + d["swaps_in"] \
        + d["gc_moves"] + d["cow_moves"]


def test_prefetch_segments_frontier_semantics():
    """CTP prefetch (ISSUE 9): the first crossing into a segment
    dispatches ONE fused LOOKUP and counts the fill in the hit/miss
    delta; re-prefetching the same frontier is a host-side no-op (no
    dispatch at all) — the per-boundary dispatch tax is exactly what
    the GC-retention acceptance forbids."""
    kvm = _kvm(1)
    ent = kvm.geom.cmt_entries
    dl = np.arange(2 * ent)              # spans exactly two segments
    x0 = KM.XLATE_CALLS[0]
    n = kvm.prefetch_segments(dl)
    assert n == 2                        # one representative per segment
    assert KM.XLATE_CALLS[0] - x0 == 1   # one fused dispatch, batched
    st = kvm.hit_stats()
    assert st.prefetch_hits + st.prefetch_misses == 2
    assert st.prefetch_misses == 2       # cold map: both fills useful
    # same frontier again: filtered on host, zero dispatches
    assert kvm.prefetch_segments(dl) == 0
    assert KM.XLATE_CALLS[0] - x0 == 1
    # the frontier advancing into a NEW segment dispatches again, for
    # only the unseen segment
    assert kvm.prefetch_segments(np.arange(3 * ent)) == 1
    assert KM.XLATE_CALLS[0] - x0 == 2
    # reset clears the frontier with the rest of the bookkeeping
    kvm.reset()
    assert kvm.prefetch_segments(dl) == 2


@pytest.mark.parametrize("C", CHANNELS)
def test_prefetch_frontier_invalidated_on_slot_reuse(C):
    """Regression (ISSUE 10): PR 9's frontier filter assumed growth
    dlpns advance monotonically — true within one sequence's life,
    false across slot REUSE, which restarts growth through the same
    dlpn range. `free_seq` never dropped the freed slot's (channel,
    segment) keys from `_pf_seen`, so the next occupant's prefetches
    were silently filtered as already-seen and every segment fill was
    paid as an in-scan miss instead. prefetch→admit→drain/free→
    re-prefetch for the reused slot must dispatch and MISS again.
    Pre-fix, the second prefetch was a host-side no-op (returned 0, no
    dispatch, no miss) and this test fails."""
    kvm = _kvm(C)
    dl = np.asarray(kvm._dlpns(0, range(4)))
    # boundary order mirrors the engine: prefetch from the pre-commit's
    # growth schedule BEFORE the growth UPDATE commits
    assert kvm.prefetch_segments(dl) > 0
    m0 = kvm.prefetch_misses
    assert m0 > 0                        # cold map: the fills were useful
    kvm.new_seq(0, 4)                    # admit ...
    kvm.free_seq(0)                      # ... drain: slot goes back
    # a real workload re-cools the segments via CMT eviction churn;
    # emulate that deterministically — the CMT is write-through, so
    # flushing the valid bits loses nothing
    fm = kvm.state.fmmu
    kvm.state = kvm.state._replace(
        fmmu=fm._replace(valid=jnp.zeros_like(fm.valid)))
    x0 = KM.XLATE_CALLS[0]
    assert kvm.prefetch_segments(dl) > 0     # NOT filtered (the fix)
    assert KM.XLATE_CALLS[0] - x0 == 1       # one fused dispatch
    assert kvm.prefetch_misses > m0          # and it did useful work
    kvm.new_seq(0, 4)                        # reused slot admits cleanly


# ---------------------------------------------------------------------
# bugfix audit (ISSUE 10): GC victim walk vs swap-pending slots
# ---------------------------------------------------------------------
@pytest.mark.parametrize("C", CHANNELS)
def test_gc_victim_excludes_swap_pending_slot(C):
    """A victim erase block must never hold pages of a swap-pending
    slot while the swap's host commit is in flight. The audit's answer
    is BY CONSTRUCTION, pinned here as the exact interleaving: (1)
    `_swap` commits host truth atomically — map re-point, pool
    free/alloc, page lists — before returning, and GC only ever runs
    between commits, so a "mid-swap" walk cannot exist on the host
    side; (2) a swapped slot's pages carry HOST_BASE tags, which never
    enter the walk's reverse map (gc_collect skips host blocks) and
    can never be picked (`pool.erase_blocks` groups device frames
    only); (3) the swap's not-yet-executed DEVICE copy is ordered
    before any reuse of its freed source frames by dispatch order, so
    even a walk racing the in-flight copy reads/writes consistent
    rows. Interleaving: swap OUT dispatched non-blocking (check=False,
    the serving scheduler's mode — the device work is still in flight
    when the walk starts) -> GC walk -> swap back IN; the pending
    slot's mapping must be untouched by the walk and fully readable
    after resume."""
    kvm = _kvm(C, n_dev=32, n_host=16)
    rng = random.Random(11 + C)
    width = kvm.pool.n_device + kvm.pool.n_host + 1
    pools = [jnp.arange(width * 4.0).reshape(width, 4)]
    _fragment(kvm, rng)
    if 0 not in kvm.seq_pages:
        kvm.new_seq(0, 4)
    rows_before = [np.asarray(pools[0][b]) for b in kvm.seq_pages[0]]
    pools, moved = kvm.swap_out(0, pools, check=False)  # in flight
    assert moved > 0
    pending = list(kvm.seq_pages[0])
    assert all(kvm.pool.is_host(b) for b in pending)
    mapping0 = {s: list(p) for s, p in kvm.seq_pages.items()}
    pools, moved_pages, _ = kvm.gc_collect(pools, block_axis=0,
                                           block_pages=4, budget=64)
    # the walk never touched the swap-pending slot: same host blocks,
    # and no victim frame aliased into its mapping
    assert kvm.seq_pages[0] == pending
    assert all(kvm.pool.is_host(b) for b in kvm.seq_pages[0])
    # the walk is otherwise live: lane counts still match the oracle
    # and every surviving mapping reads through the table
    np.testing.assert_array_equal(kvm.live_counts(), _oracle_live(kvm))
    tab = np.asarray(kvm.block_tables())
    for s, pages in kvm.seq_pages.items():
        assert len(pages) == len(mapping0[s])
        assert list(tab[s, :len(pages)]) == pages
    # resume: the swap back in lands on device rows that still carry
    # the slot's data (the defrag walk could not have recycled them)
    pools, back = kvm.swap_in(0, pools, check=True)
    assert back == moved
    assert kvm.is_resident(0)
    rows_after = [np.asarray(pools[0][b]) for b in kvm.seq_pages[0]]
    for r0, r1 in zip(rows_before, rows_after):
        np.testing.assert_array_equal(r0, r1)
