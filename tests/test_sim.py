"""SSD simulator behaviour tests: bottleneck identities, scheme
ordering, GC invariants, write backpressure."""
import dataclasses

import pytest

from repro.configs.fmmu_paper import PAPER_SSD
from repro.core.sim.ssd import SSDSim
from repro.core.sim import workloads as W


def small_cfg(**kw):
    base = dict(capacity_gb=1, channels=4, ways=2)
    base.update(kw)
    return dataclasses.replace(PAPER_SSD, **base)


def run(scheme, cores=1, wl=W.rand_read_4k, cmds=4000, cfg=None, **kw):
    cfg = cfg or small_cfg()
    sim = SSDSim(cfg, scheme=scheme, n_cores=cores, **kw)
    sim.precondition_sequential()
    res = sim.run_closed_loop(wl(cfg), cmds, outstanding=128)
    return sim, res


def test_ideal_randread_bus_or_chip_bound():
    _, r = run("ideal")
    assert max(r["util_bus"], r["util_chip"]) > 0.85
    assert r["util_ftl"] == 0.0


def test_scheme_ordering_randread():
    """ideal >= fmmu > dftl-1c ; 4-core recovers most of the loss."""
    _, ideal = run("ideal")
    _, fmmu = run("fmmu")
    _, d1 = run("dftl", 1)
    _, d4 = run("dftl", 4)
    _, c1 = run("cdftl", 1)
    assert fmmu["iops"] >= 0.97 * ideal["iops"]
    assert d1["iops"] < fmmu["iops"]
    assert c1["iops"] < d1["iops"]          # CDFTL 1-core slowest (Fig 11d)
    assert d4["iops"] > d1["iops"]


def test_fmmu_not_bottleneck_fig14_style():
    cfg = small_cfg(channels=8, ways=4, host_bw_gbps=31.52)
    _, r = run("fmmu", cfg=cfg, cmds=6000)
    assert r["util_ftl"] < 0.9
    assert max(r["util_bus"], r["util_chip"]) > r["util_ftl"]


def test_write_gc_invariants():
    cfg = small_cfg()
    sim, r = run("fmmu", wl=W.rand_write_4k, cmds=12000, cfg=cfg)
    assert r["stats"]["erases"] > 0, "GC never ran"
    # physical consistency: every mapped dlpn's rmap inverts correctly
    import numpy as np
    mapped = np.nonzero(sim.map >= 0)[0]
    assert len(mapped) == sim.n_pages_logical
    dppns = sim.map[mapped]
    assert len(np.unique(dppns)) == len(dppns), "double-mapped dppn"
    assert (sim.rmap[dppns] == mapped).all()
    # valid counts consistent
    vc = np.bincount(dppns // sim.ppb, minlength=sim.n_blocks)
    assert (vc == sim.valid).all()
    assert sim.free_pages >= 0


def test_write_backpressure_no_oom():
    """Sustained random overwrite far beyond OP must not crash."""
    run("ideal", wl=W.rand_write_4k, cmds=20000)


def test_seq_read_faster_than_rand_read():
    _, seq = run("ideal", wl=W.seq_read_64k, cmds=1500)
    _, rnd = run("ideal", wl=W.rand_read_4k, cmds=1500)
    assert seq["gbps"] > rnd["gbps"]


def test_tp_read_merging_shared():
    """Concurrent misses on one TVPN produce one in-flight TP read."""
    cfg = small_cfg()
    sim = SSDSim(cfg, scheme="fmmu")
    sim.precondition_sequential()
    got = []
    for i in range(16):   # same translation page, different blocks
        sim.read_page(i * cfg.cmt_block_entries, 4096,
                      lambda: got.append(1))
    sim.ev.run()
    assert len(got) == 16
    assert sim.stats["tp_reads"] <= 2


def test_trace_surrogates_run_all_schemes():
    cfg = small_cfg()
    for spec in W.TRACES.values():
        for scheme in ("ideal", "fmmu", "dftl", "cdftl"):
            sim = SSDSim(cfg, scheme=scheme)
            sim.precondition_sequential()
            r = sim.run_closed_loop(W.trace_surrogate(cfg, spec), 800)
            assert r["cmds"] == 800
            assert r["elapsed_us"] > 0
