"""End-to-end system sanity (extended by test_training / test_serving)."""
from repro.configs import ARCHS, SHAPES, all_cells


def test_registry_complete():
    assert len(ARCHS) == 10
    assert len(SHAPES) == 4
    assert len(all_cells()) == 40
