"""Shared lockstep driver: run oracle and JAX engine step-by-step on the
same trace, comparing full architectural state each step. Used by
tests/test_fmmu_engine.py and debugging sessions."""
import functools
import random

import jax

from repro.core.fmmu import engine as E
from repro.core.fmmu.oracle import FMMUOracle
from repro.core.fmmu.state import F_DIRTY, F_REF, F_TRANS, F_VALID
from repro.core.fmmu.types import (COND_UPDATE, LOOKUP, NIL, Request,
                                   UPDATE, small_geometry)


def lockstep(seed, n_reqs=300, max_steps=40000, geom_kw=None,
             deep_compare=True):
    kw = dict(queue_cap=2048)
    kw.update(geom_kw or {})
    g = small_geometry(**kw)
    o = FMMUOracle(g)
    eng = E.FMMUEngine(g)
    step_jit = jax.jit(functools.partial(E.step, g))
    rng = random.Random(seed)
    n_pages = g.n_tvpns * g.entries_per_tp
    o_cum = [0]
    all_oresp, all_eresp = [], []

    def oracle_flags(blk):
        return ((F_VALID * blk.valid) | (F_DIRTY * blk.dirty)
                | (F_TRANS * blk.transient) | (F_REF * blk.refbit))

    def compare(tag):
        st = eng.state
        for s in range(g.cmt_sets):
            for w in range(g.cmt_ways):
                blk = o.cmt[s][w]
                ef, of = int(st.cmt_flags[s, w]), oracle_flags(blk)
                if ef != of:
                    return f'{tag} cmt flags {s},{w}: eng={ef} orc={of}'
                if blk.valid and list(map(int, st.cmt_data[s, w])) != blk.data:
                    return f'{tag} cmt data {s},{w}'
                if blk.dirty and int(st.cmt_next[s, w]) != blk.next:
                    return f'{tag} cmt next {s},{w}'
        for s in range(g.ctp_sets):
            for w in range(g.ctp_ways):
                blk = o.ctp[s][w]
                ef, of = int(st.ctp_flags[s, w]), oracle_flags(blk)
                if ef != of:
                    return f'{tag} ctp flags {s},{w}: eng={ef} orc={of}'
                if blk.valid and list(map(int, st.ctp_data[s, w])) != blk.data:
                    return f'{tag} ctp data {s},{w}'
        qe = [int(x) for x in (st.qtail - st.qhead)]
        qo = [len(q) for q in o.queues]
        if qe != qo:
            return f'{tag} qlens {qe} vs {qo}'
        if [int(x) for x in st.credits] != o.credits:
            return f'{tag} credits'
        if int(st.resp_n) != o_cum[0] + len(o.out_resps):
            return f'{tag} resp {int(st.resp_n)} vs {o_cum[0] + len(o.out_resps)}'
        if int(st.tppn_next) != o.tppn_next:
            return f'{tag} tppn_next'
        if [int(x) for x in st.gtd] != o.gtd:
            return f'{tag} gtd'
        return None

    rid = 0
    for _ in range(n_reqs):
        dlpn = rng.randrange(n_pages)
        kind = rng.choice([LOOKUP, UPDATE, UPDATE, COND_UPDATE])
        d = rng.randrange(10 ** 6)
        old = rng.randrange(10 ** 6) if rng.random() < 0.5 else NIL
        r = Request(kind, dlpn, dppn=d, old_dppn=old, req_id=rid,
                    src=1 if kind == COND_UPDATE else 0)
        o.push_request(r)
        eng.push_request(r)
        rid += 1

    for stepno in range(max_steps):
        ocode = o.step()
        eng.state, ecode = step_jit(eng.state)
        omap = {o.WORKED: 0, o.IDLE: 1, o.BLOCKED: 2}
        if omap[ocode] != int(ecode):
            return f'step {stepno}: code orc={ocode} eng={int(ecode)}'
        if deep_compare:
            d = compare(f'step {stepno}')
            if d:
                return 'DIVERGE: ' + d
        if ocode != o.WORKED:
            ro, fo, po = o.drain_outputs()
            re_, fe, pe = eng.drain_outputs()
            o_cum[0] += len(ro)
            all_oresp += [(r_.req_id, r_.dppn, r_.status) for r_ in ro]
            all_eresp += [(r_.req_id, r_.dppn, r_.status) for r_ in re_]
            fe = [tuple(x) for x in fe]
            if fo != fe:
                return f'fc mismatch {fo} vs {fe}'
            if [tuple(x) for x in pe] != po:
                return 'prog mismatch'
            if not fo and not o.pending_work():
                break
            order = list(fo)
            rng.shuffle(order)
            for t, s, w in order:
                o.push_flash_response(t, s, w)
                eng.push_flash_response(t, s, w)
    if all_oresp != all_eresp:
        return f'resp stream mismatch ({len(all_oresp)} vs {len(all_eresp)})'
    est = eng.stats()
    ost = {k: v for k, v in o.stats.items()}
    if est != ost:
        return f'stats mismatch {ost} vs {est}'
    return f'OK:{len(all_oresp)}'


if __name__ == '__main__':
    import sys
    sys.path.insert(0, 'src')
    for seed in range(3):
        print(seed, lockstep(seed))
    print('tiny-mshr', lockstep(7, geom_kw=dict(mshr_cap=2, ctp_mshr_cap=2)))
    print('1-way    ', lockstep(8, geom_kw=dict(cmt_ways=1, ctp_ways=1)))
