"""Shared lockstep drivers.

``lockstep``       — run oracle and JAX packet engine step-by-step on the
                     same trace, comparing full architectural state each
                     step. Used by tests/test_fmmu_engine.py.
``batch_lockstep`` — drive the fused mixed-op ``translate_batch`` against
                     a shadow-dict oracle and (optionally) against the
                     unfused three-call sequence, asserting bit-identical
                     state + outputs. Used by tests/test_fmmu_batch.py.
"""
import functools
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fmmu import batch as FB
from repro.core.fmmu import engine as E
from repro.core.fmmu.oracle import FMMUOracle
from repro.core.fmmu.state import F_DIRTY, F_REF, F_TRANS, F_VALID
from repro.core.fmmu.types import (COND_UPDATE, LOOKUP, NIL, Request,
                                   UPDATE, small_geometry)


def lockstep(seed, n_reqs=300, max_steps=40000, geom_kw=None,
             deep_compare=True):
    kw = dict(queue_cap=2048)
    kw.update(geom_kw or {})
    g = small_geometry(**kw)
    o = FMMUOracle(g)
    eng = E.FMMUEngine(g)
    step_jit = jax.jit(functools.partial(E.step, g))
    rng = random.Random(seed)
    n_pages = g.n_tvpns * g.entries_per_tp
    o_cum = [0]
    all_oresp, all_eresp = [], []

    def oracle_flags(blk):
        return ((F_VALID * blk.valid) | (F_DIRTY * blk.dirty)
                | (F_TRANS * blk.transient) | (F_REF * blk.refbit))

    def compare(tag):
        st = eng.state
        for s in range(g.cmt_sets):
            for w in range(g.cmt_ways):
                blk = o.cmt[s][w]
                ef, of = int(st.cmt_flags[s, w]), oracle_flags(blk)
                if ef != of:
                    return f'{tag} cmt flags {s},{w}: eng={ef} orc={of}'
                if blk.valid and list(map(int, st.cmt_data[s, w])) != blk.data:
                    return f'{tag} cmt data {s},{w}'
                if blk.dirty and int(st.cmt_next[s, w]) != blk.next:
                    return f'{tag} cmt next {s},{w}'
        for s in range(g.ctp_sets):
            for w in range(g.ctp_ways):
                blk = o.ctp[s][w]
                ef, of = int(st.ctp_flags[s, w]), oracle_flags(blk)
                if ef != of:
                    return f'{tag} ctp flags {s},{w}: eng={ef} orc={of}'
                if blk.valid and list(map(int, st.ctp_data[s, w])) != blk.data:
                    return f'{tag} ctp data {s},{w}'
        qe = [int(x) for x in (st.qtail - st.qhead)]
        qo = [len(q) for q in o.queues]
        if qe != qo:
            return f'{tag} qlens {qe} vs {qo}'
        if [int(x) for x in st.credits] != o.credits:
            return f'{tag} credits'
        if int(st.resp_n) != o_cum[0] + len(o.out_resps):
            return f'{tag} resp {int(st.resp_n)} vs {o_cum[0] + len(o.out_resps)}'
        if int(st.tppn_next) != o.tppn_next:
            return f'{tag} tppn_next'
        if [int(x) for x in st.gtd] != o.gtd:
            return f'{tag} gtd'
        return None

    rid = 0
    for _ in range(n_reqs):
        dlpn = rng.randrange(n_pages)
        kind = rng.choice([LOOKUP, UPDATE, UPDATE, COND_UPDATE])
        d = rng.randrange(10 ** 6)
        old = rng.randrange(10 ** 6) if rng.random() < 0.5 else NIL
        r = Request(kind, dlpn, dppn=d, old_dppn=old, req_id=rid,
                    src=1 if kind == COND_UPDATE else 0)
        o.push_request(r)
        eng.push_request(r)
        rid += 1

    for stepno in range(max_steps):
        ocode = o.step()
        eng.state, ecode = step_jit(eng.state)
        omap = {o.WORKED: 0, o.IDLE: 1, o.BLOCKED: 2}
        if omap[ocode] != int(ecode):
            return f'step {stepno}: code orc={ocode} eng={int(ecode)}'
        if deep_compare:
            d = compare(f'step {stepno}')
            if d:
                return 'DIVERGE: ' + d
        if ocode != o.WORKED:
            ro, fo, po = o.drain_outputs()
            re_, fe, pe = eng.drain_outputs()
            o_cum[0] += len(ro)
            all_oresp += [(r_.req_id, r_.dppn, r_.status) for r_ in ro]
            all_eresp += [(r_.req_id, r_.dppn, r_.status) for r_ in re_]
            fe = [tuple(x) for x in fe]
            if fo != fe:
                return f'fc mismatch {fo} vs {fe}'
            if [tuple(x) for x in pe] != po:
                return 'prog mismatch'
            if not fo and not o.pending_work():
                break
            order = list(fo)
            rng.shuffle(order)
            for t, s, w in order:
                o.push_flash_response(t, s, w)
                eng.push_flash_response(t, s, w)
    if all_oresp != all_eresp:
        return f'resp stream mismatch ({len(all_oresp)} vs {len(all_eresp)})'
    est = eng.stats()
    ost = {k: v for k, v in o.stats.items()}
    if est != ost:
        return f'stats mismatch {ost} vs {est}'
    return f'OK:{len(all_oresp)}'


def _split_order_sensitive(g, st, batch):
    """True when splitting `batch` into the unfused three-call sequence
    is allowed to diverge (bitwise) from the fused single pass:

      * more than W distinct new blocks land in one set — the unfused
        split wraps the insertion clock across its separate insert
        passes while the fused pass drops rank >= W;
      * an earlier pass's insert evicts a cached block that a later
        pass still probes (UPDATE lanes probe after the LOOKUP pass's
        inserts; COND lanes probe after everyone's, including the COND
        pass's own internal lookup-insert), legally flipping that
        lane's hit to a miss.

    The fused path defines mixed-batch semantics as "all probes see the
    pre-batch state"; this predicate delimits exactly the batches where
    the unfused sequence agrees.
    """
    e, s_cnt, w_cnt = g.cmt_entries, g.cmt_sets, g.cmt_ways
    tags, valid = np.asarray(st.tags), np.asarray(st.valid)
    cached = set(tags[valid].tolist())
    new_by_grp = {LOOKUP: set(), UPDATE: set(), COND_UPDATE: set()}
    for k, d in batch:
        b = d // e
        if b not in cached:
            new_by_grp[k].add(b)
    per_set = {}
    for b in set().union(*new_by_grp.values()):
        per_set.setdefault(b % s_cnt, set()).add(b)
    if any(len(v) > w_cnt for v in per_set.values()):
        return True
    ins_l = {b % s_cnt for b in new_by_grp[LOOKUP]}
    ins_all = (ins_l | {b % s_cnt for b in new_by_grp[UPDATE]}
               | {b % s_cnt for b in new_by_grp[COND_UPDATE]})
    for k, d in batch:
        b = d // e
        if b in cached:
            s = b % s_cnt
            if ((k == UPDATE and s in ins_l)
                    or (k == COND_UPDATE and s in ins_all)):
                return True
    return False


def batch_lockstep(seed, n_batches=60, geom_kw=None, overflow=False):
    """Drive the fused translate_batch on random mixed-op batches.

    overflow=False: batches are constrained so the unfused three-call
      split is defined to be bit-identical (COND blocks disjoint from
      LOOKUP/UPDATE blocks — the unfused split probes COND lanes *after*
      the earlier passes' inserts, so shared blocks would legally flip a
      miss to a hit — and at most W distinct new blocks per set, since
      the unfused split wraps the clock across its separate insert
      passes). Compares fused vs unfused state bit-for-bit AND both
      against a shadow dict.
    overflow=True: unconstrained batches (duplicate blocks in one batch,
      >W distinct new blocks per set, duplicate read dlpns). Checks
      shadow-dict semantics and the cache/backing write-through
      coherence invariant only.

    Returns 'OK:<n_lanes>' or a divergence description.
    """
    kw = dict(cmt_sets=8, cmt_ways=4)
    kw.update(geom_kw or {})
    g = small_geometry(**kw)
    rng = random.Random(seed)
    nprng = np.random.RandomState(seed)
    n_pages = g.n_tvpns * g.entries_per_tp
    n_blocks = n_pages // g.cmt_entries
    stf = FB.init_batch_state(g)
    stu = FB.init_batch_state(g)
    shadow = {}
    lanes_done = 0

    def gen_lanes(block_pool, kind, max_blocks=3):
        blks = nprng.choice(block_pool, rng.randint(1, max_blocks),
                            replace=False)
        dl = []
        for b in blks:
            for _ in range(rng.randint(1, 3)):
                dl.append(int(b) * g.cmt_entries
                          + rng.randrange(g.cmt_entries))
        return [(kind, d) for d in dict.fromkeys(dl)]

    for it in range(n_batches):
        if overflow:
            pool = np.arange(n_blocks)
            batch = (gen_lanes(pool, LOOKUP, 4) + gen_lanes(pool, UPDATE, 4)
                     + gen_lanes(pool, COND_UPDATE, 4))
            # dedup write dlpns only (duplicate reads stay): duplicate
            # writes to one dlpn in one batch are a caller contract
            # violation, duplicate blocks are the point of this mode
            seen_w, dedup = set(), []
            for k, d in batch:
                if k != LOOKUP:
                    if d in seen_w:
                        continue
                    seen_w.add(d)
                dedup.append((k, d))
            batch = dedup
        else:
            lo = np.arange(0, 2 * n_blocks // 3)
            hi = np.arange(2 * n_blocks // 3, n_blocks)
            batch = (gen_lanes(lo, LOOKUP) + gen_lanes(lo, UPDATE)
                     + gen_lanes(hi, COND_UPDATE))
        rng.shuffle(batch)
        if not overflow and _split_order_sensitive(g, stf, batch):
            continue
        kinds = np.array([k for k, _ in batch], np.int32)
        dls = np.array([d for _, d in batch], np.int32)
        dps = nprng.randint(0, 10 ** 6, len(batch)).astype(np.int32)
        olds = np.array([shadow.get(int(d), NIL) if rng.random() < .6
                         else rng.randrange(10 ** 6) for d in dls],
                        np.int32)
        stf, out, ok = FB.translate_batch(
            g, stf, jnp.array(kinds), jnp.array(dls), jnp.array(dps),
            jnp.array(olds))
        out, ok = np.asarray(out), np.asarray(ok)
        # --- shadow-dict semantics: reads pre-batch, writes post-batch
        for i, (k, d) in enumerate(batch):
            want = shadow.get(d, NIL)
            if out[i] != want:
                return (f'batch {it} lane {i}: out {out[i]} != shadow '
                        f'{want} (kind {k} dlpn {d})')
            if k == COND_UPDATE and bool(ok[i]) != (want == olds[i]):
                return f'batch {it} lane {i}: ok mismatch'
        for i, (k, d) in enumerate(batch):
            if k == UPDATE or (k == COND_UPDATE and ok[i]):
                shadow[d] = int(dps[i])
        # --- write-through coherence: cached blocks mirror backing
        tags, valid, data, backing = (np.asarray(stf.tags),
                                      np.asarray(stf.valid),
                                      np.asarray(stf.data),
                                      np.asarray(stf.backing))
        for s, w in zip(*np.nonzero(valid)):
            b = tags[s, w]
            seg = backing[b * g.cmt_entries:(b + 1) * g.cmt_entries]
            if (data[s, w] != seg).any():
                return f'batch {it}: cache/backing divergence set {s} way {w}'
        # --- unfused three-call split must be bit-identical
        if not overflow:
            ml, mu, mc = (kinds == LOOKUP), (kinds == UPDATE), \
                (kinds == COND_UPDATE)
            if ml.any():
                stu, ou = FB.lookup_batch_unfused(g, stu, jnp.array(dls[ml]))
                if (np.asarray(ou) != out[ml]).any():
                    return f'batch {it}: lookup out fused != unfused'
            if mu.any():
                stu = FB.update_batch_unfused(g, stu, jnp.array(dls[mu]),
                                              jnp.array(dps[mu]))
            if mc.any():
                stu, oku = FB.cond_update_batch_unfused(
                    g, stu, jnp.array(dls[mc]), jnp.array(dps[mc]),
                    jnp.array(olds[mc]))
                if (np.asarray(oku) != ok[mc]).any():
                    return f'batch {it}: cond ok fused != unfused'
            for f, xf, xu in zip(stf._fields, stf, stu):
                if (np.asarray(xf) != np.asarray(xu)).any():
                    return f'batch {it}: state field {f} fused != unfused'
        lanes_done += len(batch)
    return f'OK:{lanes_done}'


def sharded_geometries(n_channels, **kw):
    """(single-device, per-channel) geometry pair covering the same
    global dlpn space: the local shard owns ceil(n_pages / C) pages."""
    base = dict(cmt_sets=8, cmt_ways=4)
    base.update(kw)
    g1 = small_geometry(**base)
    n_pages = g1.n_tvpns * g1.entries_per_tp
    loc = dict(base)
    loc["n_tvpns"] = max(1, -(-(-(-n_pages // n_channels))
                             // g1.entries_per_tp))
    return g1, small_geometry(**loc)


def sharded_lockstep(seed, n_channels, n_batches=40, geom_kw=None,
                     table_every=5):
    """ISSUE-5 oracle sweep: drive the channel-sharded translate and
    the single-device serving path on IDENTICAL random mixed-op
    batches (unconstrained: duplicate cache blocks, > W distinct new
    blocks per set, duplicate read dlpns, inactive lanes — write
    dlpns dedup'd per the caller contract) and assert

      * per-lane outputs and CondUpdate ok masks bit-identical,
      * the materialized sharded table bit-identical to the
        single-device incremental table (every `table_every` batches
        and at the end),
      * both against the shadow dict (reads pre-batch, writes post).

    The per-channel CMT geometry is 1/C-sized, so cache *internals*
    legitimately differ — the contract is the architectural mapping
    state, which is what the serving layer consumes.
    Returns 'OK:<n_lanes>' or a divergence description."""
    C = n_channels
    g1, gC = sharded_geometries(C, **(geom_kw or {}))
    n_pages = g1.n_tvpns * g1.entries_per_tp
    n_blocks = n_pages // g1.cmt_entries
    rng = random.Random(seed)
    nprng = np.random.RandomState(seed)
    ms1 = FB.init_serving_state(g1)
    msC = FB.init_sharded_state(gC, C)
    shadow = {}
    lanes_done = 0

    def gen_lanes(kind, max_blocks=4):
        blks = nprng.choice(np.arange(n_blocks),
                            rng.randint(1, max_blocks), replace=False)
        dl = []
        for b in blks:
            for _ in range(rng.randint(1, 3)):
                dl.append(int(b) * g1.cmt_entries
                          + rng.randrange(g1.cmt_entries))
        return [(kind, d) for d in dict.fromkeys(dl)]

    for it in range(n_batches):
        batch = (gen_lanes(LOOKUP) + gen_lanes(UPDATE)
                 + gen_lanes(COND_UPDATE))
        seen_w, dedup = set(), []
        for k, d in batch:
            if k != LOOKUP:
                if d in seen_w:
                    continue
                seen_w.add(d)
            dedup.append((k, d))
        batch = dedup
        rng.shuffle(batch)
        if rng.random() < 0.3:
            batch.append((LOOKUP, -1))          # inactive lane
        # pad to a fixed lane width (inactive lanes are no-ops in both
        # paths): one trace per geometry instead of one per batch size
        batch = batch[:40] + [(LOOKUP, -1)] * (40 - len(batch))
        kinds = np.array([k for k, _ in batch], np.int32)
        dls = np.array([d for _, d in batch], np.int32)
        dps = nprng.randint(0, 10 ** 6, len(batch)).astype(np.int32)
        olds = np.array([shadow.get(int(d), NIL) if rng.random() < .6
                         else rng.randrange(10 ** 6) for d in dls],
                        np.int32)
        ms1, out1, ok1 = FB.translate_serving(
            g1, ms1, jnp.array(kinds), jnp.array(dls), jnp.array(dps),
            jnp.array(olds))
        msC, outC, okC = FB.translate_sharded(
            gC, C, msC, jnp.array(kinds), jnp.array(dls),
            jnp.array(dps), jnp.array(olds))
        out1, ok1 = np.asarray(out1), np.asarray(ok1)
        outC, okC = np.asarray(outC), np.asarray(okC)
        if (out1 != outC).any():
            i = int(np.nonzero(out1 != outC)[0][0])
            return (f'batch {it} lane {i}: sharded out {outC[i]} != '
                    f'single {out1[i]} (kind {kinds[i]} dlpn {dls[i]})')
        if (ok1 != okC).any():
            return f'batch {it}: ok mask sharded != single'
        for i, (k, d) in enumerate(batch):
            if d < 0:
                continue
            want = shadow.get(d, NIL)
            if out1[i] != want:
                return (f'batch {it} lane {i}: out {out1[i]} != shadow '
                        f'{want}')
            if k == COND_UPDATE and bool(ok1[i]) != (want == olds[i]):
                return f'batch {it} lane {i}: ok mismatch vs shadow'
        for i, (k, d) in enumerate(batch):
            if d >= 0 and (k == UPDATE or (k == COND_UPDATE and ok1[i])):
                shadow[d] = int(dps[i])
        if it % table_every == table_every - 1:
            t1 = np.asarray(ms1.table[:n_pages])
            tC = np.asarray(FB.dense_table(msC, C, n_pages))
            if (t1 != tC).any():
                d = int(np.nonzero(t1 != tC)[0][0])
                return (f'batch {it}: table diverged at dlpn {d} '
                        f'(single {t1[d]} sharded {tC[d]})')
        lanes_done += len(batch)
    t1 = np.asarray(ms1.table[:n_pages])
    tC = np.asarray(FB.dense_table(msC, C, n_pages))
    if (t1 != tC).any():
        return 'final table divergence'
    return f'OK:{lanes_done}'


if __name__ == '__main__':
    import sys
    sys.path.insert(0, 'src')
    for seed in range(3):
        print(seed, lockstep(seed))
    print('tiny-mshr', lockstep(7, geom_kw=dict(mshr_cap=2, ctp_mshr_cap=2)))
    print('1-way    ', lockstep(8, geom_kw=dict(cmt_ways=1, ctp_ways=1)))
    for seed in range(3):
        print('batch', seed, batch_lockstep(seed))
        print('batch-ovf', seed, batch_lockstep(seed, overflow=True))
    print('batch-1way', batch_lockstep(9, geom_kw=dict(cmt_ways=1)))
    for C in (1, 2, 4, 8):
        print(f'sharded-C{C}', sharded_lockstep(5, C))
