"""Import shim for hypothesis: the real package when installed, else a
stub that REPLAYS explicit ``@example`` cases (some containers ship no
hypothesis wheel and nothing may be pip-installed there). Seeded
randomized loops in the same test modules keep broad coverage in that
case; the explicit examples carry the pinned regression seeds from
earlier PRs, which previously vanished with the skip — a property test
with ``@example`` decorators now runs exactly those cases instead of
skipping outright (tests with no examples still skip).

Decorator order matches real hypothesis: ``@example`` stacks OUTSIDE
``@given``::

    @example([(True, [3], 7)])          # pinned regression case
    @settings(max_examples=25)
    @given(st.lists(...))
    def test_prop(ops): ...
"""
try:
    from hypothesis import (example, given, settings,  # noqa: F401
                            strategies as st)
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    given, settings, st, example = None, None, None, None

if not HAVE_HYPOTHESIS:
    import pytest

    class _Strategy:
        """Absorbs any strategy-construction call chain."""

        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _Strategy()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            # zero-arg replacement: pytest must not mistake the wrapped
            # test's hypothesis parameters for fixtures. @example
            # decorators applied outside this wrapper append to
            # _examples; the runner replays them (regression seeds stay
            # live in no-wheel containers) and only skips when none
            # were pinned.
            def runner():
                if not runner._examples:
                    pytest.skip(
                        "hypothesis not installed in this environment")
                for args, kwargs in runner._examples:
                    runner._inner(*args, **kwargs)

            runner._inner = fn
            runner._examples = []
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    def example(*args, **kwargs):
        def deco(fn):
            # applied above @given: fn is the runner; register on it.
            # (Applied below @given — unusual but legal — there is
            # nothing to replay through, so ignore silently, matching
            # the old behavior rather than erroring.)
            if hasattr(fn, "_examples"):
                fn._examples.append((args, kwargs))
            return fn
        return deco
