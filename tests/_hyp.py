"""Import shim for hypothesis: the real package when installed, else a
stub that marks property tests as skipped (some containers ship no
hypothesis wheel and nothing may be pip-installed there). Seeded
randomized loops in the same test modules keep coverage in that case.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Absorbs any strategy-construction call chain."""

        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _Strategy()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            # zero-arg replacement: pytest must not mistake the wrapped
            # test's hypothesis parameters for fixtures
            def skipper():
                pytest.skip("hypothesis not installed in this environment")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco
