"""JAX engine vs oracle: lockstep architectural-state equivalence, and
engine-only semantics (jit path)."""
import random

import pytest

from tests.fmmu_lockstep import lockstep
from repro.core.fmmu.engine import FMMUEngine
from repro.core.fmmu.types import (COND_UPDATE, LOOKUP, NIL, Request,
                                   UPDATE, small_geometry)


@pytest.mark.parametrize("seed", range(2))
def test_engine_lockstep_deep(seed):
    assert lockstep(seed, n_reqs=150).startswith("OK")


def test_engine_lockstep_tiny_mshr():
    assert lockstep(7, n_reqs=150,
                    geom_kw=dict(mshr_cap=2, ctp_mshr_cap=2)).startswith("OK")


def test_engine_lockstep_one_way():
    assert lockstep(8, n_reqs=150,
                    geom_kw=dict(cmt_ways=1, ctp_ways=1)).startswith("OK")


def test_engine_semantics_jit():
    """Engine standalone: dict semantics through the jitted run loop."""
    g = small_geometry(queue_cap=2048)
    e = FMMUEngine(g)
    rng = random.Random(3)
    n_pages = g.n_tvpns * g.entries_per_tp
    shadow, resps, inflight, rid2dlpn = {}, {}, set(), {}
    rid = 0

    def pump():
        e.run(auto_flash=False)
        r, f, p = e.drain_outputs()
        for resp in r:
            resps[resp.req_id] = resp
            inflight.discard(rid2dlpn[resp.req_id])
        for t, s, w in f:
            e.push_flash_response(t, s, w)

    trace = []
    for _ in range(400):
        dlpn = rng.randrange(n_pages)
        while dlpn in inflight:
            pump()
        kind = rng.choice([LOOKUP, UPDATE, UPDATE])
        v = rng.randrange(10 ** 6)
        e.push_request(Request(kind, dlpn, dppn=v, req_id=rid))
        trace.append((kind, dlpn, rid, v))
        if kind == UPDATE:
            shadow[dlpn] = v
        inflight.add(dlpn)
        rid2dlpn[rid] = dlpn
        rid += 1
        if rng.random() < 0.25:
            pump()
    for _ in range(2000):
        pump()
        if not e.pending_work() and not inflight:
            break
    assert not inflight
    replay = {}
    for kind, dlpn, r, v in trace:
        if kind == UPDATE:
            replay[dlpn] = v
        else:
            assert resps[r].dppn == replay.get(dlpn, NIL)
    for dlpn, v in replay.items():
        assert e.resolve(dlpn) == v
    # flush_all persists to "flash"
    e.flush_all()
    import numpy as np
    st = e.state
    for dlpn, v in replay.items():
        tppn = int(st.gtd[dlpn // g.entries_per_tp])
        got = NIL if tppn == NIL else int(
            st.flash_tp[tppn, dlpn % g.entries_per_tp])
        assert got == v
