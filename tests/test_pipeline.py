"""Pipeline parallelism: GPipe schedule over a 'pipe' axis must equal
the sequential layer stack (subprocess with 4 virtual devices)."""
import os

from tests.test_distributed import run_sub


def test_gpipe_matches_sequential():
    out = run_sub("""
    from repro.parallel.pipeline import pipeline_apply, split_stages
    from repro.parallel.sharding import make_mesh

    L, S, B, D = 8, 4, 8, 16
    key = jax.random.key(0)
    ws = jax.random.normal(key, (L, D, D)) * (1.0 / jnp.sqrt(D))

    def layer(w, x):
        return jnp.tanh(x @ w)

    def seq(ws, x):
        for i in range(L):
            x = layer(ws[i], x)
        return x

    def stage_fn(params_slice, x):   # params_slice [L/S, D, D]
        def body(x, w):
            return layer(w, x), None
        x, _ = jax.lax.scan(body, x, params_slice)
        return x

    x = jax.random.normal(jax.random.key(1), (B, D))
    want = seq(ws, x)
    mesh = make_mesh((4,), ("pipe",))
    staged = split_stages(ws, S)
    got = pipeline_apply(mesh, stage_fn, staged, x, n_microbatches=4)
    print(json.dumps({"err": float(jnp.abs(got - want).max())}))
    """, devices=4)
    assert out["err"] < 1e-5, out


def test_gpipe_microbatch_count_invariance():
    out = run_sub("""
    from repro.parallel.pipeline import pipeline_apply, split_stages
    from repro.parallel.sharding import make_mesh

    L, S, B, D = 4, 2, 12, 8
    ws = jax.random.normal(jax.random.key(0), (L, D, D)) * 0.3

    def stage_fn(params_slice, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, params_slice)[0]

    x = jax.random.normal(jax.random.key(1), (B, D))
    mesh = make_mesh((2,), ("pipe",))
    staged = split_stages(ws, S)
    a = pipeline_apply(mesh, stage_fn, staged, x, n_microbatches=2)
    b = pipeline_apply(mesh, stage_fn, staged, x, n_microbatches=6)
    print(json.dumps({"err": float(jnp.abs(a - b).max())}))
    """, devices=2)
    assert out["err"] < 1e-5, out
