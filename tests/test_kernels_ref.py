"""Blocked-engine vs naive-oracle equivalence (the blocked engines are
what the dry-run lowers; the Pallas kernels are tested against the same
oracles in their own files)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.models import moe as moe_mod
from repro.models import Runtime
from repro.configs import get_arch, smoke_config
from repro.parallel import trivial_ctx


@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=True, window=32),
    dict(causal=True, softcap=20.0),
    dict(causal=False, bidirectional=True),
    dict(causal=True, window=48, softcap=30.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_blocked_vs_naive(kwargs, dtype):
    k = jax.random.key(0)
    b, s, h, kv, d = 2, 128, 4, 2, 16
    q = jax.random.normal(jax.random.fold_in(k, 1), (b, s, h, d), dtype)
    kk = jax.random.normal(jax.random.fold_in(k, 2), (b, s, kv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(k, 3), (b, s, kv, d), dtype)
    o1 = ref.attention_naive(q, kk, v, **kwargs)
    o2 = ref.flash_attention_blocked(q, kk, v, q_chunk=32, kv_chunk=32, **kwargs)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.abs(o1.astype(jnp.float32) - o2.astype(jnp.float32)).max()) < tol


def test_flash_segment_ids():
    k = jax.random.key(7)
    b, s, h, d = 2, 64, 2, 8
    q = jax.random.normal(jax.random.fold_in(k, 1), (b, s, h, d))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(k, 3), (b, s, h, d))
    segs = jnp.concatenate([jnp.zeros((b, s // 2), jnp.int32),
                            jnp.ones((b, s // 2), jnp.int32)], axis=1)
    o1 = ref.attention_naive(q, kk, v, causal=True, segment_ids=(segs, segs))
    o2 = ref.flash_attention_blocked(q, kk, v, causal=True,
                                     segment_ids=(segs, segs),
                                     q_chunk=16, kv_chunk=16)
    assert float(jnp.abs(o1 - o2).max()) < 1e-5
    # packing isolation: second segment must equal standalone run
    o_iso = ref.attention_naive(q[:, s // 2:], kk[:, s // 2:], v[:, s // 2:],
                                causal=True)
    assert float(jnp.abs(o1[:, s // 2:] - o_iso).max()) < 1e-5


@pytest.mark.parametrize("ppc", [1, 2, 3])
def test_paged_blocked_vs_naive(ppc):
    k = jax.random.key(1)
    b, h, d, p, maxp = 3, 4, 16, 8, 6
    nb = b * maxp
    q = jax.random.normal(jax.random.fold_in(k, 1), (b, h, d))
    kp = jax.random.normal(jax.random.fold_in(k, 2), (nb, p, 2, d))
    vp = jax.random.normal(jax.random.fold_in(k, 3), (nb, p, 2, d))
    table = jax.random.permutation(jax.random.fold_in(k, 4),
                                   jnp.arange(nb)).reshape(b, maxp)
    ctx = jnp.array([13, 40, 48])
    o1, (m1, l1) = ref.paged_attention_naive(q, kp, vp, table, ctx,
                                             return_stats=True)
    o2, (m2, l2) = ref.paged_attention_blocked(q, kp, vp, table, ctx,
                                               pages_per_chunk=ppc,
                                               return_stats=True)
    assert float(jnp.abs(o1 - o2).max()) < 1e-5
    assert float(jnp.abs(m1 - m2).max()) < 1e-5


def test_partial_combine_matches_single_shot():
    """flash-decoding cross-shard combine == one-shot attention."""
    k = jax.random.key(2)
    b, h, d, p = 2, 4, 16, 8
    maxp = 8
    nb = b * maxp
    q = jax.random.normal(jax.random.fold_in(k, 1), (b, h, d))
    kp = jax.random.normal(jax.random.fold_in(k, 2), (nb, p, 2, d))
    vp = jax.random.normal(jax.random.fold_in(k, 3), (nb, p, 2, d))
    table = jnp.arange(nb).reshape(b, maxp)
    ctx = jnp.array([maxp * p, maxp * p - 3])
    full = ref.paged_attention_naive(q, kp, vp, table, ctx)
    # split pages across 2 "shards"
    outs, ms, ls = [], [], []
    for sh in range(2):
        tb = table[:, sh * (maxp // 2):(sh + 1) * (maxp // 2)]
        cl = jnp.clip(ctx - sh * (maxp // 2) * p, 0, (maxp // 2) * p)
        o, (m, l) = ref.paged_attention_naive(q, kp, vp, tb, cl,
                                              return_stats=True)
        outs.append(o), ms.append(m), ls.append(l)
    comb = ref.combine_partial_attention(
        jnp.stack(outs), jnp.stack(ms), jnp.stack(ls))
    assert float(jnp.abs(comb - full).max()) < 1e-5


@pytest.mark.parametrize("chunk", [16, 32, 96])
def test_mamba_blocked_vs_naive(chunk):
    k = jax.random.key(3)
    bt, s, h, p, n = 2, 96, 4, 16, 8
    x = jax.random.normal(jax.random.fold_in(k, 1), (bt, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 2), (bt, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 3), (h,)))
    B = jax.random.normal(jax.random.fold_in(k, 4), (bt, s, n))
    C = jax.random.normal(jax.random.fold_in(k, 5), (bt, s, n))
    D = jnp.ones((h,))
    y1, s1 = ref.mamba_chunk_scan_naive(x, dt, A, B, C, D, chunk=chunk)
    y2, s2 = ref.mamba_chunk_scan_blocked(x, dt, A, B, C, D, chunk=chunk)
    assert float(jnp.abs(y1 - y2).max()) < 1e-3
    assert float(jnp.abs(s1 - s2).max()) < 1e-3


def test_mamba_decode_matches_scan():
    k = jax.random.key(4)
    bt, s, h, p, n = 2, 40, 2, 8, 4
    x = jax.random.normal(jax.random.fold_in(k, 1), (bt, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 2), (bt, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 3), (h,)))
    B = jax.random.normal(jax.random.fold_in(k, 4), (bt, s, n))
    C = jax.random.normal(jax.random.fold_in(k, 5), (bt, s, n))
    D = jnp.ones((h,))
    y_ref, st_ref = ref.mamba_chunk_scan_naive(x, dt, A, B, C, D, chunk=8)
    st = jnp.zeros((bt, h, p, n))
    for t in range(s):
        y, st = ref.mamba_decode_step(st, x[:, t], dt[:, t], A, B[:, t],
                                      C[:, t], D)
    assert float(jnp.abs(st - st_ref).max()) < 1e-4
    assert float(jnp.abs(y - y_ref[:, -1]).max()) < 1e-4


def test_mamba_initial_state_continuation():
    """scan(x) == scan(x[:half]) then scan(x[half:], initial_state)."""
    k = jax.random.key(5)
    bt, s, h, p, n = 1, 64, 2, 8, 4
    x = jax.random.normal(jax.random.fold_in(k, 1), (bt, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 2), (bt, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 3), (h,)))
    B = jax.random.normal(jax.random.fold_in(k, 4), (bt, s, n))
    C = jax.random.normal(jax.random.fold_in(k, 5), (bt, s, n))
    D = jnp.zeros((h,))
    y_full, st_full = ref.mamba_chunk_scan_blocked(x, dt, A, B, C, D, chunk=16)
    m = s // 2
    y1, st1 = ref.mamba_chunk_scan_blocked(x[:, :m], dt[:, :m], A, B[:, :m],
                                           C[:, :m], D, chunk=16)
    y2, st2 = ref.mamba_chunk_scan_blocked(x[:, m:], dt[:, m:], A, B[:, m:],
                                           C[:, m:], D, chunk=16,
                                           initial_state=st1)
    assert float(jnp.abs(jnp.concatenate([y1, y2], 1) - y_full).max()) < 1e-3
    assert float(jnp.abs(st2 - st_full).max()) < 1e-3


def test_moe_dropless_matches_dense_ref():
    cfg = smoke_config(get_arch("dbrx-132b"))
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 capacity_factor=100.0)
    params = moe_mod.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    ctx = trivial_ctx()
    out, aux = jax.jit(lambda p, xx: moe_mod.apply_moe(p, xx, cfg, rt, ctx))(params, x)
    dense = moe_mod.apply_moe_dense_ref(params, x, cfg, rt)
    assert float(jnp.abs(out - dense).max()) < 1e-5
    assert float(aux) > 0


def test_moe_capacity_drops_monotone():
    """Tighter capacity must only zero-out contributions, never corrupt."""
    cfg = smoke_config(get_arch("arctic-480b"))
    params = moe_mod.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(1), (1, 32, cfg.d_model))
    ctx = trivial_ctx()
    outs = {}
    for cf in (0.5, 2.0, 100.0):
        rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                     capacity_factor=cf)
        outs[cf], _ = moe_mod.apply_moe(params, x, cfg, rt, ctx)
    dense = moe_mod.apply_moe_dense_ref(
        params, x, cfg, Runtime(compute_dtype=jnp.float32,
                                param_dtype=jnp.float32))
    assert float(jnp.abs(outs[100.0] - dense).max()) < 1e-5
    # dropped-token outputs are a strict subset: err(0.5) >= err(2.0)
    e05 = float(jnp.abs(outs[0.5] - dense).max())
    e20 = float(jnp.abs(outs[2.0] - dense).max())
    assert e20 <= e05 + 1e-6
