"""Semantic tests of the FMMU oracle (the executable spec), including
hypothesis property tests: any dependency-serialized trace must behave
like a sequential dict, survive arbitrary flash-response reordering, and
persist completely through flush_all."""
import random

import pytest
from _hyp import example, given, settings, st

from repro.core.fmmu.oracle import FMMUOracle
from repro.core.fmmu.types import (COND_UPDATE, LOOKUP, NIL, Request,
                                   UPDATE, small_geometry)


class Driver:
    """HIL-style dependency checker: serializes per-dlpn, reorders flash
    responses with the given rng."""

    def __init__(self, unit, rng):
        self.u = unit
        self.rng = rng
        self.resps = {}
        self.inflight = set()
        self.rid2dlpn = {}
        self.rid = 0
        self.trace = []

    def pump(self):
        self.u.run()
        r, f, p = self.u.drain_outputs()
        for resp in r:
            self.resps[resp.req_id] = resp
            self.inflight.discard(self.rid2dlpn[resp.req_id])
        f = list(f)
        self.rng.shuffle(f)
        for t, s, w in f:
            self.u.push_flash_response(t, s, w)
        return f

    def issue(self, kind, dlpn, dppn=NIL, old=NIL):
        spins = 0
        while dlpn in self.inflight:
            self.pump()
            spins += 1
            assert spins < 10_000, "driver livelock"
        self.u.push_request(Request(kind, dlpn, dppn=dppn, old_dppn=old,
                                    req_id=self.rid,
                                    src=1 if kind == COND_UPDATE else 0))
        self.trace.append((kind, dlpn, self.rid, dppn, old))
        self.inflight.add(dlpn)
        self.rid2dlpn[self.rid] = dlpn
        self.rid += 1
        if self.rng.random() < 0.3:
            self.pump()

    def finish(self):
        for _ in range(5000):
            f = self.pump()
            if not self.u.pending_work() and not f and not self.inflight:
                break
        assert not self.inflight, "responses lost"

    def replay_and_check(self):
        shadow = {}
        for kind, dlpn, rid, dppn, old in self.trace:
            if kind == UPDATE:
                shadow[dlpn] = dppn
            elif kind == COND_UPDATE:
                if shadow.get(dlpn, NIL) == old:
                    shadow[dlpn] = dppn
            else:
                assert self.resps[rid].dppn == shadow.get(dlpn, NIL), (
                    f"lookup rid={rid} dlpn={dlpn}")
        return shadow


def _random_trace(unit, seed, n_ops):
    rng = random.Random(seed)
    g = unit.g
    n_pages = g.n_tvpns * g.entries_per_tp
    d = Driver(unit, rng)
    shadow = {}
    for _ in range(n_ops):
        dlpn = rng.randrange(n_pages)
        kind = rng.choice([LOOKUP, UPDATE, UPDATE, COND_UPDATE])
        if kind == LOOKUP:
            d.issue(LOOKUP, dlpn)
        elif kind == UPDATE:
            v = rng.randrange(10 ** 6)
            d.issue(UPDATE, dlpn, dppn=v)
            shadow[dlpn] = v
        else:
            old = rng.choice([shadow.get(dlpn, NIL), rng.randrange(10 ** 6)])
            v = rng.randrange(10 ** 6)
            d.issue(COND_UPDATE, dlpn, dppn=v, old=old)
            if shadow.get(dlpn, NIL) == old:
                shadow[dlpn] = v
    d.finish()
    return d


@pytest.mark.parametrize("seed", range(4))
def test_oracle_sequential_semantics(seed):
    o = FMMUOracle(small_geometry())
    d = _random_trace(o, seed, 1500)
    shadow = d.replay_and_check()
    for dlpn, v in shadow.items():
        assert o.resolve(dlpn) == v


@pytest.mark.parametrize("seed", range(2))
def test_oracle_flush_all_persists(seed):
    o = FMMUOracle(small_geometry())
    d = _random_trace(o, seed + 10, 800)
    shadow = d.replay_and_check()
    o.flush_all()
    assert o.cmt_dirty == 0 and o.ctp_dirty == 0
    g = o.g
    for dlpn, v in shadow.items():
        tppn = o.gtd[dlpn // g.entries_per_tp]
        got = NIL if tppn == NIL else o.flash_tp[tppn][dlpn % g.entries_per_tp]
        assert got == v


def test_oracle_mshr_merging_reduces_flash_reads():
    """Many concurrent lookups of one translation page -> one flash read
    (the non-blocking MSHR-merge claim of §4.2)."""
    g = small_geometry()
    o = FMMUOracle(g)
    # prime: one update far away so the TP exists in flash
    o.push_request(Request(UPDATE, 0, dppn=7, req_id=0))
    o.run(auto_flash=True)
    o.flush_all()
    base_reads = o.stats["fc_reads"]
    # evict TP from CTP by touching other TVPNs
    for i in range(1, g.n_tvpns):
        o.push_request(Request(UPDATE, i * g.entries_per_tp, dppn=i,
                               req_id=100 + i))
    o.run(auto_flash=True)
    o.flush_all()
    mid_reads = o.stats["fc_reads"]
    # now issue a burst of lookups to the SAME cmt block without serving
    # flash: all must merge into one outstanding read
    for j in range(g.mshr_cap):
        o.push_request(Request(LOOKUP, j, req_id=1000 + j))
    o.run(auto_flash=False)     # flash is slow: responses pending
    _, fc, _ = o.drain_outputs()
    assert len(fc) == 1, f"expected one merged flash read, got {len(fc)}"
    assert o.stats["mshr_merge"] >= g.mshr_cap - 1
    for t, s, w in fc:
        o.push_flash_response(t, s, w)
    o.run()
    r, _, _ = o.drain_outputs()
    got = {resp.req_id: resp.dppn for resp in r}
    assert got[1000] == 7
    for j in range(1, g.mshr_cap):
        assert got[1000 + j] == NIL


def test_oracle_condupdate_race():
    """GC CondUpdate must lose when the host updated concurrently (§4.1)."""
    o = FMMUOracle(small_geometry())
    o.push_request(Request(UPDATE, 5, dppn=100, req_id=0))
    o.run(auto_flash=True)
    # host writes a newer version
    o.push_request(Request(UPDATE, 5, dppn=200, req_id=1))
    o.run(auto_flash=True)
    # GC finishes its copy of the old page and conditionally updates
    o.push_request(Request(COND_UPDATE, 5, dppn=300, old_dppn=100,
                           req_id=2, src=1))
    o.run(auto_flash=True)
    r, _, _ = o.drain_outputs()
    stale = [x for x in r if x.req_id == 2][0]
    assert stale.status == 1  # ST_STALE: update refused
    assert o.resolve(5) == 200


def test_oracle_flush_batches_same_tvpn():
    """Dirty blocks of one TVPN flush together via next-links: flushing
    after k updates inside one TP costs exactly one program."""
    g = small_geometry()
    o = FMMUOracle(g)
    for j in range(4):  # 4 updates, all within TVPN 0, different blocks
        o.push_request(Request(UPDATE, j * g.cmt_entries, dppn=j, req_id=j))
    o.run(auto_flash=True)
    o.flush_all()
    assert o.stats["programs"] == 1
    assert o.stats["flush_blocks"] == 4


# pinned regression cases (replayed even without a hypothesis wheel —
# tests/_hyp.py): a CondUpdate racing an Update on one dlpn, and a
# full-block write/readback sweep that forces a flush + reload
@example([(1, 0, 5), (0, 0, 0), (2, 0, 9), (0, 0, 0), (1, 0, 3),
          (2, 0, 9), (0, 0, 0)], 1234)
@example([(1, j, j) for j in range(8)]
         + [(0, j, 0) for j in range(8)], 7)
@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2),
                          st.integers(0, 127),
                          st.integers(0, 999)),
                min_size=1, max_size=120),
       st.integers(0, 2 ** 30))
def test_oracle_property_random_programs(ops, flash_seed):
    """Property: any op sequence == dict semantics (hypothesis-driven)."""
    g = small_geometry()
    o = FMMUOracle(g)
    rng = random.Random(flash_seed)
    d = Driver(o, rng)
    shadow = {}
    for op, dlpn, val in ops:
        if op == 0:
            d.issue(LOOKUP, dlpn)
        elif op == 1:
            d.issue(UPDATE, dlpn, dppn=val)
            shadow[dlpn] = val
        else:
            old = shadow.get(dlpn, NIL) if val % 2 else val
            d.issue(COND_UPDATE, dlpn, dppn=val, old=old)
            if shadow.get(dlpn, NIL) == old:
                shadow[dlpn] = val
    d.finish()
    d.replay_and_check()
    for dlpn, v in shadow.items():
        assert o.resolve(dlpn) == v
