"""Non-blocking host-tier swap pipeline (ISSUE 4).

Data-movement correctness: the fused donated jitted swap (CondUpdate
map commits riding the single-probe translate + pool gather/scatter +
swap_pending lane flip, one dispatch per swap) must be bit-identical
to a host-numpy oracle that replays the same tier moves with plain
take/set — under random interleavings of allocation churn, swaps, and
device-side macro-step growth. Plus the residency-lane contract and
the BENCH_serve.json schema gate used by CI's bench-smoke lane.
"""
import importlib.util
import pathlib
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fmmu import batch as fb
from repro.paging import kv_manager as KM
from repro.paging.kv_manager import KVPageManager
from repro.paging.pool import BlockPool, OutOfBlocks


def _oracle_apply_swap(shadow: np.ndarray, kvm: KVPageManager,
                       pre_pages, post_pages):
    """Host-numpy oracle: replay one swap's tier moves on the shadow
    pool. A page whose block id changed moved tiers; the data travels
    from the old block's row to the new block's row (host blocks live
    at pool.host_row(b))."""
    row = lambda b: (kvm.pool.host_row(b) if BlockPool.is_host(b)
                     else b)
    src = [row(a) for a, b in zip(pre_pages, post_pages) if a != b]
    dst = [row(b) for a, b in zip(pre_pages, post_pages) if a != b]
    shadow[dst] = shadow[src]


def test_fused_swap_bit_identical_to_oracle_roundtrip():
    """One swap_out + swap_in cycle: the jitted pipeline's pool bytes
    equal the numpy oracle's, the map commits are CondUpdate-guarded,
    and the swap_pending lane flips with the data."""
    kvm = KVPageManager(n_slots=2, max_pages=4, n_device_blocks=4,
                        n_host_blocks=4)
    kvm.swap_pad = 4      # pinned lane pad: one compiled fn, idempotent
    kvm.new_seq(0, 3)     # pad moves (3 real lanes padded to 4)
    pool = jnp.arange((4 + 4 + 1) * 5.0).reshape(9, 5)
    shadow = np.array(pool)
    for direction in ("out", "in"):
        pre = list(kvm.seq_pages[0])
        if direction == "out":
            [pool], n = kvm.swap_out(0, [pool])
            assert bool(np.asarray(kvm.state.swap_pending)[0])
        else:
            [pool], n = kvm.swap_in(0, [pool])
            assert not bool(np.asarray(kvm.state.swap_pending)[0])
        assert n == 3
        _oracle_apply_swap(shadow, kvm, pre, kvm.seq_pages[0])
        np.testing.assert_array_equal(np.asarray(pool), shadow,
                                      f"swap_{direction}")
    # table agrees with the from-scratch oracle after the round trip
    np.testing.assert_array_equal(np.asarray(kvm.block_tables()),
                                  np.asarray(kvm.retranslate_tables()))


@pytest.mark.slow
def test_swap_oracle_equivalence_random_interleavings():
    """ISSUE-4 property test: under a random interleaving of
    new/extend/free/swap_out/swap_in and device-side macro-step growth
    (serving_grow + reconcile_macro, the scan's allocation path), the
    jitted swap pipeline keeps the pool tensor bit-identical to the
    host-numpy oracle, the incremental table bit-identical to the
    retranslation oracle, and the allocator mirror exact."""
    import functools

    rng = random.Random(11)
    n_slots, max_pages = 4, 6
    kvm = KVPageManager(n_slots, max_pages, n_device_blocks=16,
                        n_host_blocks=10)
    n_rows = 16 + 10 + 1
    pool = jnp.arange(n_rows * 3.0).reshape(n_rows, 3)
    shadow = np.array(pool)
    grow_fn = jax.jit(functools.partial(fb.serving_grow, kvm.geom),
                      donate_argnums=(0,))
    live = set()
    for step in range(120):
        ops = ["new"] if len(live) < n_slots else []
        if live:
            ops += ["extend", "free", "swap_out", "swap_in", "macro"]
        op = rng.choice(ops)
        try:
            if op == "new":
                slot = rng.choice([s for s in range(n_slots)
                                   if s not in live])
                kvm.new_seq(slot, rng.randint(1, 3))
                live.add(slot)
            elif op == "extend":
                slot = rng.choice(sorted(live))
                room = max_pages - len(kvm.seq_pages[slot])
                if room:
                    kvm.extend_seq(slot, rng.randint(1, room))
            elif op == "free":
                slot = rng.choice(sorted(live))
                kvm.free_seq(slot)
                live.discard(slot)
            elif op in ("swap_out", "swap_in"):
                slot = rng.choice(sorted(live))
                pre = list(kvm.seq_pages[slot])
                fn = kvm.swap_out if op == "swap_out" else kvm.swap_in
                [pool], _ = fn(slot, [pool],
                               check=rng.random() < 0.5)
                _oracle_apply_swap(shadow, kvm, pre,
                                   kvm.seq_pages[slot])
            else:   # macro: device-side growth, host replays at the
                    # boundary exactly like the engine does
                slots = [s for s in sorted(live)
                         if kvm.is_resident(s)
                         and len(kvm.seq_pages[s]) < max_pages]
                if not slots or kvm.pool.free_device < len(slots):
                    continue
                kvm.sync_allocator()
                grow = np.zeros(len(slots), bool)
                dl = np.zeros(len(slots), np.int32)
                for i, s in enumerate(slots):
                    grow[i] = True
                    dl[i] = s * max_pages + len(kvm.seq_pages[s])
                kvm.state, _, ok = grow_fn(kvm.state, grow, dl)
                assert bool(np.asarray(ok).all())
                kvm.reconcile_macro(list(slots))
        except OutOfBlocks:
            pass
        np.testing.assert_array_equal(np.asarray(pool), shadow,
                                      f"step {step}: pool diverged "
                                      "from the numpy oracle")
        if step % 15 == 14:
            np.testing.assert_array_equal(
                np.asarray(kvm.block_tables()),
                np.asarray(kvm.retranslate_tables()), f"step {step}")
            kvm.sync_allocator()
            st = kvm.state
            assert int(st.free_n) == kvm.pool.free_device
            np.testing.assert_array_equal(
                np.asarray(st.free_stack[:int(st.free_n)]),
                np.asarray(kvm.pool._free_dev, np.int32))


def test_swap_pending_lane_tracks_residency():
    """The ServingMapState.swap_pending lane is the device's view of
    host-tier residency: set by swap_out, cleared by swap_in, and
    refreshed from host bookkeeping by sync_allocator after a
    host-side free of a swapped-out slot."""
    kvm = KVPageManager(n_slots=3, max_pages=4, n_device_blocks=8,
                        n_host_blocks=8)
    pool = jnp.zeros((8 + 8 + 1, 2))
    kvm.new_seq(0, 2)
    kvm.new_seq(1, 2)
    lanes = lambda: list(np.asarray(kvm.state.swap_pending))
    assert lanes() == [False, False, False]
    [pool], _ = kvm.swap_out(1, [pool])
    assert lanes() == [False, True, False]
    assert not kvm.is_resident(1)       # host predicate agrees
    [pool], _ = kvm.swap_out(0, [pool])
    [pool], _ = kvm.swap_in(1, [pool])
    assert lanes() == [True, False, False]
    # free a swapped-out slot host-side: the lane goes stale until the
    # (always-following) allocator sync refreshes it
    kvm.free_seq(0)
    assert kvm._alloc_dirty
    kvm.sync_allocator()
    assert lanes() == [False, False, False]


def test_swap_is_one_fused_call_and_nonblocking_path():
    """A swap is exactly ONE fused map call (XLATE_CALLS += 1) and
    with check=False performs no guard-mask readback the caller could
    block on; hit_stats surfaces the tier activity (ISSUE-4: the
    zero-fallback/swap claims are counter-assertable)."""
    kvm = KVPageManager(n_slots=2, max_pages=4, n_device_blocks=4,
                        n_host_blocks=4)
    kvm.new_seq(0, 3)
    pool = jnp.zeros((4 + 4 + 1, 2))
    x0 = KM.XLATE_CALLS[0]
    [pool], n = kvm.swap_out(0, [pool], check=False)
    assert n == 3
    assert KM.XLATE_CALLS[0] - x0 == 1
    st = kvm.hit_stats()
    assert st["swaps_out"] == 3 and st["swaps_in"] == 0
    assert st["host_resident_slots"] == 1
    [pool], _ = kvm.swap_in(0, [pool], check=False)
    assert KM.XLATE_CALLS[0] - x0 == 2
    st = kvm.hit_stats()
    assert st["swaps_in"] == 3 and st["host_resident_slots"] == 0


# ---------------------------------------------------------------------
# BENCH_serve.json schema gate (benchmarks/check_bench_json.py): CI
# hard-fails on malformed/missing artifacts; validate both directions.
# ---------------------------------------------------------------------
def _load_checker():
    root = pathlib.Path(__file__).resolve().parents[1]
    spec = importlib.util.spec_from_file_location(
        "check_bench_json", root / "benchmarks" / "check_bench_json.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _valid_doc():
    modes = ("fused_macro", "single_step", "incremental",
             "rebuild_legacy", "oversub_fused", "oversub_fallback")
    return {
        "bench": "serve_decode", "n_slots": 16, "max_pages": 64,
        "macro_k": 8, "steps_timed": 24, "repeats": 2,
        "steps_per_sec": {m: 100.0 for m in modes},
        "dispersion": {m: {"median": 100.0, "min": 90.0, "iqr": 5.0,
                           "windows": [99.0, 101.0]} for m in modes},
        "speedups": {"fused_macro_vs_incremental": 2.0,
                     "fused_macro_vs_single_step": 1.5,
                     "single_step_vs_incremental": 1.4,
                     "incremental_vs_rebuild": 2.0,
                     "oversub_fused_vs_fallback": 1.5},
        "oversubscription": {
            "prompt_len": 80, "max_new": 48, "n_device_blocks": 76,
            "n_host_blocks": 640,
            "tokens_per_sec": {"oversub_fused": 900.0,
                               "oversub_fallback": 600.0},
            "modes": {m: {"macro_steps": 10, "macro_fallbacks": 0,
                          "swaps_out": 4, "swaps_in": 4}
                      for m in ("oversub_fused", "oversub_fallback")},
        },
        "channel_scaling": {
            "channels": [1, 2, 4, 8],
            "device_count": 8, "cpu_bound": False,
            "steps_per_sec": {f"n{n}": 100.0 * n
                              for n in (1, 2, 4, 8)},
            "dispersion": {f"n{n}": {"median": 100.0, "min": 90.0,
                                     "iqr": 5.0,
                                     "windows": [99.0, 101.0]}
                           for n in (1, 2, 4, 8)},
            "speedup_n8_vs_n1": 2.0,
            "per_channel_lanes": {f"n{n}": [10] * n
                                  for n in (2, 4, 8)},
        },
        "fault_injection": {
            "channels": 4, "stall": [4.0, 1.0, 1.0, 1.0],
            "swap_fail_p": 0.01, "seed": 2026,
            "retention_degraded_vs_healthy": 0.7,
            "tokens_per_sec": {"faults_healthy": 900.0,
                               "faults_degraded": 630.0},
            "modes": {
                "faults_healthy": {
                    "swap_faults": 0, "quarantines": 0,
                    "watchdog_quarantines": 0, "requeues": 0,
                    "retired_blocks": 0, "program_faults": 0},
                "faults_degraded": {
                    "swap_faults": 5, "quarantines": 1,
                    "watchdog_quarantines": 0, "requeues": 1,
                    "retired_blocks": 0, "program_faults": 0},
            },
        },
        "gc": {
            "watermark": 3, "pages_per_boundary": 8, "block_pages": 4,
            "retention_gc_on_vs_off": 0.95,
            "tokens_per_sec": {"gc_off": 900.0, "gc_on": 860.0},
            "modes": {
                "gc_off": {
                    "gc_walks": 0, "gc_moves": 0, "gc_victims": 0,
                    "host_writes": 4000, "flash_programs": 4100,
                    "write_amp": 1.025, "victims_per_channel": [0],
                    "prefetch_hits": 0, "prefetch_misses": 0},
                "gc_on": {
                    "gc_walks": 12, "gc_moves": 30, "gc_victims": 9,
                    "host_writes": 4000, "flash_programs": 4130,
                    "write_amp": 1.0325, "victims_per_channel": [9],
                    "prefetch_hits": 50, "prefetch_misses": 10},
            },
        },
        "shared_prefix": {
            "batch": 8, "common_tokens": 80, "tail_tokens": 4,
            "max_new": 4,
            "prefill_tokens": {"prefix_off": 672, "prefix_on": 112},
            "prefill_flop_ratio": 0.1667,
            "device_pages": {"prefix_off": 88, "prefix_on": 18},
            "device_page_ratio": 0.2045,
            "shared_admits": 7, "shared_pages": 70, "cow_moves": 8,
            "outputs_bit_identical": True, "off_inert": True,
            "forced_divergence": {"cow_moves": 7,
                                  "outputs_bit_identical": True},
        },
        "recovery": {
            "channels": 2, "seed": 2027, "crash_at": 80,
            "snapshot_sweep": {
                f"snap{n}": {
                    "snapshot_every": n, "mttr_s": 0.5 + 0.01 * n,
                    "recover_s": 0.1, "replayed_records": 5 * n,
                    "snapshot_seq": 80 - 5 * n, "last_seq": 81,
                    "torn": n == 4, "oob_scan": n == 4,
                    "requeued": 3,
                } for n in (1, 4, 16)
            },
            "mttr_s": {f"snap{n}": 0.5 + 0.01 * n for n in (1, 4, 16)},
        },
    }


def test_bench_schema_accepts_valid_and_rejects_malformed(tmp_path):
    chk = _load_checker()
    chk.check(_valid_doc())                      # no raise

    import json
    good = tmp_path / "BENCH_serve.json"
    good.write_text(json.dumps(_valid_doc()))
    hist = tmp_path / "hist.jsonl"
    assert chk.main([str(good), "--append-history", str(hist)]) == 0
    line = json.loads(hist.read_text())
    assert line["speedups"]["oversub_fused_vs_fallback"] == 1.5
    assert line["oversub_fallbacks"]["oversub_fused"] == 0
    assert line["oversub_tokens_per_sec"]["oversub_fused"] == 900.0
    assert line["degraded_retention"] == 0.7
    assert line["recovery_mttr_s"]["snap4"] == 0.54
    assert line["recovery_replayed"]["snap16"] == 80
    assert line["gc_retention"] == 0.95
    assert line["write_amp"]["gc_on"] == 1.0325
    assert line["gc_moves"] == 30
    assert line["prefix_flop_ratio"] == 0.1667
    assert line["prefix_page_ratio"] == 0.2045
    assert line["prefix_cow_moves"] == 8

    # missing file and invalid JSON hard-fail
    assert chk.main([str(tmp_path / "nope.json")]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert chk.main([str(bad)]) == 1

    # structural mutations every gate must catch
    def broken(mutate):
        doc = _valid_doc()
        mutate(doc)
        with pytest.raises(chk.SchemaError):
            chk.check(doc)

    broken(lambda d: d.pop("speedups"))
    broken(lambda d: d["speedups"].pop("oversub_fused_vs_fallback"))
    broken(lambda d: d["steps_per_sec"].pop("oversub_fused"))
    broken(lambda d: d["steps_per_sec"].update(fused_macro="fast"))
    broken(lambda d: d["dispersion"]["fused_macro"].pop("windows"))
    broken(lambda d: d["dispersion"]["fused_macro"].update(windows=[1.0]))
    broken(lambda d: d["oversubscription"]["modes"].pop("oversub_fused"))
    broken(lambda d: d["oversubscription"]["modes"]["oversub_fused"]
           .update(macro_fallbacks="none"))
    broken(lambda d: d["oversubscription"]["tokens_per_sec"]
           .pop("oversub_fallback"))
    # ISSUE-5 channel_scaling gates
    broken(lambda d: d.pop("channel_scaling"))
    broken(lambda d: d["channel_scaling"].update(channels=[1, 2, 4]))
    broken(lambda d: d["channel_scaling"].pop("speedup_n8_vs_n1"))
    broken(lambda d: d["channel_scaling"]["steps_per_sec"].pop("n8"))
    broken(lambda d: d["channel_scaling"].update(cpu_bound="maybe"))
    broken(lambda d: d["channel_scaling"]["per_channel_lanes"]
           .update(n8=[10] * 7))        # wrong width for N=8
    broken(lambda d: d["channel_scaling"]["per_channel_lanes"]
           .update(n4=[0, 0, 0, 0]))    # zero routed lanes
    broken(lambda d: d["channel_scaling"]["dispersion"]["n2"]
           .update(windows=[1.0]))
    # ISSUE-6 fault_injection gates
    broken(lambda d: d.pop("fault_injection"))
    broken(lambda d: d["fault_injection"]
           .pop("retention_degraded_vs_healthy"))
    broken(lambda d: d["fault_injection"].update(stall=[4.0, 1.0]))
    broken(lambda d: d["fault_injection"].update(stall=[0.5] * 4))
    broken(lambda d: d["fault_injection"]["tokens_per_sec"]
           .pop("faults_degraded"))
    broken(lambda d: d["fault_injection"]["modes"]["faults_degraded"]
           .update(swap_faults="many"))
    # a degraded run that never fired a fault (or a healthy control
    # that did) invalidates the retention headline
    broken(lambda d: d["fault_injection"]["modes"]["faults_degraded"]
           .update(swap_faults=0))
    broken(lambda d: d["fault_injection"]["modes"]["faults_healthy"]
           .update(swap_faults=3))
    # ISSUE-9 gc gates
    broken(lambda d: d.pop("gc"))
    broken(lambda d: d["gc"].pop("retention_gc_on_vs_off"))
    broken(lambda d: d["gc"]["tokens_per_sec"].pop("gc_on"))
    broken(lambda d: d["gc"]["modes"]["gc_on"].pop("write_amp"))
    # WA is flash/host: a value below 1.0 means the counters are wrong
    broken(lambda d: d["gc"]["modes"]["gc_on"].update(write_amp=0.9))
    broken(lambda d: d["gc"]["modes"]["gc_on"].update(gc_moves="many"))
    # a gc_on run that never moved a page (or a gc_off control that
    # did) invalidates the retention + write-amp headline
    broken(lambda d: d["gc"]["modes"]["gc_on"].update(gc_moves=0))
    broken(lambda d: d["gc"]["modes"]["gc_off"].update(gc_moves=7))
    broken(lambda d: d["gc"]["modes"]["gc_on"]
           .update(victims_per_channel=[]))
    # ISSUE-10 shared_prefix gates
    broken(lambda d: d.pop("shared_prefix"))
    broken(lambda d: d["shared_prefix"].pop("prefill_flop_ratio"))
    # sharing can only shrink prompt work: ratio must stay in (0, 1]
    broken(lambda d: d["shared_prefix"].update(prefill_flop_ratio=1.5))
    broken(lambda d: d["shared_prefix"]["prefill_tokens"]
           .pop("prefix_on"))
    broken(lambda d: d["shared_prefix"]["device_pages"]
           .update(prefix_on=0))
    # a sharing run that never admitted/relocated measured nothing
    broken(lambda d: d["shared_prefix"].update(shared_admits=0))
    broken(lambda d: d["shared_prefix"].update(cow_moves=0))
    broken(lambda d: d["shared_prefix"]
           .update(outputs_bit_identical=False))
    broken(lambda d: d["shared_prefix"].update(off_inert=False))
    broken(lambda d: d["shared_prefix"]["forced_divergence"]
           .update(cow_moves=0))
    # ISSUE-7 recovery gates
    broken(lambda d: d.pop("recovery"))
    broken(lambda d: d["recovery"].pop("snapshot_sweep"))
    broken(lambda d: d["recovery"].update(snapshot_sweep={}))
    broken(lambda d: d["recovery"]["snapshot_sweep"]["snap4"]
           .pop("mttr_s"))
    broken(lambda d: d["recovery"]["snapshot_sweep"]["snap4"]
           .update(mttr_s="fast"))
    # MTTR can never be smaller than its replay component
    broken(lambda d: d["recovery"]["snapshot_sweep"]["snap4"]
           .update(mttr_s=0.01))
    # a sweep point that replayed nothing / requeued nothing measured
    # an idle engine, not a recovery
    broken(lambda d: d["recovery"]["snapshot_sweep"]["snap4"]
           .update(replayed_records=0))
    broken(lambda d: d["recovery"]["snapshot_sweep"]["snap4"]
           .update(requeued=0))
    broken(lambda d: d["recovery"]["snapshot_sweep"]["snap4"]
           .update(torn="maybe"))
    broken(lambda d: d["recovery"]["mttr_s"].pop("snap4"))
