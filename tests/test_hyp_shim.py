"""The _hyp stub must replay explicit @example cases when hypothesis is
absent (ISSUE-5 satellite): before this fix the @given wrapper skipped
unconditionally, silently dropping the pinned regression seeds from
PRs 2-4 in CI's no-wheel container."""
import pytest

import _hyp


@pytest.mark.skipif(_hyp.HAVE_HYPOTHESIS,
                    reason="real hypothesis present: stub not in play")
def test_stub_given_replays_examples():
    ran = []

    @_hyp.example([3], tag="b")
    @_hyp.example([1, 2], tag="a")
    @_hyp.settings(max_examples=5)
    @_hyp.given(_hyp.st.lists(_hyp.st.integers()))
    def prop(xs, tag=""):
        ran.append((tuple(xs), tag))

    prop()            # zero-arg runner: replays both pinned examples
    assert ran == [((1, 2), "a"), ((3,), "b")]


@pytest.mark.skipif(_hyp.HAVE_HYPOTHESIS,
                    reason="real hypothesis present: stub not in play")
def test_stub_given_without_examples_skips():
    @_hyp.given(_hyp.st.integers())
    def prop(x):
        raise AssertionError("must not run")

    with pytest.raises(pytest.skip.Exception):
        prop()


def test_example_importable_both_ways():
    # test modules import `example` unconditionally; both the real
    # package and the stub must provide it
    from _hyp import example, given, settings, st  # noqa: F401
    assert callable(example)
