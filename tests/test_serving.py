"""End-to-end serving correctness: prefill + paged decode must equal the
teacher-forced full forward, for every architecture family (paged GQA,
local/global+softcap, SSM states, hybrid, MoE, cross-attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.models import Runtime, build_model
from repro.serving.engine import ServeEngine

RT = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
             remat="none", page_size=8, capacity_factor=100.0)

ARCHS = ["llama3.2-1b", "gemma2-9b", "glm4-9b", "qwen2-72b",
         "jamba-1.5-large-398b", "mamba2-1.3b", "dbrx-132b",
         "arctic-480b", "seamless-m4t-large-v2", "llava-next-mistral-7b"]


def _teacher_logits(m, params, req_batch, upto):
    """Full-forward logits at position upto-1 (teacher forcing)."""
    batch = {k: v for k, v in req_batch.items()}
    batch["tokens"] = req_batch["tokens"][:, :upto]
    logits, _ = jax.jit(m.prefill)(params, batch)
    return logits


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = smoke_config(get_arch(arch))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    key = jax.random.key(1)
    L, n_new = 21, 4
    toks = np.asarray(
        jax.random.randint(key, (L + n_new,), 0, cfg.vocab_size))
    extra = {}
    if cfg.prefix_len:
        extra["prefix_emb"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1), (cfg.prefix_len, cfg.d_model),
            jnp.float32)
    if cfg.n_enc_layers:
        extra["src_emb"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2), (32, cfg.d_model), jnp.float32)

    eng = ServeEngine(m, params, n_slots=2, max_ctx=64)
    rid = eng.submit(list(toks[:L]), max_new=n_new, **extra)

    # engine greedy decode
    done = eng.run()
    got = done[rid]

    # teacher-forced reference: at each step, feed ground-truth prefix
    # where "ground truth" is the engine's own greedy choice
    full = list(toks[:L]) + got
    req_batch = {"tokens": jnp.asarray(full)[None]}
    if "prefix_emb" in extra:
        req_batch["prefix_emb"] = extra["prefix_emb"][None]
    if "src_emb" in extra:
        req_batch["src_emb"] = extra["src_emb"][None]
        req_batch["src_valid"] = jnp.ones((1, 32), jnp.int32)
    for t in range(n_new):
        ref_logits = _teacher_logits(m, params, req_batch, L + t)
        want = int(jnp.argmax(ref_logits[0]))
        assert got[t] == want, (
            f"{arch}: step {t}: engine={got[t]} teacher={want}")


def test_two_concurrent_requests_isolated():
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    t1 = list(range(1, 12))
    t2 = list(range(50, 73))
    # solo runs
    e1 = ServeEngine(m, params, n_slots=2, max_ctx=64)
    r1 = e1.submit(t1, max_new=4)
    solo1 = e1.run()[r1]
    e2 = ServeEngine(m, params, n_slots=2, max_ctx=64)
    r2 = e2.submit(t2, max_new=4)
    solo2 = e2.run()[r2]
    # batched together
    e = ServeEngine(m, params, n_slots=2, max_ctx=64)
    rr1 = e.submit(t1, max_new=4)
    rr2 = e.submit(t2, max_new=4)
    both = e.run()
    assert both[rr1] == solo1
    assert both[rr2] == solo2


def test_preemption_swap_roundtrip():
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    # tiny device pool: 6 blocks of 8 tokens; host overflow available
    eng = ServeEngine(m, params, n_slots=2, max_ctx=48,
                      n_device_blocks=6, n_host_blocks=8)
    r1 = eng.submit(list(range(1, 25)), max_new=4)   # 24 toks -> 4 pages
    r2 = eng.submit(list(range(30, 50)), max_new=4)  # 20 toks -> 3 pages
    done = eng.run()
    assert set(done) == {r1, r2}
    assert eng.metrics["preemptions"] >= 1
    # compare r1 against solo run (no preemption)
    solo = ServeEngine(m, params, n_slots=1, max_ctx=48)
    rs = solo.submit(list(range(1, 25)), max_new=4)
    assert solo.run()[rs] == done[r1]


def test_fmmu_map_hit_stats_progress():
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    eng = ServeEngine(m, params, n_slots=2, max_ctx=32)
    rid = eng.submit(list(range(1, 17)), max_new=4)
    eng.run()
    st = eng.kvm.hit_stats()
    assert st["updates"] > 0 and st["hits"] + st["misses"] > 0
