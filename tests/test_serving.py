"""End-to-end serving correctness: prefill + paged decode must equal the
teacher-forced full forward, for every architecture family (paged GQA,
local/global+softcap, SSM states, hybrid, MoE, cross-attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.models import Runtime, build_model
from repro.serving.engine import ServeEngine

RT = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
             remat="none", page_size=8, capacity_factor=100.0)

ARCHS = ["llama3.2-1b", "gemma2-9b", "glm4-9b", "qwen2-72b",
         "jamba-1.5-large-398b", "mamba2-1.3b", "dbrx-132b",
         "arctic-480b", "seamless-m4t-large-v2", "llava-next-mistral-7b"]


def _teacher_logits(m, params, req_batch, upto):
    """Full-forward logits at position upto-1 (teacher forcing)."""
    batch = {k: v for k, v in req_batch.items()}
    batch["tokens"] = req_batch["tokens"][:, :upto]
    logits, _ = jax.jit(m.prefill)(params, batch)
    return logits


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = smoke_config(get_arch(arch))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    key = jax.random.key(1)
    L, n_new = 21, 4
    toks = np.asarray(
        jax.random.randint(key, (L + n_new,), 0, cfg.vocab_size))
    extra = {}
    if cfg.prefix_len:
        extra["prefix_emb"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1), (cfg.prefix_len, cfg.d_model),
            jnp.float32)
    if cfg.n_enc_layers:
        extra["src_emb"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2), (32, cfg.d_model), jnp.float32)

    eng = ServeEngine(m, params, n_slots=2, max_ctx=64)
    rid = eng.submit(list(toks[:L]), max_new=n_new, **extra)

    # engine greedy decode
    done = eng.run()
    got = done[rid]

    # teacher-forced reference: at each step, feed ground-truth prefix
    # where "ground truth" is the engine's own greedy choice
    full = list(toks[:L]) + got
    req_batch = {"tokens": jnp.asarray(full)[None]}
    if "prefix_emb" in extra:
        req_batch["prefix_emb"] = extra["prefix_emb"][None]
    if "src_emb" in extra:
        req_batch["src_emb"] = extra["src_emb"][None]
        req_batch["src_valid"] = jnp.ones((1, 32), jnp.int32)
    for t in range(n_new):
        ref_logits = _teacher_logits(m, params, req_batch, L + t)
        want = int(jnp.argmax(ref_logits[0]))
        assert got[t] == want, (
            f"{arch}: step {t}: engine={got[t]} teacher={want}")


def test_two_concurrent_requests_isolated():
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    t1 = list(range(1, 12))
    t2 = list(range(50, 73))
    # solo runs
    e1 = ServeEngine(m, params, n_slots=2, max_ctx=64)
    r1 = e1.submit(t1, max_new=4)
    solo1 = e1.run()[r1]
    e2 = ServeEngine(m, params, n_slots=2, max_ctx=64)
    r2 = e2.submit(t2, max_new=4)
    solo2 = e2.run()[r2]
    # batched together
    e = ServeEngine(m, params, n_slots=2, max_ctx=64)
    rr1 = e.submit(t1, max_new=4)
    rr2 = e.submit(t2, max_new=4)
    both = e.run()
    assert both[rr1] == solo1
    assert both[rr2] == solo2


def test_preemption_swap_roundtrip():
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    # tiny device pool: 6 blocks of 8 tokens; host overflow available
    eng = ServeEngine(m, params, n_slots=2, max_ctx=48,
                      n_device_blocks=6, n_host_blocks=8)
    r1 = eng.submit(list(range(1, 25)), max_new=4)   # 24 toks -> 4 pages
    r2 = eng.submit(list(range(30, 50)), max_new=4)  # 20 toks -> 3 pages
    done = eng.run()
    assert set(done) == {r1, r2}
    assert eng.metrics["preemptions"] >= 1
    # compare r1 against solo run (no preemption)
    solo = ServeEngine(m, params, n_slots=1, max_ctx=48)
    rs = solo.submit(list(range(1, 25)), max_new=4)
    assert solo.run()[rs] == done[r1]


def test_growth_pause_resume_without_host_tier():
    """On-demand growth under a tight pool with NO host tier: a slot
    whose page growth fails must PAUSE (not decode into the scratch
    block) and resume once blocks free up, with outputs identical to
    uncontended solo runs."""
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    # pool of 3 pages, page_size 8: both prompts take 1 page each; at
    # ctx 8 both want a second page -> only one can grow, the other
    # pauses until r1 finishes and frees its blocks
    eng = ServeEngine(m, params, n_slots=2, max_ctx=64,
                      n_device_blocks=3, n_host_blocks=0)
    t1, t2 = list(range(1, 9)), list(range(30, 38))
    r1 = eng.submit(t1, max_new=6)
    r2 = eng.submit(t2, max_new=12)
    done = eng.run()
    assert set(done) == {r1, r2}
    for toks, max_new, rid in [(t1, 6, r1), (t2, 12, r2)]:
        solo = ServeEngine(m, params, n_slots=1, max_ctx=64)
        rs = solo.submit(list(toks), max_new=max_new)
        assert solo.run()[rs] == done[rid], rid


def test_growth_livelock_raises_out_of_blocks():
    """If every resident needs pages and nothing can be grown or
    preempted, the engine must raise (pausing everyone would spin
    forever) rather than silently corrupt KV in the scratch block."""
    from repro.paging.pool import OutOfBlocks

    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    eng = ServeEngine(m, params, n_slots=1, max_ctx=64,
                      n_device_blocks=2, n_host_blocks=0)
    eng.submit(list(range(1, 9)), max_new=40)   # needs 6 pages, pool=2
    with pytest.raises(OutOfBlocks):
        eng.run()


def test_fmmu_map_hit_stats_progress():
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    eng = ServeEngine(m, params, n_slots=2, max_ctx=32)
    rid = eng.submit(list(range(1, 17)), max_new=4)
    eng.run()
    st = eng.kvm.hit_stats()
    # the incremental table means the hot path performs zero lookups:
    # only UPDATE lanes ran, so the probe counters must NOT have moved
    assert st["updates"] > 0
    assert st["hits"] + st["misses"] == 0
    # the probe path itself is still live (oracle retranslation uses it)
    eng.kvm.retranslate_tables()
    st = eng.kvm.hit_stats()
    assert st["hits"] + st["misses"] > 0


def _pool_state(eng):
    return (list(eng.kvm.pool._free_dev), list(eng.kvm.pool._free_host),
            {s: list(p) for s, p in eng.kvm.seq_pages.items()})


@pytest.mark.slow
def test_macro_step_equivalence_bitwise():
    """ISSUE-3 equivalence: K-step fused decode produces bit-identical
    tokens, block tables, and pool state to K single steps — including
    slots crossing page boundaries mid-macro-step (7-token prompts,
    page 8: the crossing lands inside a scan) and a slot finishing
    mid-scan (max_new=7 with K=4 retires at scan step 3). Marked slow:
    CI fast lane skips it; the full lane and local tier-1 run it."""
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    t1, t2 = list(range(1, 8)), list(range(50, 73))

    def run(macro_k):
        eng = ServeEngine(m, params, n_slots=2, max_ctx=64,
                          macro_k=macro_k)
        r1 = eng.submit(t1, max_new=10)     # budget > K: simple variant
        r2 = eng.submit(t2, max_new=7)      # finishes mid-scan: full
        done = eng.run()
        return done[r1], done[r2], eng

    a1, a2, es = run(0)
    b1, b2, em = run(4)
    assert em.metrics["macro_steps"] > 0
    assert (a1, a2) == (b1, b2)
    assert _pool_state(es) == _pool_state(em)
    np.testing.assert_array_equal(np.asarray(es.kvm.block_tables()),
                                  np.asarray(em.kvm.block_tables()))
    # device allocator mirror agrees with the host pool once the
    # (lazily deferred) sync of the final host-side frees runs
    em.kvm.sync_allocator()
    st = em.kvm.state
    assert int(st.free_n) == em.kvm.pool.free_device
    np.testing.assert_array_equal(
        np.asarray(st.free_stack[:int(st.free_n)]),
        np.asarray(em.kvm.pool._free_dev, np.int32))


def test_macro_pool_dry_engages_single_step_fallback():
    """ISSUE-3: when the device pool cannot cover a worst-case K-step
    growth, the engine must fall back to single-step mode (whose
    preempt/pause machinery needs the host) BEFORE the in-graph
    allocator can run dry — pause semantics preserved, outputs equal
    the uncontended solo runs, and the macro path reports fallbacks."""
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    t1, t2 = list(range(1, 9)), list(range(30, 38))

    eng = ServeEngine(m, params, n_slots=2, max_ctx=64,
                      n_device_blocks=3, n_host_blocks=0, macro_k=4)
    r1 = eng.submit(t1, max_new=6)
    r2 = eng.submit(t2, max_new=12)
    done = eng.run()
    assert set(done) == {r1, r2}
    assert eng.metrics["macro_fallbacks"] > 0
    assert not bool(np.asarray(eng.kvm.state.oob)), \
        "in-graph allocator ran dry: proactive check failed"
    for toks, max_new, rid in [(t1, 6, r1), (t2, 12, r2)]:
        solo = ServeEngine(m, params, n_slots=1, max_ctx=64)
        rs = solo.submit(list(toks), max_new=max_new)
        assert solo.run()[rs] == done[rid], rid


def test_macro_steady_state_one_dispatch_one_sync_per_k_steps():
    """ISSUE-3 acceptance: steady-state fused decode performs exactly
    ONE host dispatch and ONE device->host sync per K steps, zero host
    -side fused map calls, zero full-map retranslations, zero
    allocator re-syncs, and no re-tracing of the translate pipeline."""
    from repro.core.fmmu import batch as B
    from repro.paging import kv_manager as KM
    from repro.serving import engine as E

    K = 8
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    eng = ServeEngine(m, params, n_slots=2, max_ctx=256, macro_k=K)
    eng.min_page_bucket = 32       # pin: a bucket crossing re-traces
    eng.submit(list(range(1, 9)), max_new=10 ** 6)
    eng.submit(list(range(20, 28)), max_new=10 ** 6)
    done: dict = {}
    eng.step(done)                     # admission + prefill + 1st macro
    for _ in range(3):                 # settle: trace the scan variants
        eng.step(done)
    for _ in range(6):
        d0, s0 = E.MACRO_DISPATCHES[0], E.HOST_SYNCS[0]
        x0, f0, a0 = (KM.XLATE_CALLS[0], KM.FULL_TABLE_CALLS[0],
                      KM.ALLOC_SYNCS[0])
        p0 = B.PROBE_TRACES[0]
        n0 = eng.metrics["decode_steps"]
        eng.step(done)
        assert eng.metrics["decode_steps"] - n0 == K
        assert E.MACRO_DISPATCHES[0] - d0 == 1
        assert E.HOST_SYNCS[0] - s0 == 1
        assert KM.XLATE_CALLS[0] - x0 == 0
        assert KM.FULL_TABLE_CALLS[0] - f0 == 0
        assert KM.ALLOC_SYNCS[0] - a0 == 0
        assert B.PROBE_TRACES[0] - p0 == 0, "macro scan re-traced"
    assert eng.metrics["macro_fallbacks"] == 0


def test_oversubscribed_zero_fallbacks_counter_enforced():
    """ISSUE-4 acceptance: under ~2x oversubscription (4 live
    sequences vs a device pool sized for ~2, host tier holding the
    overflow) the non-blocking swap pipeline keeps EVERY decode round
    on the fused macro path — zero single-step fallbacks, asserted
    from counters, not timings — while swap traffic is nonzero and
    every output is bit-identical to an uncontended solo run."""
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    # each seq: 8-token prompt + 24 new = 4 pages; 4 seqs = 16 pages
    # of working set vs 10 device blocks (~2x); host absorbs the rest
    eng = ServeEngine(m, params, n_slots=4, max_ctx=64,
                      n_device_blocks=10, n_host_blocks=24, macro_k=4,
                      swap_patience=2)
    prompts = [list(range(1 + 20 * i, 9 + 20 * i)) for i in range(4)]
    rids = [eng.submit(p, max_new=24) for p in prompts]
    done: dict = {}
    swapped_slots = set()
    while eng.step(done):
        for r in eng.active.values():
            if not eng.kvm.is_resident(r.slot):
                swapped_slots.add(r.slot)
    assert set(done) == set(rids)
    assert eng.metrics["macro_fallbacks"] == 0, \
        "oversubscription dropped the engine out of the macro path"
    assert eng.metrics["swaps_out"] > 0 and eng.metrics["swaps_in"] > 0
    assert len(swapped_slots) >= 2, "rotation never swapped anyone"
    st = eng.kvm.hit_stats()
    assert st["swaps_out"] > 0 and st["swaps_in"] > 0
    # a swap-pending slot that resumed must be bit-identical to a solo
    # run that never swapped (the pipeline moved its KV bytes exactly)
    for p, rid in zip(prompts, rids):
        solo = ServeEngine(m, params, n_slots=1, max_ctx=64)
        rs = solo.submit(list(p), max_new=24)
        assert solo.run()[rs] == done[rid], rid


def test_nonblocking_false_restores_fallback_behavior():
    """The PR-3 baseline knob: with nonblocking_swap=False the same
    oversubscribed workload must fall back to single-step mode (the
    behavior serve_bench's oversub_fallback mode times) and still
    produce identical outputs — the pipelines differ in scheduling,
    never in results."""
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))

    def run(nonblocking):
        eng = ServeEngine(m, params, n_slots=4, max_ctx=64,
                          n_device_blocks=10, n_host_blocks=24,
                          macro_k=4, swap_patience=2,
                          nonblocking_swap=nonblocking)
        rids = [eng.submit(list(range(1 + 20 * i, 9 + 20 * i)),
                           max_new=24) for i in range(4)]
        done = eng.run()
        return [done[r] for r in rids], eng

    outs_nb, eng_nb = run(True)
    outs_fb, eng_fb = run(False)
    assert outs_nb == outs_fb
    assert eng_nb.metrics["macro_fallbacks"] == 0
    assert eng_fb.metrics["macro_fallbacks"] > 0, \
        "PR-3 baseline should have fallen back under pressure"


def test_chunked_admission_token_budget():
    """Continuous-batching admission: a prompt longer than the
    per-round token budget is chunk-prefilled (first chunk through the
    prefill kernel, remainder streamed through the decode path as
    forced lanes) and the outputs are identical to unbudgeted
    admission — on both the single-step and macro paths."""
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    long_p = [int(t) for t in np.asarray(jax.random.randint(
        jax.random.key(3), (30,), 1, cfg.vocab_size))]
    short_p = list(range(40, 48))

    def run(admit_tokens, macro_k):
        eng = ServeEngine(m, params, n_slots=2, max_ctx=64,
                          macro_k=macro_k, admit_tokens=admit_tokens)
        r1 = eng.submit(list(long_p), max_new=6)
        r2 = eng.submit(list(short_p), max_new=6)
        d = eng.run()
        return d[r1], d[r2], eng

    ref1, ref2, eng0 = run(None, 0)
    assert eng0.metrics["chunked_prefills"] == 0
    for admit, mk in [(12, 0), (12, 4), (5, 4)]:
        b1, b2, eng = run(admit, mk)
        assert (b1, b2) == (ref1, ref2), (admit, mk)
        assert eng.metrics["chunked_prefills"] >= 1, (admit, mk)
        if mk:
            assert eng.metrics["macro_fallbacks"] == 0, \
                "chunked admission must ride the macro path"


def test_steady_state_decode_zero_full_map_translations():
    """ISSUE-2 trace-count assertion: a steady-state decode step performs
    ZERO full-map retranslations and at most ONE fused map call (the
    batched page-growth `_xlate`; zero on non-boundary steps), and does
    not re-trace the translate pipeline."""
    from repro.core.fmmu import batch as B
    from repro.paging import kv_manager as KM

    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    eng = ServeEngine(m, params, n_slots=2, max_ctx=64)
    eng.submit(list(range(1, 9)), max_new=40)
    eng.submit(list(range(20, 28)), max_new=40)
    done: dict = {}
    eng.step(done)                      # admission + prefill + 1st step
    for _ in range(3):                  # settle: trace the decode shapes
        eng.step(done)
    boundary_seen = False
    for _ in range(10):
        f0, x0, p0 = (KM.FULL_TABLE_CALLS[0], KM.XLATE_CALLS[0],
                      B.PROBE_TRACES[0])
        pre = {r.slot: len(eng.kvm.seq_pages[r.slot])
               for r in eng.active.values()}
        eng.step(done)
        grew = any(len(eng.kvm.seq_pages.get(s, [])) != n
                   for s, n in pre.items())
        assert KM.FULL_TABLE_CALLS[0] - f0 == 0
        assert KM.XLATE_CALLS[0] - x0 == (1 if grew else 0)
        boundary_seen = boundary_seen or grew
        if not grew:                    # steady state: nothing re-traced
            assert B.PROBE_TRACES[0] - p0 == 0
    assert boundary_seen, "bench window never crossed a page boundary"
    assert eng.metrics["decode_steps"] >= 14
