"""Checkpointing: roundtrip, async, atomic commit, corruption detection,
retention, resume-continues-identically, elastic restore; plus the
serving-side state round-trip (ISSUE 7): ServingMapState + BlockPool
through journal snapshot/replay, bit-identical."""
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, smoke_config
from repro.data.pipeline import DataConfig, data_iter
from repro.models import Runtime, build_model
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.train_loop import TrainerConfig, train

RT = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
             remat="none")


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


def _specs():
    return {"a": P(None, "model"), "b": {"c": P(None,)}}


def test_roundtrip_and_crc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = _tree()
        res = mgr.save(3, tree, _specs())
        assert res.step == 3
        got, step = mgr.restore(tree)
        assert step == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(a, b)


def test_corruption_detected():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _tree(), _specs())
        path = os.path.join(d, "step_000000001", "arrays", "00000.npy")
        arr = np.load(path)
        arr[0] += 1
        np.save(path, arr)
        with pytest.raises(IOError):
            mgr.restore(_tree())


def test_async_save_and_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_last=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(), _specs(), async_=True)
            mgr.wait()
        assert mgr.all_steps() == [3, 4]


def test_atomic_commit_no_tmp_left():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(9, _tree(), _specs())
        assert not [n for n in os.listdir(d) if n.endswith(".tmp")]


def test_resume_continues_identically():
    """train(60) == train(30) -> restore -> train(30 more)."""
    m = build_model(smoke_config(get_arch("llama3.2-1b")), RT)
    dcfg = DataConfig(vocab_size=m.cfg.vocab_size, seq_len=32,
                      global_batch=4, pack=False)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=5, decay_steps=60)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        state_a, _ = train(m, data_iter(dcfg, prefetch=False), ocfg,
                           TrainerConfig(total_steps=20, ckpt_every=0,
                                         ckpt_dir=d1))
        # interrupted run: 10 steps, checkpoint, then "restart"
        train(m, data_iter(dcfg, prefetch=False), ocfg,
              TrainerConfig(total_steps=10, ckpt_every=10, ckpt_dir=d2,
                            async_ckpt=False))
        it = data_iter(dcfg, prefetch=False)
        for _ in range(10):   # data stream replays deterministically
            next(it)
        state_b, _ = train(m, it, ocfg,
                           TrainerConfig(total_steps=20, ckpt_every=0,
                                         ckpt_dir=d2))
        for a, b in zip(jax.tree.leaves(state_a.params),
                        jax.tree.leaves(state_b.params)):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_elastic_restore_trivial_mesh():
    """Save and restore with a ParallelCtx: shardings rebuilt from the
    manifest's logical specs (full multi-device path exercised in
    test_distributed.py subprocesses)."""
    from repro.parallel import trivial_ctx
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(5, _tree(), _specs())
        got, step = mgr.restore(_tree(), ctx=trivial_ctx())
        assert step == 5
        for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(got)):
            np.testing.assert_array_equal(a, b)
        with open(os.path.join(d, "step_000000005", "manifest.json")) as f:
            man = json.load(f)
        assert man["leaves"][0]["spec"] == [None, "model"]


# ---------------------------------------------------------------------
# serving-state round-trip (ISSUE 7, satellite): the crash-consistency
# plane's snapshot + replay must restore the serving map and the block
# allocator BIT-exactly — dense block table, per-channel free-list
# ORDER (the device-mirror contract makes order part of the state),
# retirement set and per-channel counters, and allocator stats.
# ---------------------------------------------------------------------

def _kvm_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.block_tables()),
                                  np.asarray(b.block_tables()))
    assert {s: list(p) for s, p in a.seq_pages.items()} == \
           {s: list(p) for s, p in b.seq_pages.items()}
    assert a._host_pages == b._host_pages
    assert a.pool.state_dict() == b.pool.state_dict()


@pytest.mark.recovery
@pytest.mark.parametrize("channels", (1, 2, 4))
def test_serving_map_pool_roundtrip(channels):
    """Drive allocation / growth / free / swap / retirement traffic on
    a journaled KVPageManager, then rebuild a fresh manager two ways —
    records-only replay from the base snapshot, and latest-snapshot +
    tail replay — and require bit-identical state both times."""
    from repro.core import journal as jl
    from repro.paging.kv_manager import KVPageManager

    def fresh():
        return KVPageManager(n_slots=4, max_pages=6, n_device_blocks=16,
                             n_host_blocks=8, channels=channels)

    with tempfile.TemporaryDirectory() as d:
        kvm = fresh()
        j = jl.Journal(d)
        kvm.journal = j
        j.snapshot(kvm.snapshot_state())          # base snapshot (seq 0)
        kvm.new_seq(0, 3)
        kvm.new_seq(1, 2)
        kvm.extend_seqs({0: 2, 1: 1})
        kvm.new_seq(2, 4)
        kvm.free_seq(1)                            # perturbs list order
        # map-only retirement of a mapped block: replacement from the
        # same channel, bad block permanently out of service
        kvm.retire_bad_blocks([(0 * kvm.max_pages + 1,
                                kvm.seq_pages[0][1])])
        # swap one sequence out and back: host-tier ids + swap stats
        width = kvm.pool.n_device + kvm.pool.n_host + 1
        pools = [jnp.arange(width * 4.0).reshape(width, 4)]
        pools, n = kvm.swap_out(2, pools)
        assert n == 4

        # (a) records-only replay from the base snapshot
        rec = jl.replay(d)
        assert rec.snap_seq == 0 and rec.replayed == j.records
        k2 = fresh()
        k2.restore_mapping(rec)
        _kvm_equal(kvm, k2)

        # (b) exhaustion counters are snapshot-granular (no record on
        # exception paths): bump one, snapshot, more traffic, replay
        # from the LATEST snapshot + tail
        kvm.pool.note_exhausted(0)
        j.snapshot(kvm.snapshot_state())
        pools, n = kvm.swap_in(2, pools)
        assert n == 4
        kvm.extend_seqs({0: 1})
        rec = jl.replay(d)
        assert rec.snap_seq > 0 and rec.replayed < j.records
        k3 = fresh()
        k3.restore_mapping(rec)
        _kvm_equal(kvm, k3)
        assert k3.pool.exhausted_ch[0] == 1
        assert k3.pool.stats.retired == 1
        assert k3.pool.is_retired(k3.pool.state_dict()["retired"][0])
        j.close()
