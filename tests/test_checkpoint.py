"""Checkpointing: roundtrip, async, atomic commit, corruption detection,
retention, resume-continues-identically, elastic restore."""
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, smoke_config
from repro.data.pipeline import DataConfig, data_iter
from repro.models import Runtime, build_model
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.train_loop import TrainerConfig, train

RT = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
             remat="none")


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


def _specs():
    return {"a": P(None, "model"), "b": {"c": P(None,)}}


def test_roundtrip_and_crc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = _tree()
        res = mgr.save(3, tree, _specs())
        assert res.step == 3
        got, step = mgr.restore(tree)
        assert step == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(a, b)


def test_corruption_detected():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _tree(), _specs())
        path = os.path.join(d, "step_000000001", "arrays", "00000.npy")
        arr = np.load(path)
        arr[0] += 1
        np.save(path, arr)
        with pytest.raises(IOError):
            mgr.restore(_tree())


def test_async_save_and_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_last=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(), _specs(), async_=True)
            mgr.wait()
        assert mgr.all_steps() == [3, 4]


def test_atomic_commit_no_tmp_left():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(9, _tree(), _specs())
        assert not [n for n in os.listdir(d) if n.endswith(".tmp")]


def test_resume_continues_identically():
    """train(60) == train(30) -> restore -> train(30 more)."""
    m = build_model(smoke_config(get_arch("llama3.2-1b")), RT)
    dcfg = DataConfig(vocab_size=m.cfg.vocab_size, seq_len=32,
                      global_batch=4, pack=False)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=5, decay_steps=60)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        state_a, _ = train(m, data_iter(dcfg, prefetch=False), ocfg,
                           TrainerConfig(total_steps=20, ckpt_every=0,
                                         ckpt_dir=d1))
        # interrupted run: 10 steps, checkpoint, then "restart"
        train(m, data_iter(dcfg, prefetch=False), ocfg,
              TrainerConfig(total_steps=10, ckpt_every=10, ckpt_dir=d2,
                            async_ckpt=False))
        it = data_iter(dcfg, prefetch=False)
        for _ in range(10):   # data stream replays deterministically
            next(it)
        state_b, _ = train(m, it, ocfg,
                           TrainerConfig(total_steps=20, ckpt_every=0,
                                         ckpt_dir=d2))
        for a, b in zip(jax.tree.leaves(state_a.params),
                        jax.tree.leaves(state_b.params)):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_elastic_restore_trivial_mesh():
    """Save and restore with a ParallelCtx: shardings rebuilt from the
    manifest's logical specs (full multi-device path exercised in
    test_distributed.py subprocesses)."""
    from repro.parallel import trivial_ctx
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(5, _tree(), _specs())
        got, step = mgr.restore(_tree(), ctx=trivial_ctx())
        assert step == 5
        for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(got)):
            np.testing.assert_array_equal(a, b)
        with open(os.path.join(d, "step_000000005", "manifest.json")) as f:
            man = json.load(f)
        assert man["leaves"][0]["spec"] == [None, "model"]
