"""Multi-device semantics via subprocesses with 8 virtual CPU devices
(tests otherwise see 1 device; the dry-run owns the 512-device config).

Covers: sharded train step == single-device math, MoE expert parallelism
across the model axis, elastic checkpoint restore 8 -> 4 devices,
compressed-psum correctness, sequence-parallel paged-attention combine.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 900) -> dict:
    """Run `body` in a subprocess with N virtual devices; the body must
    print a final JSON line."""
    prog = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    assert jax.device_count() == {devices}
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stderr[-3000:]}"
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    out = run_sub("""
    from repro.configs import get_arch, smoke_config
    from repro.models import Runtime, build_model
    from repro.parallel.sharding import ParallelCtx, make_mesh
    from repro.parallel import trivial_ctx
    from repro.data.pipeline import DataConfig, make_batch

    cfg = smoke_config(get_arch("llama3.2-1b"))
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 remat="none")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                      global_batch=8, pack=False)
    batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 0).items()}

    m1 = build_model(cfg, rt, trivial_ctx())
    p = m1.init(jax.random.key(0))
    l1, _ = jax.jit(m1.loss_fn)(p, batch)

    ctx = ParallelCtx(mesh=make_mesh((4, 2), ("data", "model")))
    m2 = build_model(cfg, rt, ctx)
    ps = jax.device_put(p, m2.param_shardings(p))
    bs = jax.device_put(batch, {k: ctx.sharding(P("data"), v.shape[:1])
                                for k, v in batch.items()})
    with ctx.mesh:
        l2, _ = jax.jit(m2.loss_fn)(ps, bs)
    print(json.dumps({"l1": float(l1), "l2": float(l2)}))
    """)
    assert abs(out["l1"] - out["l2"]) < 2e-4, out


def test_moe_expert_parallel_matches_dense():
    out = run_sub("""
    from repro.configs import get_arch, smoke_config
    from repro.models import Runtime
    from repro.models import moe as moe_mod
    from repro.parallel.sharding import ParallelCtx, make_mesh

    cfg = smoke_config(get_arch("dbrx-132b"))
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 capacity_factor=100.0)
    params = moe_mod.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    ctx = ParallelCtx(mesh=make_mesh((2, 4), ("data", "model")))
    with ctx.mesh:
        out, aux = jax.jit(
            lambda p, xx: moe_mod.apply_moe(p, xx, cfg, rt, ctx))(params, x)
    ref = moe_mod.apply_moe_dense_ref(params, x, cfg, rt)
    print(json.dumps({"err": float(jnp.abs(out - ref).max())}))
    """)
    assert out["err"] < 1e-4, out


def test_elastic_restore_8_to_4_devices(tmp_path):
    d = str(tmp_path)
    out = run_sub(f"""
    from repro.training.checkpoint import CheckpointManager
    from repro.parallel.sharding import ParallelCtx, make_mesh
    tree = {{"w": jnp.arange(64.0).reshape(8, 8),
             "m": jnp.arange(32.0).reshape(4, 8)}}
    specs = {{"w": P("data", "model"), "m": P(None, "model")}}
    ctx = ParallelCtx(mesh=make_mesh((4, 2), ("data", "model")))
    sharded = jax.device_put(
        tree, ctx.tree_shardings(specs, tree))
    mgr = CheckpointManager({d!r})
    mgr.save(1, sharded, specs)
    print(json.dumps({{"saved": True}}))
    """, devices=8)
    assert out["saved"]
    out2 = run_sub(f"""
    from repro.training.checkpoint import CheckpointManager
    from repro.training.elastic import make_ctx
    tree_like = {{"w": jnp.zeros((8, 8)), "m": jnp.zeros((4, 8))}}
    ctx = make_ctx(4, model_parallel=2)       # "lost" half the fleet
    mgr = CheckpointManager({d!r})
    got, step = mgr.restore(tree_like, ctx=ctx)
    ok = bool((np.asarray(got["w"]) == np.arange(64.0).reshape(8, 8)).all())
    shard_shape = got["w"].sharding.shard_shape(got["w"].shape)
    print(json.dumps({{"ok": ok, "step": step,
                       "shard_shape": list(shard_shape)}}))
    """, devices=4)
    assert out2["ok"] and out2["step"] == 1
    assert out2["shard_shape"] == [4, 4]   # 2x2 mesh now


def test_compressed_psum_error_feedback():
    out = run_sub("""
    from repro.parallel.collectives import (compressed_psum,
                                            init_error_feedback)
    from repro.parallel.sharding import make_mesh
    mesh = make_mesh((4,), ("pod",))
    g = jax.random.normal(jax.random.key(0), (4, 256))

    def body(gg, ee):
        return compressed_psum(gg, "pod", ee)

    from repro.parallel.sharding import shard_map
    fn = shard_map(body, mesh=mesh, in_specs=(P("pod"), P("pod")),
                   out_specs=(P(None), P("pod")), check_vma=False)
    err = jnp.zeros((4, 256))
    # shard_map with in_specs P('pod') splits axis 0: each shard [1,256]
    total, err2 = fn(g, err)
    want = g.sum(axis=0, keepdims=True)
    rel = float(jnp.abs(total[:1] - want).max() / jnp.abs(want).max())
    # with error feedback, two successive reductions of the same gradient
    # have bounded bias: second-round residual grows smaller
    total2, err3 = fn(g, err2)
    r1 = float(jnp.abs(err2).mean())
    print(json.dumps({"rel": rel, "resid": r1}))
    """)
    assert out["rel"] < 0.05, out
    assert out["resid"] < 0.05


def test_sequence_parallel_paged_decode_combine():
    """Pages striped across the data axis; per-shard partial attention +
    cross-shard flash-decoding combine == single-shot attention."""
    out = run_sub("""
    from repro.kernels import ref
    from repro.parallel.sharding import make_mesh
    b, h, d, page, maxp = 2, 4, 16, 8, 8
    nb = b * maxp
    k = jax.random.key(0)
    q = jax.random.normal(jax.random.fold_in(k, 1), (b, h, d))
    kp = jax.random.normal(jax.random.fold_in(k, 2), (nb, page, 2, d))
    vp = jax.random.normal(jax.random.fold_in(k, 3), (nb, page, 2, d))
    table = jnp.arange(nb).reshape(b, maxp)
    ctx = jnp.array([61, 64])
    want = ref.paged_attention_naive(q, kp, vp, table, ctx)

    mesh = make_mesh((4,), ("data",))
    pages_per_shard = maxp // 4

    def shard_fn(q, kp, vp, table, ctxl):
        # table [b, maxp/4] local page ids; ctx clipped to local range
        i = jax.lax.axis_index("data")
        lo = i * pages_per_shard * page
        local_ctx = jnp.clip(ctxl - lo, 0, pages_per_shard * page)
        o, (m, l) = ref.paged_attention_naive(
            q, kp, vp, table, local_ctx, return_stats=True)
        outs = jax.lax.all_gather(o, "data")
        ms = jax.lax.all_gather(m, "data")
        ls = jax.lax.all_gather(l, "data")
        return ref.combine_partial_attention(outs, ms, ls)

    from repro.parallel.sharding import shard_map
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(None), P(None), P(None), P(None, "data"), P(None)),
        out_specs=P(None), check_vma=False)
    got = fn(q, kp, vp, table, ctx)
    print(json.dumps({"err": float(jnp.abs(got - want).max())}))
    """)
    assert out["err"] < 1e-5, out


def test_striped_paged_decode_attention_exact():
    """Runtime.shard_kv_pool_pages: range-partitioned pools + page-mask
    partial attention + flash-decoding combine == plain paged decode."""
    out = run_sub("""
    from repro.configs import get_arch, smoke_config
    from repro.models import Runtime
    from repro.models import attention
    from repro.parallel.sharding import ParallelCtx, make_mesh
    cfg = smoke_config(get_arch('llama3.2-1b'))
    ctx = ParallelCtx(mesh=make_mesh((2, 4), ('data', 'model')))
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 page_size=8)
    params = attention.init_attention(jax.random.key(0), cfg, jnp.float32)
    NB, page, maxp = 64, 8, 8
    pool_k = jax.random.normal(jax.random.key(1),
                               (NB, page, cfg.n_kv_heads, cfg.head_dim))
    pool_v = jax.random.normal(jax.random.key(2),
                               (NB, page, cfg.n_kv_heads, cfg.head_dim))
    errs = {}
    # batch=1: pages striped across every chip, combine over all axes
    table = jax.random.permutation(jax.random.key(3),
                                   jnp.arange(NB))[:maxp].reshape(1, maxp)
    ctxl = jnp.array([13])
    x = 0.1 * jax.random.normal(jax.random.key(4), (1, cfg.d_model))
    with ctx.mesh:
        a1, b1, c1 = jax.jit(lambda: attention.attn_decode_paged(
            params, x, cfg, rt, pool_k=pool_k, pool_v=pool_v,
            block_table=table, ctx_lens=ctxl))()
        a2, b2, c2 = jax.jit(lambda: attention.attn_decode_paged_striped(
            params, x, cfg, rt, ctx, pool_k=pool_k, pool_v=pool_v,
            block_table=table, ctx_lens=ctxl))()
    errs['b1_y'] = float(jnp.abs(a1 - a2).max())
    errs['b1_pool'] = float(jnp.abs(b1 - b2).max())
    # batch=4: data-local allocation, combine over model only
    t0 = jax.random.permutation(jax.random.key(6), jnp.arange(32))[:16]
    t1 = 32 + jax.random.permutation(jax.random.key(7), jnp.arange(32))[:16]
    tb = jnp.concatenate([t0.reshape(2, 8), t1.reshape(2, 8)])
    cl = jnp.array([13, 30, 47, 62])
    xb = 0.1 * jax.random.normal(jax.random.key(8), (4, cfg.d_model))
    with ctx.mesh:
        a1, b1, c1 = jax.jit(lambda: attention.attn_decode_paged(
            params, xb, cfg, rt, pool_k=pool_k, pool_v=pool_v,
            block_table=tb, ctx_lens=cl))()
        a2, b2, c2 = jax.jit(lambda: attention.attn_decode_paged_striped(
            params, xb, cfg, rt, ctx, pool_k=pool_k, pool_v=pool_v,
            block_table=tb, ctx_lens=cl))()
    errs['b4_y'] = float(jnp.abs(a1 - a2).max())
    errs['b4_pool'] = float(jnp.abs(b1 - b2).max())
    print(json.dumps(errs))
    """)
    assert all(v < 1e-5 for v in out.values()), out
