"""Roofline extraction: HLO collective parser, depth extrapolation,
model-FLOPs accounting."""
import pytest

from repro.configs import SHAPES, get_arch
from repro.launch import roofline as rl


HLO = """
HloModule jit_step, entry_computation_layout={...}
  %x.1 = bf16[2048,1024]{1,0} all-reduce(%fusion.3), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %y = f32[512]{0} all-gather(%p0), channel_id=2, replica_groups={{0,1,2,3}}, dimensions={0}
  %z = (bf16[64,64]{1,0}, bf16[64,64]{1,0}) all-to-all(%a, %b), replica_groups={{0,1}}
  %w = bf16[128]{0} collective-permute(%c), source_target_pairs={{0,1}}
  %s = f32[2048,1024]{1,0} reduce-scatter(%d), channel_id=9, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %done = bf16[8]{0} all-reduce-done(%start)
"""


def test_collective_parser_kinds_and_bytes():
    out = rl.collective_bytes(HLO)
    c = out["counts"]
    assert c["all-reduce"] == 1          # -done line skipped
    assert c["all-gather"] == 1
    assert c["all-to-all"] == 1
    assert c["collective-permute"] == 1
    assert c["reduce-scatter"] == 1
    b = out["bytes_by_kind"]
    assert b["all-reduce"] == 2048 * 1024 * 2
    # all-gather operand = result / group(4)
    assert b["all-gather"] == 512 * 4 // 4
    # tuple result: both halves counted
    assert b["all-to-all"] == 2 * 64 * 64 * 2
    assert b["collective-permute"] == 128 * 2
    assert b["reduce-scatter"] == 2048 * 1024 * 4


def test_extrapolation_linear_exact():
    c1 = {"flops": 100.0, "bytes accessed": 50.0}
    c2 = {"flops": 160.0, "bytes accessed": 70.0}
    out = rl.extrapolate(c1, c2, 10)
    assert out["flops"] == 100 + 9 * 60
    assert out["bytes accessed"] == 50 + 9 * 20


def test_analyze_dominant_and_ratio():
    r = rl.analyze({"flops": 197e12, "bytes accessed": 819e9 * 2},
                   {"total_bytes": 50e9 * 0.5},
                   n_devices=4, model_flops_global=197e12 * 4 * 0.9)
    assert r.dominant == "memory"
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert abs(r.collective_s - 0.5) < 1e-9
    assert abs(r.useful_ratio - 0.9) < 1e-9


def test_model_flops_train_is_6nd():
    cfg = get_arch("llama3.2-1b")
    shape = SHAPES["train_4k"]
    _, active = cfg.count_params()
    want = 6.0 * active * shape.seq_len * shape.global_batch
    assert rl.model_flops(cfg, shape) == want


def test_model_flops_decode_includes_kv_scan():
    cfg = get_arch("qwen2-72b")
    shape = SHAPES["decode_32k"]
    got = rl.model_flops(cfg, shape)
    _, active = cfg.count_params()
    fwd = 2.0 * active * shape.global_batch
    assert got > fwd  # attention-over-history term present
    attn = got - fwd
    # 4 * layers * H * hd * ctx * batch
    want = (4 * cfg.n_layers * cfg.n_heads * cfg.head_dim
            * shape.seq_len * shape.global_batch)
    assert abs(attn - want) / want < 1e-6
