"""Per-architecture smoke tests: reduced same-family config, one
forward/train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, smoke_config, shape_applicable
from repro.models import Runtime, build_model

RT = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32, remat="none")


def _batch(cfg, b=2, s=64, key=0):
    ks = jax.random.split(jax.random.key(key), 8)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
    }
    if cfg.prefix_len:
        batch["prefix_emb"] = 0.02 * jax.random.normal(
            ks[2], (b, cfg.prefix_len, cfg.d_model), jnp.float32)
        total = s + cfg.prefix_len
        batch["positions"] = jnp.broadcast_to(jnp.arange(total)[None], (b, total))
    if cfg.n_enc_layers:
        batch["src_emb"] = 0.02 * jax.random.normal(
            ks[3], (b, 32, cfg.d_model), jnp.float32)
        batch["src_valid"] = jnp.ones((b, 32), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = smoke_config(get_arch(arch))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(m.loss_fn)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    assert 3.0 < float(loss) < 12.0, f"{arch}: implausible init loss {loss}"
    grads = jax.jit(jax.grad(lambda p, b: m.loss_fn(p, b)[0]))(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_smoke(arch):
    cfg = smoke_config(get_arch(arch))
    m = build_model(cfg, RT)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg)
    batch.pop("labels")
    logits, caches = jax.jit(m.prefill)(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert caches is not None


def test_param_specs_match_structure():
    for arch in ARCHS:
        cfg = smoke_config(get_arch(arch))
        m = build_model(cfg, RT)
        shapes = m.param_shapes()
        specs = m.specs()
        t1 = jax.tree.structure(shapes)
        t2 = jax.tree.structure(
            specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
        assert t1 == t2, f"{arch}: spec tree != param tree"
        # every spec dim must be valid for its param rank
        flat_shapes = jax.tree.leaves(shapes)
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
        for sh, sp in zip(flat_shapes, flat_specs):
            assert len(sp) <= len(sh.shape), f"{arch}: spec {sp} rank > {sh.shape}"


def test_full_configs_match_published_sizes():
    expect = {
        "jamba-1.5-large-398b": 398, "qwen2-72b": 73, "gemma2-9b": 9.2,
        "llama3.2-1b": 1.24, "glm4-9b": 9.4, "dbrx-132b": 132,
        "arctic-480b": 480, "llava-next-mistral-7b": 7.2,
        "mamba2-1.3b": 1.3, "seamless-m4t-large-v2": 2.0,
    }
    for name, bn in expect.items():
        total, _ = get_arch(name).count_params()
        assert abs(total / 1e9 - bn) / bn < 0.12, (
            f"{name}: {total/1e9:.1f}B vs published ~{bn}B")


def test_shape_applicability_table():
    runnable = [(a.name, s.name) for a, s, ok, _ in
                [(a, s, *shape_applicable(a, s))
                 for a in ARCHS.values() for s in SHAPES.values()] if ok]
    assert len(runnable) == 32  # 10*4 minus 8 long_500k skips
    assert ("mamba2-1.3b", "long_500k") in runnable
    assert ("jamba-1.5-large-398b", "long_500k") in runnable
    assert ("qwen2-72b", "long_500k") not in runnable
