"""Copy-on-write prefix sharing (ISSUE 10): radix tree unit behavior
(page groups, exact-prefix verification, LRU pruning), the refcount
lane vs a numpy oracle under admit/share/COW/free/GC churn, the
satellite-3 refcount-invariant property test, SHARE/COW journal replay
bit-identity, recovery pin release, the sharing-off jaxpr- and
journal-byte-identity guarantees, and engine-level output
bit-identity with sharing on."""
import collections
import random
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import journal as jl
from repro.core.fmmu import batch as B
from repro.core.fmmu.types import UPDATE, small_geometry
from repro.paging import kv_manager as KM
from repro.paging.kv_manager import KVPageManager
from repro.paging.pool import BlockPool

from _hyp import example, given, settings, st

pytestmark = pytest.mark.prefix

CHANNELS = (1, 2, 4)
PAGE = 2            # tokens per page for the synthetic prompts below

# three fixed prefixes (2 pages each at PAGE=2) over a tiny vocab —
# the property test's admissions draw from these so prefixes collide
PREFIXES = [(1, 2, 3, 4), (1, 2, 9, 9), (5, 6, 7, 8)]


def _kvm(C, n_dev=32, n_host=8, max_pages=8):
    return KVPageManager(n_slots=6, max_pages=max_pages,
                         n_device_blocks=n_dev, n_host_blocks=n_host,
                         channels=C, track_live=True, track_refs=True)


def _map_counts(kvm) -> collections.Counter:
    """Device-tier mapping multiset recomputed from host seq_pages —
    the ground truth for both the _ref dict and the refcnt lane."""
    return collections.Counter(
        b for ps in kvm.seq_pages.values() for b in ps
        if not BlockPool.is_host(b))


def _check_invariants(kvm, ctx=""):
    """Satellite 3's invariants, asserted wholesale:
    - every tracked block's refcount equals its number of mapping
      dlpns (zero only while the tree pins it);
    - every device block is in EXACTLY one of {free, mapped-or-pinned,
      retired};
    - the device refcnt lane mirrors the mapping counts bit-for-bit
      (so COW/free/GC never leave a dangling or phantom ref)."""
    cnt = _map_counts(kvm)
    for b, n in kvm._ref.items():
        assert n == cnt.get(b, 0), (ctx, "ref", b, n, cnt.get(b, 0))
        if n == 0:
            assert b in kvm._pinned, (ctx, "zero-ref unpinned", b)
    for b in kvm._pinned:
        assert b in kvm._ref, (ctx, "pin untracked", b)
    free = {b for ch in kvm.pool._free_dev_ch for b in ch}
    retired = {b for b in kvm.pool._retired if not BlockPool.is_host(b)}
    held = set(cnt) | set(kvm._pinned)
    for b in range(kvm.pool.n_device):
        where = (b in free) + (b in held) + (b in retired)
        assert where == 1, (ctx, "partition", b,
                            b in free, b in held, b in retired)
    want = np.zeros(kvm.pool.n_device, np.int64)
    for b, n in cnt.items():
        want[b] = n
    np.testing.assert_array_equal(kvm.refcounts(), want, err_msg=str(ctx))


def _admit_shared(kvm, slot, tokens):
    """The engine's admission dance at manager level: match, map the
    hit as shared leading pages, register the full prompt path."""
    groups = KVPageManager.page_groups(tokens, PAGE)
    m = kvm.match_prefix(groups)
    kvm.new_seq(slot, len(groups), shared=m)
    kvm.register_prefix(slot, groups)
    return len(m)


# ---------------------------------------------------------------------
# radix tree units
# ---------------------------------------------------------------------
def test_page_groups_and_path_keys():
    """Groups split page-granular with a shareable partial tail; path
    keys chain over the WHOLE prefix (same tail after different heads
    gets different keys)."""
    g = KVPageManager.page_groups([1, 2, 3, 4, 5], 2)
    assert g == [(1, 2), (3, 4), (5,)]
    ka = KVPageManager._path_keys([(1, 2), (3, 4)])
    kb = KVPageManager._path_keys([(9, 9), (3, 4)])
    assert [d for d, _ in ka] == [1, 2]
    assert ka[0] != kb[0] and ka[1] != kb[1]   # chained, not per-page


@pytest.mark.parametrize("C", CHANNELS)
def test_match_register_roundtrip(C):
    """A registered prompt path matches in full; a shorter prompt
    matches its prefix; a diverging prompt matches only the common
    part. Registration is idempotent (first writer wins)."""
    kvm = _kvm(C)
    toks = [1, 2, 3, 4, 5, 6]
    groups = KVPageManager.page_groups(toks, PAGE)
    kvm.new_seq(0, len(groups))
    assert kvm.match_prefix(groups) == []
    n = kvm.register_prefix(0, groups)
    assert n == 3
    assert kvm.register_prefix(0, groups) == 0         # idempotent
    assert kvm.match_prefix(groups) == kvm.seq_pages[0]
    assert kvm.match_prefix(groups[:2]) == kvm.seq_pages[0][:2]
    div = KVPageManager.page_groups([1, 2, 3, 4, 7, 7], PAGE)
    assert kvm.match_prefix(div) == kvm.seq_pages[0][:2]
    _check_invariants(kvm)


def test_match_rejects_hash_collision():
    """A node whose stored exact prefix disagrees with the probe (a
    crc32 collision, simulated white-box) degrades to a MISS at that
    depth — sharing the wrong KV is never possible."""
    kvm = _kvm(1)
    groups = KVPageManager.page_groups([1, 2, 3, 4], PAGE)
    kvm.new_seq(0, 2)
    kvm.register_prefix(0, groups)
    keys = KVPageManager._path_keys(groups)
    b, _ = kvm._nodes[keys[1]]
    kvm._nodes[keys[1]] = (b, ((1, 2), (8, 8)))        # forged prefix
    assert kvm.match_prefix(groups) == kvm.seq_pages[0][:1]


def test_lru_prune_bounds_tree_and_frees_orphans():
    """Eviction walks least-recently-matched first; an unpinned block
    with no mappers goes straight back to the pool, one still mapped
    lingers until its refs drain through the free gate."""
    kvm = _kvm(1)
    a = KVPageManager.page_groups([1, 2, 3, 4], PAGE)
    c = KVPageManager.page_groups([5, 6, 7, 8], PAGE)
    kvm.new_seq(0, 2)
    kvm.register_prefix(0, a)
    kvm.new_seq(1, 2)
    kvm.register_prefix(1, c)
    kvm.match_prefix(a)                  # LRU-touch path a
    free0 = kvm.pool.free_device
    kvm.free_seq(1)                      # c's blocks now pinned-at-0
    assert kvm.pool.free_device == free0  # tree still holds them
    kvm.prefix_max_nodes = 2
    kvm._prune_nodes()                   # evicts c's nodes (cold)
    assert kvm.match_prefix(a) == kvm.seq_pages[0]     # hot path kept
    assert kvm.match_prefix(c) == []
    assert kvm.pool.free_device == free0 + 2           # orphans freed
    _check_invariants(kvm)


# ---------------------------------------------------------------------
# shared admission + refcount lane vs oracle
# ---------------------------------------------------------------------
@pytest.mark.parametrize("C", CHANNELS)
def test_shared_admission_refcounts_match_oracle(C):
    """B admissions of a common prefix map ONE physical block per
    shared page; shared pages program nothing; host _ref and the
    device refcnt lane both equal the mapping count."""
    kvm = _kvm(C)
    common = [1, 2, 3, 4]
    _admit_shared(kvm, 0, common + [10, 11])           # leader
    writes0 = kvm.host_writes
    for i, slot in enumerate((1, 2, 3)):
        hit = _admit_shared(kvm, slot, common + [20 + i, 30 + i])
        assert hit == 2
    assert kvm.host_writes - writes0 == 3      # only the unique tails
    lead = kvm.seq_pages[0][:2]
    for slot in (1, 2, 3):
        assert kvm.seq_pages[slot][:2] == lead         # one block, B maps
    assert kvm._ref[lead[0]] == 4 and kvm._ref[lead[1]] == 4
    _check_invariants(kvm)


@pytest.mark.parametrize("C", CHANNELS)
def test_free_seq_refcount_gate(C):
    """free_seq returns a share-managed block only at zero mapping
    refs and no pin — freeing one mapper leaves the other's pages
    intact; freeing the last mapper of an UNPINNED shared block (a COW
    destination is plain, but a matched block stays pinned) keeps it
    out of the pool until the tree lets go."""
    kvm = _kvm(C)
    common = [1, 2, 3, 4]
    _admit_shared(kvm, 0, common + [10, 11])
    _admit_shared(kvm, 1, common + [20, 21])
    shared = kvm.seq_pages[0][:2]
    free0 = kvm.pool.free_device
    kvm.free_seq(1)
    # slot 1's tail block was pinned by ITS registration: tree-held
    assert kvm.pool.free_device == free0
    assert kvm._ref[shared[0]] == 1
    kvm.free_seq(0)
    # every block of both slots is now pinned-at-zero: pool unchanged
    assert kvm.pool.free_device == free0
    assert all(kvm._ref[b] == 0 for b in shared)
    _check_invariants(kvm)
    kvm.prefix_max_nodes = 0
    kvm._prune_nodes()
    assert kvm.pool.free_device == kvm.pool.n_device   # all home
    assert not kvm._ref and not kvm._pinned
    _check_invariants(kvm)


# ---------------------------------------------------------------------
# copy-on-write relocation
# ---------------------------------------------------------------------
@pytest.mark.parametrize("C", CHANNELS)
def test_cow_relocates_and_drops_ref(C):
    """First divergent write: every shared page at/after the write
    frontier relocates to a private block (KV rows copied
    bit-identically), the shared block's ref drops, and the OTHER
    mapper still reads the original data."""
    kvm = _kvm(C)
    common = [1, 2, 3, 4]
    _admit_shared(kvm, 0, common + [10, 11])
    _admit_shared(kvm, 1, common + [20, 21])
    width = kvm.pool.n_device + kvm.pool.n_host + 1
    pools = [jnp.arange(width * 4.0).reshape(width, 4)]
    rows0 = np.asarray(pools[0])
    shared = list(kvm.seq_pages[0][:2])
    pools, n = kvm.cow_writes({1: 1}, pools, block_axis=0)
    # frontier page 1: pages 1 (shared) and 2 (own pin) relocate;
    # page 0 stays shared below the frontier
    assert n == 2
    assert kvm.seq_pages[1][0] == shared[0]
    assert kvm.seq_pages[1][1] != shared[1]
    assert kvm.seq_pages[0] [:2] == shared             # leader intact
    assert kvm._ref[shared[1]] == 1
    assert kvm.cow_moves == n
    rows = np.asarray(pools[0])
    for old, new in zip(shared[1:] , kvm.seq_pages[1][1:2]):
        np.testing.assert_array_equal(rows[new], rows0[old])
    np.testing.assert_array_equal(rows[shared[1]], rows0[shared[1]])
    _check_invariants(kvm)
    # the relocated pages left the COW trigger set: a second boundary
    # scan at the same frontier finds nothing
    _, n2 = kvm.cow_writes({1: 1})
    assert n2 == 0


def test_cow_stale_lane_skipped():
    """A page remapped BEHIND the host's back (racing commit) fails
    the CondUpdate guard: the lane is skipped, its unused destination
    returns to the free list, and the mapping is left alone — the GC
    walk's stale-lane discipline, verbatim."""
    kvm = _kvm(1)
    common = [1, 2, 3, 4]
    _admit_shared(kvm, 0, common + [10, 11])
    _admit_shared(kvm, 1, common + [20, 21])
    old = kvm.seq_pages[1][1]
    # remap slot 1 page 1 via a raw fused UPDATE the host dicts never
    # see: the _shared entry now points at a dead mapping
    dl = 1 * kvm.max_pages + 1
    kvm._xlate(UPDATE, [dl], [31])
    free0 = kvm.pool.free_device
    moves0 = kvm.cow_moves
    _, n = kvm.cow_writes({1: 1})
    # page 1's lane failed its guard; page 2 (own pin) still moved
    assert kvm.seq_pages[1][1] == old     # host view untouched
    assert kvm._ref[old] == 2             # ref NOT dropped
    assert n == 1 and kvm.cow_moves - moves0 == 1
    assert kvm.pool.free_device == free0 - 1   # only page 2's dest


# ---------------------------------------------------------------------
# satellite 3: refcount invariants under random interleavings
# ---------------------------------------------------------------------
def _churn(seed: int, C: int, steps: int = 40):
    kvm = _kvm(C)
    rng = random.Random(seed)
    tail = iter(range(100, 100 + 4 * steps))
    for step in range(steps):
        op = rng.random()
        free_slots = [s for s in range(kvm.n_slots)
                      if s not in kvm.seq_pages]
        try:
            if op < 0.35 and free_slots:
                pre = list(PREFIXES[rng.randrange(3)])
                toks = pre + [next(tail), next(tail)]
                _admit_shared(kvm, rng.choice(free_slots), toks)
            elif op < 0.55 and kvm.seq_pages:
                kvm.free_seq(rng.choice(list(kvm.seq_pages)))
            elif op < 0.75 and kvm._shared:
                slot = rng.choice(list(kvm._shared))
                kvm.cow_writes({slot: rng.randrange(kvm.max_pages)})
            elif op < 0.85:
                kvm.gc_collect(block_pages=4, budget=8)
            else:
                kvm.prefix_max_nodes = rng.randrange(4)
                kvm._prune_nodes()
                kvm.prefix_max_nodes = 4096
        except KM.OutOfBlocks:
            pass
        _check_invariants(kvm, (seed, C, step))


@example(seed=0, C=1)
@example(seed=1, C=2)
@example(seed=2, C=4)
@example(seed=77, C=1)
@example(seed=1234, C=4)
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), C=st.sampled_from(CHANNELS))
def test_refcount_invariants_property(seed, C):
    """Random admit / shared-admit / diverge-COW / free / GC / prune
    interleavings: every device block is in exactly one of
    free/mapped/retired, every refcount equals its mapper count, and
    COW never leaves a dangling reference (checked after EVERY op)."""
    _churn(seed, C)


# ---------------------------------------------------------------------
# crash consistency: SHARE/COW records replay bit-identically
# ---------------------------------------------------------------------
@pytest.mark.parametrize("C", CHANNELS)
def test_share_cow_journal_replay_bit_identity(C):
    """Leader registration, shared admission, and a COW divergence all
    journal; replay + restore rebuilds seq_pages, pool state, the
    device table, the refcnt lane, and the _ref dict bit-identically
    (all pins still carry mappers here, so recovery's pin release is
    a no-op)."""
    def fresh():
        return _kvm(C)
    with tempfile.TemporaryDirectory() as d:
        kvm = fresh()
        j = jl.Journal(d)
        kvm.journal = j
        j.snapshot(kvm.snapshot_state())
        common = [1, 2, 3, 4]
        _admit_shared(kvm, 0, common + [10, 11])       # leader + pins
        groups = KVPageManager.page_groups(common + [20, 21], PAGE)
        m = kvm.match_prefix(groups)
        assert len(m) == 2
        kvm.new_seq(1, len(groups), shared=m)          # SHARE record
        kvm.cow_writes({1: 0})                         # COW record
        kvm.new_seq(2, 2)                              # plain traffic
        rec = jl.replay(d)
        k2 = fresh()
        k2.restore_mapping(rec)
        assert {s: list(p) for s, p in kvm.seq_pages.items()} == \
               {s: list(p) for s, p in k2.seq_pages.items()}
        assert kvm.pool.state_dict() == k2.pool.state_dict()
        assert kvm._ref == k2._ref
        np.testing.assert_array_equal(np.asarray(kvm.block_tables()),
                                      np.asarray(k2.block_tables()))
        np.testing.assert_array_equal(kvm.refcounts(), k2.refcounts())
        _check_invariants(kvm)
        j.close()


def test_recovery_releases_orphan_pins():
    """The radix tree is volatile: after a crash, recovered pins with
    no surviving mapper return to the pool (deterministic sorted
    order) and the restored manager carries no sharing state — the
    cache rebuilds from post-recovery traffic."""
    with tempfile.TemporaryDirectory() as d:
        kvm = _kvm(1)
        j = jl.Journal(d)
        kvm.journal = j
        j.snapshot(kvm.snapshot_state())
        _admit_shared(kvm, 0, [1, 2, 3, 4, 10, 11])
        kvm.free_seq(0)             # 3 blocks pinned-at-zero, live
        assert kvm.pool.free_device == kvm.pool.n_device - 3
        rec = jl.replay(d)
        assert rec.ref == {b: 0 for b in rec.pinned} and len(rec.pinned) == 3
        k2 = _kvm(1)
        k2.restore_mapping(rec)
        assert k2.pool.free_device == k2.pool.n_device  # pins released
        assert not k2._ref and not k2._pinned
        _check_invariants(k2)
        j.close()


@pytest.mark.parametrize("C", (1, 2))
def test_sharing_off_journal_byte_identity(C):
    """With sharing never engaged, a track_refs=True manager's journal
    stream is BYTE-identical to a track_refs=False manager's — k=0
    admission emits the exact historical NEW_SEQ record."""
    import os

    def drive(kvm):
        kvm.new_seq(0, 3)
        kvm.extend_seq(0, 1)
        kvm.new_seq(1, 2)
        kvm.free_seq(0)

    with tempfile.TemporaryDirectory() as da, \
            tempfile.TemporaryDirectory() as db:
        ka = _kvm(C)
        kb = KVPageManager(n_slots=6, max_pages=8, n_device_blocks=32,
                           n_host_blocks=8, channels=C,
                           track_live=True, track_refs=False)
        for kvm, d in ((ka, da), (kb, db)):
            kvm.journal = jl.Journal(d)
            kvm.journal.snapshot(kvm.snapshot_state())
            drive(kvm)
            kvm.journal.close()
        for name in ("journal.log", "oob.log"):
            with open(os.path.join(da, name), "rb") as fa, \
                    open(os.path.join(db, name), "rb") as fb_:
                assert fa.read() == fb_.read(), name


# ---------------------------------------------------------------------
# sharing-off jaxpr identity: refcnt is an ABSENT pytree leaf
# ---------------------------------------------------------------------
def _prims(closed):
    return collections.Counter(e.primitive.name
                               for jx in _iter(closed.jaxpr)
                               for e in jx.eqns)


def _iter(jaxpr):
    yield jaxpr
    for eq in jaxpr.eqns:
        for v in eq.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for x in vs:
                sub = getattr(x, "jaxpr", x)
                if hasattr(sub, "eqns"):
                    yield from _iter(sub)


def test_sharing_off_jaxpr_identical_and_on_adds_no_probe():
    """track_refs=False leaves refcnt=None — an absent pytree leaf —
    so the traced fused translate is STRING-IDENTICAL to the pre-
    sharing (PR 9) graph. Arming the lane adds only elementwise +
    scatter ops riding the existing write mask: no sort, no gather."""
    import functools
    g = small_geometry()
    dl = jnp.arange(8, dtype=jnp.int32)
    dp = jnp.ones(8, jnp.int32)
    old = jnp.zeros(8, jnp.int32)
    kinds = jnp.array([0, 1, 2, 0, 1, 2, 0, 1], jnp.int32)
    fn = functools.partial(B.translate_serving, g)
    ms_pr9 = B.init_serving_state(g, n_device_blocks=8, track_live=True)
    ms_off = B.init_serving_state(g, n_device_blocks=8, track_live=True,
                                  track_refs=False)
    ms_on = B.init_serving_state(g, n_device_blocks=8, track_live=True,
                                 track_refs=True)
    assert ms_off.refcnt is None and ms_on.refcnt is not None
    jx_pr9 = jax.make_jaxpr(fn)(ms_pr9, kinds, dl, dp, old)
    jx_off = jax.make_jaxpr(fn)(ms_off, kinds, dl, dp, old)
    jx_on = jax.make_jaxpr(fn)(ms_on, kinds, dl, dp, old)
    assert str(jx_off) == str(jx_pr9)       # the off path CANNOT regress
    off, on = _prims(jx_off), _prims(jx_on)
    assert not (off - on), (off - on)
    extra = on - off
    assert "sort" not in extra, extra
    assert "gather" not in extra, extra


def test_manager_refs_off_carries_no_lane():
    kvm = KVPageManager(n_slots=4, max_pages=8, n_device_blocks=16,
                        n_host_blocks=0, channels=1)
    assert kvm.state.refcnt is None
    assert kvm.match_prefix([(1, 2)]) == []
    assert kvm.register_prefix(0, [(1, 2)]) == 0
    assert not kvm.has_shared()


# ---------------------------------------------------------------------
# engine end to end: sharing changes footprint, never outputs
# ---------------------------------------------------------------------
@pytest.fixture(scope="module")
def _model():
    from repro.configs import get_arch, smoke_config
    from repro.models import Runtime, build_model
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 remat="none", page_size=8, capacity_factor=100.0)
    cfg = smoke_config(get_arch("llama3.2-1b"))
    m = build_model(cfg, rt)
    return m, m.init(jax.random.key(0))


@pytest.mark.slow
def test_engine_prefix_sharing_outputs_bit_identical(_model):
    """4 requests with a 16-token common prefix: sharing on must emit
    bit-identical tokens to sharing off, prefill ONCE (the leader),
    admit the followers on shared pages, and COW each diverging tail —
    across the single-step and macro decode paths."""
    from repro.serving.config import PrefixConfig, ServeConfig
    from repro.serving.engine import ServeEngine
    m, params = _model

    def run(prefix, macro_k=0, channels=1):
        sc = ServeConfig(n_slots=8, max_ctx=64, macro_k=macro_k,
                         channels=channels,
                         prefix=PrefixConfig(min_tokens=8)
                         if prefix else None)
        e = ServeEngine(m, params, config=sc)
        rids = [e.submit(list(t), max_new=4) for t in prompts]
        out = e.run()
        return e, [out[r] for r in rids]

    common = list(range(1, 17))
    prompts = [common + [100 + i] * 4 for i in range(4)]
    e0, o0 = run(False)
    assert e0.kvm.state.refcnt is None          # off path truly inert
    assert e0.metrics["shared_admits"] == 0
    assert e0.metrics["cow_moves"] == 0
    e1, o1 = run(True)
    assert o1 == o0
    assert e1.metrics["shared_admits"] == 3
    assert e1.metrics["shared_pages"] == 6
    assert e1.metrics["cow_moves"] > 0
    assert e1.metrics["prefills"] == 1          # leader only
    e2, o2 = run(True, macro_k=4)
    assert o2 == o0
    assert e2.metrics["shared_admits"] == 3
