"""Training stack: loss goes down, grad accumulation invariance,
optimizer semantics, straggler detection, data pipeline determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.data.pipeline import DataConfig, Prefetcher, data_iter, make_batch
from repro.models import Runtime, build_model
from repro.training import optimizer as opt
from repro.training.straggler import QuorumPolicy, StragglerMonitor
from repro.training.train_loop import (TrainerConfig, TrainState,
                                       make_train_step, train)

RT = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
             remat="none")


def _model():
    return build_model(smoke_config(get_arch("llama3.2-1b")), RT)


def test_loss_decreases_100_steps():
    m = _model()
    dcfg = DataConfig(vocab_size=m.cfg.vocab_size, seq_len=64,
                      global_batch=8, pack=False)
    it = data_iter(dcfg, prefetch=False)
    with tempfile.TemporaryDirectory() as d:
        state, summary = train(
            m, it, opt.AdamWConfig(lr=1e-2, weight_decay=0.0,
                                   warmup_steps=10, decay_steps=100),
            TrainerConfig(total_steps=60, log_every=10, ckpt_every=0,
                          ckpt_dir=None))
    hist = summary["history"]
    assert hist[-1][1] < hist[0][1] - 1.0, f"no learning: {hist}"


def test_grad_accum_equivalence():
    m = _model()
    cfg = opt.AdamWConfig(lr=1e-3, grad_clip=0.0, weight_decay=0.0)
    step1, init1, _ = make_train_step(m, cfg, grad_accum=1)
    step4, init4, _ = make_train_step(m, cfg, grad_accum=4)
    state = init1(jax.random.key(0))
    state4 = TrainState(jax.tree.map(jnp.copy, state.params),
                        opt.init_opt_state(state.params))
    dcfg = DataConfig(vocab_size=m.cfg.vocab_size, seq_len=32,
                      global_batch=8, pack=False)
    batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 0).items()}
    s1, m1 = jax.jit(step1)(state, batch)
    s4, m4 = jax.jit(step4)(state4, batch)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_adamw_matches_reference_math():
    cfg = opt.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                          weight_decay=0.0, grad_clip=0.0,
                          warmup_steps=0, decay_steps=10 ** 9)
    params = {"w": jnp.asarray([1.0, 2.0])}
    state = opt.init_opt_state(params)
    grads = {"w": jnp.asarray([0.5, -0.5])}
    p2, s2, _ = opt.adamw_update(cfg, params, grads, state)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(p2["w"][0], want, rtol=1e-5)


def test_grad_clip_bounds_update():
    cfg = opt.AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0,
                          warmup_steps=0)
    params = {"w": jnp.ones(4)}
    grads = {"w": jnp.full(4, 100.0)}
    state = opt.init_opt_state(params)
    _, _, metrics = opt.adamw_update(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) > 100


def test_straggler_monitor_detects_injected_delay():
    mon = StragglerMonitor(k_sigma=3.0, warmup=3)
    for i in range(20):
        mon.record(i, 0.10 + 0.001 * (i % 3))
    assert not mon.events
    assert mon.record(20, 0.50, host=7)   # simulated slow host
    assert mon.events[0].host == 7
    # baseline not poisoned by the outlier
    assert mon.ewma < 0.12


def test_quorum_policy():
    q = QuorumPolicy(n_hosts=10, quorum=0.9)
    assert q.decide(0, list(range(10)))
    assert q.decide(1, list(range(9)))        # 9/10 >= quorum; skip host 9
    assert q.skipped == [(1, [9])]
    assert not q.decide(2, list(range(5)))    # below quorum: wait


# ----------------------------------------------------------------------
def test_data_determinism_and_packing():
    dcfg = DataConfig(vocab_size=512, seq_len=128, global_batch=4)
    b1 = make_batch(dcfg, 7)
    b2 = make_batch(dcfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(dcfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # packing: labels masked at doc boundaries, segments increase
    assert (b1["segment_ids"].max(axis=1) >= 1).any()
    ends = np.diff(b1["segment_ids"], axis=1) > 0
    assert (b1["labels"][:, :-1][ends] == -1).all()


def test_data_host_sharding_disjoint():
    base = dict(vocab_size=512, seq_len=64, global_batch=8, host_count=2)
    b0 = make_batch(DataConfig(host_index=0, **base), 3)
    b1 = make_batch(DataConfig(host_index=1, **base), 3)
    assert b0["tokens"].shape[0] == 4
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_prefetcher_matches_sync():
    dcfg = DataConfig(vocab_size=256, seq_len=32, global_batch=2,
                      prefetch=2)
    pre = Prefetcher(dcfg)
    got = [next(pre) for _ in range(3)]
    pre.close()
    for step, g in enumerate(got):
        np.testing.assert_array_equal(g["tokens"],
                                      make_batch(dcfg, step)["tokens"])
