"""Batched (vectorized) FMMU engine: dict semantics, MSHR-merge dedup,
CondUpdate races, and hypothesis property tests."""
import random

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.fmmu import batch as B
from repro.core.fmmu.types import NIL, small_geometry


@pytest.fixture(scope="module")
def setup():
    g = small_geometry()
    return g, B.make_jitted(g)


def test_batch_semantics(setup):
    g, fns = setup
    stt = B.init_batch_state(g)
    rng = random.Random(0)
    n_pages = g.n_tvpns * g.entries_per_tp
    shadow = {}
    for _ in range(150):
        bq = 16
        dlpns = rng.sample(range(n_pages), bq)
        op = rng.choice(["lookup", "update", "cond"])
        if op == "update":
            dppns = [rng.randrange(10 ** 6) for _ in range(bq)]
            stt = fns["update"](stt, jnp.array(dlpns), jnp.array(dppns))
            shadow.update(zip(dlpns, dppns))
        elif op == "lookup":
            stt, out = fns["lookup"](stt, jnp.array(dlpns))
            for a, o in zip(dlpns, np.asarray(out)):
                assert o == shadow.get(a, NIL)
        else:
            olds = [shadow.get(a, NIL) if rng.random() < 0.5
                    else rng.randrange(10 ** 6) for a in dlpns]
            news = [rng.randrange(10 ** 6) for _ in range(bq)]
            stt, ok = fns["cond_update"](stt, jnp.array(dlpns),
                                         jnp.array(news), jnp.array(olds))
            for a, n, o, k in zip(dlpns, news, olds, np.asarray(ok)):
                assert bool(k) == (shadow.get(a, NIL) == o)
                if shadow.get(a, NIL) == o:
                    shadow[a] = n


def test_batch_miss_dedup_is_mshr_merge(setup):
    """All misses to one cache block produce exactly ONE backing fill —
    the vectorized equivalent of in-cache MSHR merging."""
    g, fns = setup
    stt = B.init_batch_state(g)
    # populate backing
    dl = jnp.arange(g.cmt_entries)
    stt = fns["update"](stt, dl, dl * 10)
    stt = B.init_batch_state(g)._replace(backing=stt.backing)  # cold cache
    fills_before = int(stt.stats[2])
    # 8 lookups, all within one block
    reps = jnp.array([0, 1, 2, 3, 0, 1, 2, 3])[: g.cmt_entries]
    stt, out = fns["lookup"](stt, reps)
    assert int(stt.stats[2]) - fills_before == 1
    np.testing.assert_array_equal(np.asarray(out), np.asarray(reps) * 10)


def test_batch_inactive_slots(setup):
    g, fns = setup
    stt = B.init_batch_state(g)
    stt = fns["update"](stt, jnp.array([3, -1, 5]), jnp.array([30, 99, 50]))
    stt, out = fns["lookup"](stt, jnp.array([3, -1, 5]))
    assert list(np.asarray(out)) == [30, NIL, 50]


def test_batch_capacity_eviction(setup):
    """More distinct blocks than the cache holds: values still correct
    (served from backing), cache does not corrupt."""
    g, fns = setup
    stt = B.init_batch_state(g)
    n_pages = g.n_tvpns * g.entries_per_tp
    dl = jnp.arange(0, n_pages, g.cmt_entries)  # one per block, all blocks
    stt = fns["update"](stt, dl, dl + 1)
    stt, out = fns["lookup"](stt, dl)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dl) + 1)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.lists(st.integers(0, 127), min_size=1,
                                   max_size=8, unique=True),
                          st.integers(0, 999)),
                min_size=1, max_size=25))
def test_batch_property(ops):
    g = small_geometry()
    fns = B.make_jitted(g)
    stt = B.init_batch_state(g)
    shadow = {}
    for is_update, dlpns, base in ops:
        arr = jnp.array(dlpns)
        if is_update:
            vals = jnp.array([base + i for i in range(len(dlpns))])
            stt = fns["update"](stt, arr, vals)
            shadow.update({d: base + i for i, d in enumerate(dlpns)})
        else:
            stt, out = fns["lookup"](stt, arr)
            for d, o in zip(dlpns, np.asarray(out)):
                assert o == shadow.get(d, NIL)
