"""Batched (vectorized) FMMU engine: dict semantics, MSHR-merge dedup,
CondUpdate races, property tests, and the fused translate pipeline
(single-probe invariant, fused-vs-unfused bit-identity, mixed-op edge
cases)."""
import functools
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import example, given, settings, st

from fmmu_lockstep import batch_lockstep
from repro.core.fmmu import batch as B
from repro.core.fmmu.types import (COND_UPDATE, LOOKUP, NIL, UPDATE,
                                   small_geometry)


@pytest.fixture(scope="module")
def setup():
    g = small_geometry()
    return g, B.make_jitted(g)


def test_batch_semantics(setup):
    g, fns = setup
    stt = B.init_batch_state(g)
    rng = random.Random(0)
    n_pages = g.n_tvpns * g.entries_per_tp
    shadow = {}
    for _ in range(150):
        bq = 16
        dlpns = rng.sample(range(n_pages), bq)
        op = rng.choice(["lookup", "update", "cond"])
        if op == "update":
            dppns = [rng.randrange(10 ** 6) for _ in range(bq)]
            stt = fns["update"](stt, jnp.array(dlpns), jnp.array(dppns))
            shadow.update(zip(dlpns, dppns))
        elif op == "lookup":
            stt, out = fns["lookup"](stt, jnp.array(dlpns))
            for a, o in zip(dlpns, np.asarray(out)):
                assert o == shadow.get(a, NIL)
        else:
            olds = [shadow.get(a, NIL) if rng.random() < 0.5
                    else rng.randrange(10 ** 6) for a in dlpns]
            news = [rng.randrange(10 ** 6) for _ in range(bq)]
            stt, ok = fns["cond_update"](stt, jnp.array(dlpns),
                                         jnp.array(news), jnp.array(olds))
            for a, n, o, k in zip(dlpns, news, olds, np.asarray(ok)):
                assert bool(k) == (shadow.get(a, NIL) == o)
                if shadow.get(a, NIL) == o:
                    shadow[a] = n


def test_batch_miss_dedup_is_mshr_merge(setup):
    """All misses to one cache block produce exactly ONE backing fill —
    the vectorized equivalent of in-cache MSHR merging."""
    g, fns = setup
    stt = B.init_batch_state(g)
    # populate backing
    dl = jnp.arange(g.cmt_entries)
    stt = fns["update"](stt, dl, dl * 10)
    stt = B.init_batch_state(g)._replace(backing=stt.backing)  # cold cache
    fills_before = int(stt.stats[2])
    # 8 lookups, all within one block
    reps = jnp.array([0, 1, 2, 3, 0, 1, 2, 3])[: g.cmt_entries]
    stt, out = fns["lookup"](stt, reps)
    assert int(stt.stats[2]) - fills_before == 1
    np.testing.assert_array_equal(np.asarray(out), np.asarray(reps) * 10)


def test_batch_inactive_slots(setup):
    g, fns = setup
    stt = B.init_batch_state(g)
    stt = fns["update"](stt, jnp.array([3, -1, 5]), jnp.array([30, 99, 50]))
    stt, out = fns["lookup"](stt, jnp.array([3, -1, 5]))
    assert list(np.asarray(out)) == [30, NIL, 50]


def test_batch_capacity_eviction(setup):
    """More distinct blocks than the cache holds: values still correct
    (served from backing), cache does not corrupt."""
    g, fns = setup
    stt = B.init_batch_state(g)
    n_pages = g.n_tvpns * g.entries_per_tp
    dl = jnp.arange(0, n_pages, g.cmt_entries)  # one per block, all blocks
    stt = fns["update"](stt, dl, dl + 1)
    stt, out = fns["lookup"](stt, dl)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dl) + 1)


# pinned regression cases (replayed even without a hypothesis wheel —
# tests/_hyp.py): same-set eviction churn across update/lookup rounds
# (the PR-2 incremental-table seed), and a re-written dlpn read back
# through a cold cache (the PR-4 swap CondUpdate shape)
@example([(True, [0, 1, 2, 3], 100), (False, [3, 2, 1, 0], 0),
          (True, [0, 64], 7), (False, [64, 0], 0),
          (True, [0, 4, 8, 12, 16], 55), (False, [16, 0, 8], 0)])
@example([(False, [127], 0), (True, [127], 5), (False, [127], 0),
          (True, [127], 9), (False, [127], 0)])
@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.lists(st.integers(0, 127), min_size=1,
                                   max_size=8, unique=True),
                          st.integers(0, 999)),
                min_size=1, max_size=25))
def test_batch_property(ops):
    g = small_geometry()
    fns = B.make_jitted(g)
    stt = B.init_batch_state(g)
    shadow = {}
    for is_update, dlpns, base in ops:
        arr = jnp.array(dlpns)
        if is_update:
            vals = jnp.array([base + i for i in range(len(dlpns))])
            stt = fns["update"](stt, arr, vals)
            shadow.update({d: base + i for i, d in enumerate(dlpns)})
        else:
            stt, out = fns["lookup"](stt, arr)
            for d, o in zip(dlpns, np.asarray(out)):
                assert o == shadow.get(d, NIL)


# ======================================================================
# Fused translate pipeline
# ======================================================================
def _iter_jaxprs(jaxpr):
    yield jaxpr
    for eq in jaxpr.eqns:
        for v in eq.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for x in vs:
                sub = getattr(x, "jaxpr", x)
                if hasattr(sub, "eqns"):
                    yield from _iter_jaxprs(sub)


def _count_sorts(closed_jaxpr):
    return sum(1 for j in _iter_jaxprs(closed_jaxpr.jaxpr)
               for eq in j.eqns if eq.primitive.name == "sort")


def test_single_probe_single_insert_per_batch():
    """The single-probe invariant: every batch entry point traces exactly
    ONE CMT probe and ONE insert pass (one sort) — in particular the
    CondUpdate/GC path, which used to probe twice and insert twice."""
    g = small_geometry()
    stt = B.init_batch_state(g)
    dl = jnp.arange(8, dtype=jnp.int32)
    dp = jnp.ones(8, jnp.int32)
    old = jnp.zeros(8, jnp.int32)
    mixed = jnp.array([0, 1, 2, 0, 1, 2, 0, 1], jnp.int32)
    ms = B.init_serving_state(g)
    cases = [
        (functools.partial(B.cond_update_batch, g), (stt, dl, dp, old)),
        (functools.partial(B.lookup_batch, g), (stt, dl)),
        (functools.partial(B.update_batch, g), (stt, dl, dp)),
        (functools.partial(B.translate_batch, g), (stt, mixed, dl, dp, old)),
        # the serving wrapper's incremental-table scatter must add no
        # probe and no sort
        (functools.partial(B.translate_serving, g),
         (ms, mixed, dl, dp, old)),
    ]
    for fn, args in cases:
        p0, i0 = B.PROBE_TRACES[0], B.INSERT_TRACES[0]
        jaxpr = jax.make_jaxpr(fn)(*args)
        assert B.PROBE_TRACES[0] - p0 == 1, fn
        assert B.INSERT_TRACES[0] - i0 == 1, fn
        assert _count_sorts(jaxpr) == 1, fn
    # contrast: the unfused GC path probes twice, inserts twice, and
    # pays two full sorts per insert
    p0, i0 = B.PROBE_TRACES[0], B.INSERT_TRACES[0]
    jaxpr = jax.make_jaxpr(
        functools.partial(B.cond_update_batch_unfused, g))(stt, dl, dp, old)
    assert B.PROBE_TRACES[0] - p0 == 2
    assert B.INSERT_TRACES[0] - i0 == 2
    assert _count_sorts(jaxpr) == 4


def test_translate_mixed_lockstep_vs_unfused_and_shadow():
    """Mixed-op batches: fused path is bit-identical (full state pytree
    + outputs) to the unfused three-call sequence, and both follow
    dict semantics."""
    for seed in range(2):
        res = batch_lockstep(seed, n_batches=40)
        assert res.startswith("OK"), res


def test_translate_overflow_and_duplicate_blocks_lockstep():
    """Unconstrained batches: duplicate blocks in one batch (MSHR
    merge), >W distinct new blocks per set (no-allocate overflow),
    duplicate read dlpns — dict semantics and write-through coherence
    hold."""
    for seed in range(2):
        res = batch_lockstep(seed, n_batches=40, overflow=True)
        assert res.startswith("OK"), res
    res = batch_lockstep(11, n_batches=25, overflow=True,
                         geom_kw=dict(cmt_sets=2, cmt_ways=1))
    assert res.startswith("OK"), res


def test_translate_duplicate_block_one_batch_single_fill(setup):
    """All lanes of a mixed batch inside ONE cache block: exactly one
    backing fill (MSHR merge across op kinds)."""
    g, fns = setup
    stt = B.init_batch_state(g)
    base = jnp.arange(g.cmt_entries, dtype=jnp.int32)
    stt = fns["update"](stt, base, base * 7)
    stt = B.init_batch_state(g)._replace(backing=stt.backing)  # cold cache
    e = g.cmt_entries
    opc = jnp.array([LOOKUP, UPDATE, COND_UPDATE, LOOKUP][:e], jnp.int32)
    dl = jnp.arange(len(opc), dtype=jnp.int32)          # one block
    dp = jnp.full((len(opc),), 999, jnp.int32)
    old = dl * 7                                        # cond lane matches
    stt, out, ok = fns["translate"](stt, opc, dl, dp, old)
    assert int(stt.stats[2]) == 1
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dl) * 7)
    assert bool(ok[2])
    # write-allocate pulled the post-write contents (state is donated:
    # snapshot the miss counter before handing stt to the next call)
    miss_before = int(stt.stats[1])
    stt2, out2 = fns["lookup"](stt, dl)
    assert int(stt2.stats[1]) == miss_before            # all hits now
    want = np.asarray(dl) * 7
    want[1] = 999                                       # UPDATE lane
    want[2] = 999                                       # applied COND lane
    np.testing.assert_array_equal(np.asarray(out2), want)


def test_translate_set_overflow_serves_uncached(setup):
    """>W distinct blocks into one set in ONE mixed batch: surplus is
    served from backing (values still correct), at most W fills land."""
    g, _ = setup
    g2 = small_geometry(cmt_sets=2, cmt_ways=2)
    fns = B.make_jitted(g2)
    stt = B.init_batch_state(g2)
    e = g2.cmt_entries
    # 5 distinct blocks, all congruent mod 2 -> same set
    blocks = np.arange(0, 10, 2)
    dl = jnp.asarray(blocks * e, jnp.int32)
    dp = jnp.asarray(blocks * 100, jnp.int32)
    stt = fns["update"](stt, dl, dp)                    # write-allocate
    assert int(stt.stats[2]) <= g2.cmt_ways
    stt, out = fns["lookup"](stt, dl)
    np.testing.assert_array_equal(np.asarray(out), blocks * 100)


def test_serving_table_coherent_with_map(setup):
    """ServingMapState.table is maintained by the same fused call that
    commits each write: after any mixed-op churn it equals the mapping
    a full lookup of every DLPN would return (shadow-dict oracle)."""
    g, fns = setup
    ms = B.init_serving_state(g)
    n_pages = g.n_tvpns * g.entries_per_tp
    rng = random.Random(3)
    shadow = {}
    for _ in range(60):
        bq = 12
        dlpns = rng.sample(range(n_pages), bq)
        kinds = [rng.choice([LOOKUP, UPDATE, COND_UPDATE])
                 for _ in range(bq)]
        news = [rng.randrange(10 ** 6) for _ in range(bq)]
        olds = [shadow.get(a, NIL) if rng.random() < 0.5
                else rng.randrange(10 ** 6) for a in dlpns]
        ms, _, ok = fns["serve"](ms, jnp.array(kinds), jnp.array(dlpns),
                                 jnp.array(news), jnp.array(olds))
        for a, k, n, o, applied in zip(dlpns, kinds, news, olds,
                                       np.asarray(ok)):
            if k == UPDATE or (k == COND_UPDATE and applied):
                shadow[a] = n
    table = np.asarray(ms.table)
    want = np.full(n_pages, NIL, np.int32)
    for a, v in shadow.items():
        want[a] = v
    np.testing.assert_array_equal(table, want)


def test_make_jitted_donation_chain(setup):
    """Donated state: chained steady-state use (always rebinding the
    returned state) stays correct through every entry point."""
    g, fns = setup
    stt = B.init_batch_state(g)
    dl = jnp.arange(6, dtype=jnp.int32)
    stt = fns["update"](stt, dl, dl + 50)
    stt, out = fns["lookup"](stt, dl)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dl) + 50)
    stt, ok = fns["cond_update"](stt, dl, dl + 90, dl + 50)
    assert np.asarray(ok).all()
    opc = jnp.zeros(6, jnp.int32)
    stt, out, _ = fns["translate"](stt, opc, dl, opc, opc)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dl) + 90)


# ---------------------------------------------------------------------
# Device-resident free-list allocator (ISSUE 3): pure state transitions
# ---------------------------------------------------------------------
def test_allocator_alloc_free_oob_flag(setup):
    from repro.core.fmmu.types import HOST_BASE
    g, _ = setup
    ms = B.init_serving_state(g, n_device_blocks=4, n_host_blocks=2)
    # init mirrors BlockPool: first pop is block 0, then 1, 2, ...
    ms, blk, ok = B.alloc_serving(ms, jnp.array([True, False, True]))
    assert list(np.asarray(blk)) == [0, -1, 1]
    assert list(np.asarray(ok)) == [True, False, True]
    assert int(ms.free_n) == 2 and not bool(ms.oob)
    # over-allocation: earlier lanes succeed, later lanes fail, the
    # sticky OutOfBlocks FLAG raises instead of a Python exception
    ms, blk, ok = B.alloc_serving(ms, jnp.array([True, True, True]))
    assert list(np.asarray(blk)) == [2, 3, -1]
    assert list(np.asarray(ok)) == [True, True, False]
    assert int(ms.free_n) == 0 and bool(ms.oob)
    # free routes tiers by HOST_BASE and pushes in lane order (the
    # host block below models one the host tier handed out: free may
    # only return blocks that were actually popped)
    ms = ms._replace(host_n=jnp.int32(1))    # host popped HOST_BASE+0
    ms = B.free_serving(ms, jnp.array([1, -1, HOST_BASE, 3]))
    assert int(ms.free_n) == 2
    assert list(np.asarray(ms.free_stack[:2])) == [1, 3]
    assert int(ms.host_n) == 2
    assert int(ms.host_stack[1]) == HOST_BASE
    # resync from the (authoritative) host pool clears the flag
    ms = B.set_allocator(ms, jnp.arange(3, -1, -1, dtype=jnp.int32),
                         jnp.int32(4), ms.host_stack, ms.host_n)
    assert int(ms.free_n) == 4 and not bool(ms.oob)
    ms, blk, ok = B.alloc_serving(ms, jnp.array([True]))
    assert int(blk[0]) == 0


def test_serving_grow_allocates_and_commits(setup):
    """serving_grow = one pop + one fused map commit: the new mapping
    lands in the backing map AND the incremental table, the allocator
    advances, and failed lanes leave every structure untouched."""
    g, _ = setup
    ms = B.init_serving_state(g, n_device_blocks=2)
    grow = jnp.array([True, False, True])
    dl = jnp.array([5, -1, 9], jnp.int32)
    ms, blocks, ok = B.serving_grow(g, ms, grow, dl)
    assert list(np.asarray(blocks)) == [0, -1, 1]
    assert int(ms.table[5]) == 0 and int(ms.table[9]) == 1
    assert int(ms.fmmu.backing[5]) == 0 and int(ms.fmmu.backing[9]) == 1
    assert int(ms.free_n) == 0
    # pool dry: nothing commits, oob raised
    ms2, blocks2, ok2 = B.serving_grow(g, ms, jnp.array([False, True, False]),
                                       jnp.array([-1, 17, -1], jnp.int32))
    assert not bool(ok2[1]) and bool(ms2.oob)
    assert int(ms2.table[17]) == NIL and int(ms2.fmmu.backing[17]) == NIL


def test_allocator_transitions_inside_jit_donated(setup):
    """alloc/free/grow are pure pytree transitions usable under jit
    with donation (the macro-step contract)."""
    g, _ = setup
    ms = B.init_serving_state(g, n_device_blocks=8)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def roundtrip(ms, want, dl):
        ms, blocks, ok = B.alloc_serving(ms, want)
        ms = B.free_serving(ms, jnp.where(ok, blocks, NIL))
        ms, _, _ = B.serving_grow(g, ms, want, dl)
        return ms

    ms = roundtrip(ms, jnp.array([True, True]), jnp.array([3, 4], jnp.int32))
    assert int(ms.free_n) == 6
    assert int(ms.table[3]) >= 0 and int(ms.table[4]) >= 0
