"""KV page manager: allocation, translation tables, block reuse,
swap data integrity (CondUpdate-guarded tier moves), and coherence of
the device-resident incremental block table against the from-scratch
retranslation oracle."""
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.paging import kv_manager as KM
from repro.paging.kv_manager import KVPageManager
from repro.paging.pool import HOST_BASE, BlockPool, OutOfBlocks


def test_alloc_translate_free_cycle():
    kvm = KVPageManager(n_slots=4, max_pages=8, n_device_blocks=16)
    b0 = kvm.new_seq(0, 3)
    b1 = kvm.new_seq(1, 4)
    assert not set(b0) & set(b1)
    t = np.asarray(kvm.block_tables())
    assert list(t[0, :3]) == b0 and (t[0, 3:] == -1).all()
    assert list(t[1, :4]) == b1
    kvm.free_seq(0)
    t = np.asarray(kvm.block_tables())
    assert (t[0] == -1).all()
    b2 = kvm.new_seq(2, 3)           # freed blocks recycled
    assert set(b2) <= set(b0) | set(range(16))


def test_extend_and_out_of_blocks():
    kvm = KVPageManager(n_slots=2, max_pages=8, n_device_blocks=4)
    kvm.new_seq(0, 3)
    kvm.extend_seq(0, 1)
    with pytest.raises(OutOfBlocks):
        kvm.new_seq(1, 2)


def test_swap_roundtrip_moves_data():
    kvm = KVPageManager(n_slots=2, max_pages=4, n_device_blocks=4,
                        n_host_blocks=4)
    blocks = kvm.new_seq(0, 3)
    pool = jnp.arange((4 + 4 + 1) * 5.0).reshape(9, 5)   # +1 scratch row
    orig = np.array(pool)
    pools, n = kvm.swap_out(0, [pool])
    assert n == 3
    assert all(BlockPool.is_host(b) for b in kvm.seq_pages[0])
    # host rows hold the data now
    hrows = [4 + (b - HOST_BASE) for b in kvm.seq_pages[0]]
    np.testing.assert_array_equal(np.asarray(pools[0])[hrows],
                                  orig[blocks])
    pools, n = kvm.swap_in(0, pools)
    assert n == 3
    new_blocks = kvm.seq_pages[0]
    assert all(not BlockPool.is_host(b) for b in new_blocks)
    np.testing.assert_array_equal(np.asarray(pools[0])[new_blocks],
                                  orig[blocks])
    # tables reflect the final placement
    t = np.asarray(kvm.block_tables())
    assert list(t[0, :3]) == new_blocks


def test_block_tables_is_zero_cost_read():
    """block_tables() must neither translate nor touch FMMU state: no
    fused map call, no full retranslation, stats frozen."""
    kvm = KVPageManager(n_slots=2, max_pages=4, n_device_blocks=8)
    kvm.new_seq(0, 2)
    x0, f0 = KM.XLATE_CALLS[0], KM.FULL_TABLE_CALLS[0]
    stats0 = kvm.hit_stats()
    for _ in range(3):
        t = np.asarray(kvm.block_tables())
    assert KM.XLATE_CALLS[0] == x0 and KM.FULL_TABLE_CALLS[0] == f0
    assert kvm.hit_stats() == stats0
    assert list(t[0, :2]) == kvm.seq_pages[0]


def test_extend_seqs_batched_single_xlate():
    kvm = KVPageManager(n_slots=4, max_pages=8, n_device_blocks=32)
    for s in range(3):
        kvm.new_seq(s, 2)
    x0 = KM.XLATE_CALLS[0]
    got = kvm.extend_seqs({0: 1, 1: 2, 2: 1})
    assert KM.XLATE_CALLS[0] - x0 == 1
    assert sorted(got) == [0, 1, 2] and len(got[1]) == 2
    t = np.asarray(kvm.block_tables())
    for s in range(3):
        assert list(t[s, :len(kvm.seq_pages[s])]) == kvm.seq_pages[s]
    # atomic on exhaustion: no partial growth
    with pytest.raises(OutOfBlocks):
        kvm.extend_seqs({0: 20, 1: 20})
    assert len(kvm.seq_pages[0]) == 3 and len(kvm.seq_pages[1]) == 4
    # zero-page requests are a no-op, not a KeyError
    assert kvm.extend_seq(0, 0) == []
    assert kvm.extend_seqs({0: 0, 1: 0}) == {}
    # unknown slot rejected before any allocation or mapping leaks
    free_before = kvm.pool.free_device
    pages_before = {s: list(p) for s, p in kvm.seq_pages.items()}
    with pytest.raises(KeyError):
        kvm.extend_seqs({0: 1, 99: 1})
    assert kvm.pool.free_device == free_before
    assert {s: list(p) for s, p in kvm.seq_pages.items()} == pages_before
    inc = np.asarray(kvm.block_tables())
    np.testing.assert_array_equal(inc, np.asarray(kvm.retranslate_tables()))


@pytest.mark.slow
def test_churn_equivalence_incremental_vs_retranslation():
    """ISSUE-2 property test: after a random interleaving of
    new_seq/extend_seq(s)/free_seq/swap_out/swap_in, the incremental
    device table must be bit-identical to a from-scratch full-map
    retranslation (the old path, kept as the oracle). Marked slow:
    the CI fast lane skips it; the full lane and local tier-1 run it."""
    rng = random.Random(7)
    n_slots, max_pages = 4, 8
    kvm = KVPageManager(n_slots, max_pages, n_device_blocks=20,
                        n_host_blocks=12)
    pool = jnp.arange((20 + 12 + 1) * 3.0).reshape(33, 3)
    live = set()
    for step in range(150):
        ops = ["new"] if len(live) < n_slots else []
        if live:
            ops += ["extend", "extend_multi", "free", "swap_out",
                    "swap_in"]
        op = rng.choice(ops)
        try:
            if op == "new":
                slot = rng.choice([s for s in range(n_slots)
                                   if s not in live])
                kvm.new_seq(slot, rng.randint(1, 3))
                live.add(slot)
            elif op == "extend":
                slot = rng.choice(sorted(live))
                room = max_pages - len(kvm.seq_pages[slot])
                if room:
                    kvm.extend_seq(slot, rng.randint(1, room))
            elif op == "extend_multi":
                wants = {s: 1 for s in live
                         if len(kvm.seq_pages[s]) < max_pages}
                kvm.extend_seqs(wants)
            elif op == "free":
                slot = rng.choice(sorted(live))
                kvm.free_seq(slot)
                live.discard(slot)
            elif op == "swap_out":
                [pool], _ = kvm.swap_out(rng.choice(sorted(live)), [pool])
            else:
                [pool], _ = kvm.swap_in(rng.choice(sorted(live)), [pool])
        except OutOfBlocks:
            pass
        if step % 10 == 9:
            inc = np.asarray(kvm.block_tables())
            oracle = np.asarray(kvm.retranslate_tables())
            np.testing.assert_array_equal(inc, oracle, f"step {step}")
    inc = np.asarray(kvm.block_tables())
    oracle = np.asarray(kvm.retranslate_tables())
    np.testing.assert_array_equal(inc, oracle)


def test_swap_block_axis():
    kvm = KVPageManager(n_slots=1, max_pages=4, n_device_blocks=4,
                        n_host_blocks=4)
    blocks = kvm.new_seq(0, 2)
    pool = jnp.arange(2.0 * 9 * 3).reshape(2, 9, 3)   # block axis 1
    orig = np.array(pool)
    pools, _ = kvm.swap_out(0, [pool], block_axis=1)
    hrows = [4 + (b - HOST_BASE) for b in kvm.seq_pages[0]]
    np.testing.assert_array_equal(np.asarray(pools[0])[:, hrows],
                                  orig[:, blocks])


def test_allocator_mirror_sync_and_reconcile():
    """ISSUE-3 mirror protocol: host pool mutations dirty the device
    allocator (synced lazily, ALLOC_SYNCS-counted); device-side pops
    replayed through reconcile_macro keep both sides identical WITHOUT
    a re-push."""
    import jax

    from repro.core.fmmu import batch as fb

    kvm = KVPageManager(n_slots=2, max_pages=4, n_device_blocks=8)
    a0 = KM.ALLOC_SYNCS[0]
    assert not kvm._alloc_dirty            # mirrors agree at birth
    kvm.sync_allocator()
    assert KM.ALLOC_SYNCS[0] == a0         # clean -> no-op
    kvm.new_seq(0, 2)                      # host mutation -> dirty
    assert kvm._alloc_dirty
    kvm.sync_allocator()
    assert KM.ALLOC_SYNCS[0] == a0 + 1 and not kvm._alloc_dirty
    st = kvm.state
    assert int(st.free_n) == kvm.pool.free_device
    np.testing.assert_array_equal(
        np.asarray(st.free_stack[:int(st.free_n)]),
        np.asarray(kvm.pool._free_dev, np.int32))
    # simulate a macro-step's device-side growth: slot 0 page 2, then
    # slot 1 page 0 (two scan steps), committed through serving_grow
    import functools
    grow_fn = jax.jit(functools.partial(fb.serving_grow, kvm.geom),
                      donate_argnums=(0,))
    kvm.seq_pages[1] = []                  # slot 1 enters via device path
    for slot, page in [(0, 2), (1, 0)]:
        grow = np.zeros(2, bool)
        grow[slot] = True
        dl = np.asarray([slot * 4 + page] * 2, np.int32)
        kvm.state, _, ok = grow_fn(kvm.state, grow, dl)
        assert bool(np.asarray(ok)[slot])
    got = kvm.reconcile_macro([0, 1])
    # host popped the same ids the device did, in the same order
    assert got == {0: [2], 1: [3]}
    assert kvm.seq_pages[0] == [0, 1, 2] and kvm.seq_pages[1] == [3]
    assert not kvm._alloc_dirty            # mirror held: no re-push due
    assert int(kvm.state.free_n) == kvm.pool.free_device
    np.testing.assert_array_equal(
        np.asarray(kvm.state.free_stack[:int(kvm.state.free_n)]),
        np.asarray(kvm.pool._free_dev, np.int32))
    # the committed mappings agree with the retranslation oracle
    inc = np.asarray(kvm.block_tables())
    np.testing.assert_array_equal(inc, np.asarray(kvm.retranslate_tables()))
