"""KV page manager: allocation, translation tables, block reuse,
swap data integrity (CondUpdate-guarded tier moves)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.paging.kv_manager import KVPageManager
from repro.paging.pool import HOST_BASE, BlockPool, OutOfBlocks


def test_alloc_translate_free_cycle():
    kvm = KVPageManager(n_slots=4, max_pages=8, n_device_blocks=16)
    b0 = kvm.new_seq(0, 3)
    b1 = kvm.new_seq(1, 4)
    assert not set(b0) & set(b1)
    t = np.asarray(kvm.block_tables())
    assert list(t[0, :3]) == b0 and (t[0, 3:] == -1).all()
    assert list(t[1, :4]) == b1
    kvm.free_seq(0)
    t = np.asarray(kvm.block_tables())
    assert (t[0] == -1).all()
    b2 = kvm.new_seq(2, 3)           # freed blocks recycled
    assert set(b2) <= set(b0) | set(range(16))


def test_extend_and_out_of_blocks():
    kvm = KVPageManager(n_slots=2, max_pages=8, n_device_blocks=4)
    kvm.new_seq(0, 3)
    kvm.extend_seq(0, 1)
    with pytest.raises(OutOfBlocks):
        kvm.new_seq(1, 2)


def test_swap_roundtrip_moves_data():
    kvm = KVPageManager(n_slots=2, max_pages=4, n_device_blocks=4,
                        n_host_blocks=4)
    blocks = kvm.new_seq(0, 3)
    pool = jnp.arange((4 + 4 + 1) * 5.0).reshape(9, 5)   # +1 scratch row
    orig = np.array(pool)
    pools, n = kvm.swap_out(0, [pool])
    assert n == 3
    assert all(BlockPool.is_host(b) for b in kvm.seq_pages[0])
    # host rows hold the data now
    hrows = [4 + (b - HOST_BASE) for b in kvm.seq_pages[0]]
    np.testing.assert_array_equal(np.asarray(pools[0])[hrows],
                                  orig[blocks])
    pools, n = kvm.swap_in(0, pools)
    assert n == 3
    new_blocks = kvm.seq_pages[0]
    assert all(not BlockPool.is_host(b) for b in new_blocks)
    np.testing.assert_array_equal(np.asarray(pools[0])[new_blocks],
                                  orig[blocks])
    # tables reflect the final placement
    t = np.asarray(kvm.block_tables())
    assert list(t[0, :3]) == new_blocks


def test_swap_block_axis():
    kvm = KVPageManager(n_slots=1, max_pages=4, n_device_blocks=4,
                        n_host_blocks=4)
    blocks = kvm.new_seq(0, 2)
    pool = jnp.arange(2.0 * 9 * 3).reshape(2, 9, 3)   # block axis 1
    orig = np.array(pool)
    pools, _ = kvm.swap_out(0, [pool], block_axis=1)
    hrows = [4 + (b - HOST_BASE) for b in kvm.seq_pages[0]]
    np.testing.assert_array_equal(np.asarray(pools[0])[:, hrows],
                                  orig[:, blocks])
