"""Crash-consistent map journaling (ISSUE 7): frame-level torn-tail
detection, replay truncated at EVERY byte offset of the last record
(full replay or clean drop — never a corrupt map), the injected crash
axis's byte-exact tears, the device commit_seq lane vs journaled lanes,
and jaxpr-identity of the journaling-disabled path.

The exhaustive truncation test enumerates offsets deterministically;
the hypothesis property on top varies the traffic script and the cut
fraction (pinned @example seeds replay in containers without the
hypothesis wheel — tests/_hyp.py)."""
import os
import random
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import example, given, settings, st
from repro.core import journal as jl
from repro.core.faults import Crash, FaultPlane, make_plan
from repro.core.fmmu import batch as fb
from repro.paging.kv_manager import KVPageManager

pytestmark = pytest.mark.recovery


# ------------------------------------------------------------- framing
def test_frame_roundtrip_and_valid_bytes(tmp_path):
    p = str(tmp_path / "log")
    blob = b"".join(jl._frame(i + 1, jl.NEW_SEQ, {"i": i})
                    for i in range(3))
    with open(p, "wb") as f:
        f.write(blob)
    frames, valid, torn = jl.read_frames(p)
    assert [s for s, _, _ in frames] == [1, 2, 3]
    assert [d["i"] for _, _, d in frames] == [0, 1, 2]
    assert valid == len(blob) and not torn


def test_read_frames_every_truncation_is_detected(tmp_path):
    """Cutting the 2-frame log at ANY interior byte offset yields the
    longest whole-frame prefix and torn=True — no parser state escapes
    a partial header, partial payload, or partial crc."""
    f1 = jl._frame(1, jl.EXTEND, {"dl": [5], "blocks": [2], "lanes": 1})
    f2 = jl._frame(2, jl.FREE, {"slot": 0, "blocks": [2], "lanes": 1})
    blob = f1 + f2
    p = str(tmp_path / "log")
    for cut in range(len(blob) + 1):
        with open(p, "wb") as f:
            f.write(blob[:cut])
        frames, valid, torn = jl.read_frames(p)
        want = (2 if cut == len(blob) else 1 if cut >= len(f1) else 0)
        assert len(frames) == want, cut
        assert valid == (len(f1) * want if want < 2 else len(blob))
        assert torn == (cut not in (0, len(f1), len(blob))), cut


def test_corrupt_interior_frame_stops_replay(tmp_path):
    f1 = jl._frame(1, jl.SUBMIT, {"rid": 0, "lanes": 0})
    f2 = jl._frame(2, jl.SUBMIT, {"rid": 1, "lanes": 0})
    blob = bytearray(f1 + f2)
    blob[len(f1) // 2] ^= 0xFF          # flip a byte inside frame 1
    p = str(tmp_path / "log")
    with open(p, "wb") as f:
        f.write(bytes(blob))
    frames, valid, torn = jl.read_frames(p)
    assert frames == [] and valid == 0 and torn


# ------------------------------------------- torn-tail replay property
def _traffic(kvm, rng):
    """A random but always-legal op script; every op is a journaled
    commit point. Growth is gated on per-channel headroom (and leaves
    room for the caller's final 2-page new_seq) so no script ever hits
    OutOfBlocks."""
    live = []
    for _ in range(rng.randrange(6, 11)):
        op = rng.random()
        free_slots = [s for s in range(kvm.n_slots) if s not in live]
        roomy = [s for s in live
                 if len(kvm.seq_pages[s]) + 2 <= kvm.max_pages]
        headroom = min(kvm.pool.free_device_ch(c)
                       for c in range(kvm.channels)) >= 4
        if op < 0.5 and free_slots and headroom:
            slot = free_slots[0]
            kvm.new_seq(slot, rng.randrange(1, 4))
            live.append(slot)
        elif op < 0.8 and roomy and headroom:
            kvm.extend_seqs({rng.choice(roomy): rng.randrange(1, 3)})
        elif live:
            kvm.free_seq(live.pop(rng.randrange(len(live))))


def _cut_dir(src: str, dst: str, o_base: int, r_base: int, cut: int,
             o_tail: int):
    """Clone the journal dir with the final commit's (oob + record)
    byte stream truncated after `cut` bytes — the exact layout
    ``Journal.append``'s crash path would leave behind."""
    if os.path.isdir(dst):
        shutil.rmtree(dst)
    os.makedirs(dst)
    for name in os.listdir(src):
        shutil.copy(os.path.join(src, name), os.path.join(dst, name))
    with open(os.path.join(dst, "oob.log"), "r+b") as f:
        f.truncate(o_base + min(cut, o_tail))
    with open(os.path.join(dst, "journal.log"), "r+b") as f:
        f.truncate(r_base + max(0, cut - o_tail))


def _torn_tail_case(seed: int, exhaustive: bool, frac: float = 0.0,
                    final: str = "new2"):
    """Drive journaled traffic, then truncate the LAST commit's bytes —
    at every offset (exhaustive) or at one seeded offset — and require:
    replay never corrupts the map (check() passes) and the recovered
    mapping is bit-exactly either the pre-commit or the post-commit
    oracle, with the flip happening exactly when the commit's OOB frame
    is complete (the SPOR contract: whole OOB = replayable, torn OOB =
    dropped cleanly).

    ``final`` picks the dangling commit: a 2-page new_seq ("new2", the
    default — fits any channel draw), a 3-page new_seq or 3-page slot
    extension ("new3" / "extend3" — always at channels=2, where the
    slot's pages stripe across channels, so the OOB scan must apply
    owners in page order, not channel order), a RETIRE with a
    program-fault chain ("retire" — the dangling frame carries
    bad-block marks for a schedule-failed replacement candidate the
    replayed shadow still holds free), or a mid-swap tear ("swap")."""
    rng = random.Random(seed)
    with tempfile.TemporaryDirectory() as d:
        src = os.path.join(d, "j")
        kvm = KVPageManager(
            n_slots=4, max_pages=8, n_device_blocks=24,
            n_host_blocks=8 if final == "swap" else 0,
            channels=rng.choice((1, 2)) if final == "new2" else 2)
        j = jl.Journal(src)
        kvm.journal = j
        j.snapshot(kvm.snapshot_state())
        _traffic(kvm, rng)
        if len(kvm.seq_pages) == kvm.n_slots:
            kvm.free_seq(min(kvm.seq_pages))
        victim = None
        if final != "new2":
            # the non-default finals need pool headroom (and a live
            # 3-page victim slot for extend/retire/swap); every top-up
            # op below is itself a journaled commit, so it lands
            # before the pre-commit oracle is taken
            while (min(kvm.pool.free_device_ch(c)
                       for c in range(kvm.channels)) < 6
                   and kvm.seq_pages):
                kvm.free_seq(min(kvm.seq_pages))
            if final != "new3":
                victim = next(s for s in range(4)
                              if s not in kvm.seq_pages)
                kvm.new_seq(victim, 3)
        m_before = jl.replay(src).mapping()
        o_base = os.path.getsize(os.path.join(src, "oob.log"))
        r_base = os.path.getsize(os.path.join(src, "journal.log"))
        # final commit: programs blocks, so it has an OOB frame and
        # exercises the reverse-map scan
        if final in ("new2", "new3"):
            slot = next(s for s in range(4) if s not in kvm.seq_pages)
            kvm.new_seq(slot, 2 if final == "new2" else 3)
        elif final == "extend3":
            kvm.extend_seq(victim, 3)
        elif final == "retire":
            # first replacement candidate fails its program too: the
            # chain retires {original, candidate} and keeps the second
            # candidate — the candidate is a block the replayed shadow
            # still thinks is free
            old = kvm.seq_pages[victim][0]
            kvm.faults = FaultPlane(make_plan(seed)._replace(
                program_fail=np.array([True] + [False] * 7)))
            kvm.retire_bad_blocks([(victim * kvm.max_pages, old)])
        else:
            assert final == "swap"
            width = kvm.pool.n_device + kvm.pool.n_host + 1
            kvm.swap_out(victim, [jnp.zeros((width, 2))])
        j.close()
        m_after = jl.replay(src).mapping()
        assert m_after != m_before
        o_tail = os.path.getsize(os.path.join(src, "oob.log")) - o_base
        r_tail = (os.path.getsize(os.path.join(src, "journal.log"))
                  - r_base)
        total = o_tail + r_tail
        cuts = (range(total + 1) if exhaustive
                else [max(0, min(total, int(round(frac * total))))])
        work = os.path.join(d, "cut")
        for cut in cuts:
            _cut_dir(src, work, o_base, r_base, cut, o_tail)
            rec = jl.replay(work)
            rec.check()                      # never a corrupt map
            got = rec.mapping()
            if cut >= o_tail:                # whole OOB frame landed
                assert got == m_after, (seed, cut)
                assert rec.oob_scan == (cut < total), (seed, cut)
            else:                            # commit never hit "flash"
                assert got == m_before, (seed, cut)
                assert not rec.oob_scan, (seed, cut)


def test_truncate_every_byte_offset_of_last_record():
    """The satellite's exhaustive case: every single byte offset of the
    final commit's on-disk bytes, two fixed traffic scripts."""
    for seed in (7, 23):
        _torn_tail_case(seed, exhaustive=True)


@pytest.mark.parametrize("final", ("new3", "extend3", "retire", "swap"))
def test_truncate_every_byte_offset_other_commit_kinds(final):
    """Review hardening: exhaustive byte-offset sweeps for the dangling
    commit kinds the default case cannot reach — multi-page allocs
    whose pages stripe across channels=2 (the OOB scan must apply
    owners in page order), a RETIRE program-fault chain (bad-block
    marks for a block the shadow still holds free), and a mid-swap
    tear."""
    _torn_tail_case(31, exhaustive=True, final=final)


@example(seed=3, frac=0.0, final="new2")
@example(seed=5, frac=0.5, final="new2")
@example(seed=11, frac=0.93, final="new2")
@example(seed=42, frac=1.0, final="new2")
@example(seed=17, frac=0.4, final="new3")
@example(seed=19, frac=0.55, final="extend3")
@example(seed=29, frac=0.5, final="retire")
@example(seed=37, frac=0.8, final="swap")
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), frac=st.floats(0.0, 1.0),
       final=st.sampled_from(("new2", "new3", "extend3", "retire",
                              "swap")))
def test_torn_tail_property(seed, frac, final):
    """Property form: arbitrary traffic script x arbitrary cut point x
    dangling commit kind. The pinned examples are the regression
    seeds; with hypothesis installed the strategy explores beyond
    them."""
    _torn_tail_case(int(seed), exhaustive=False, frac=float(frac),
                    final=str(final))


def test_torn_tail_seeded_sweep():
    """Seeded breadth for no-hypothesis containers: 12 scripts x 4 cut
    fractions."""
    for seed in range(12):
        for frac in (0.0, 0.33, 0.71, 1.0):
            _torn_tail_case(100 + seed, exhaustive=False, frac=frac)


def test_torn_tail_seeded_sweep_commit_kinds():
    """Seeded breadth over the non-default dangling commits (same
    no-hypothesis rationale as above)."""
    for final in ("new3", "extend3", "retire", "swap"):
        for seed in (0, 1, 2):
            for frac in (0.0, 0.45, 0.77, 1.0):
                _torn_tail_case(140 + seed, exhaustive=False,
                                frac=frac, final=final)


# --------------------------------------------------- injected crashes
def test_crash_axis_tears_byte_exactly(tmp_path):
    """The fault plane's crash axis must persist round(tear * total)
    bytes of the commit's oob+record stream and kill the journal."""
    for tear, torn in ((0.0, True), (0.4, True), (1.0, False)):
        d = str(tmp_path / f"t{tear}")
        plan = make_plan(1, crash_at=0)
        plan = plan._replace(
            crash_tear=np.full_like(plan.crash_tear, tear))
        j = jl.Journal(d, faults=FaultPlane(plan))
        with pytest.raises(Crash) as ei:
            j.append(jl.NEW_SEQ,
                     {"slot": 0, "dl": [0, 1], "blocks": [4, 6]},
                     programmed=[(0, 4), (1, 6)])
        assert ei.value.torn == torn and j.dead
        oob = jl._frame(1, jl.OOB,
                        {"pairs": [[0, 4], [1, 6]], "retired": []})
        rec = jl._frame(1, jl.NEW_SEQ, {"slot": 0, "dl": [0, 1],
                                        "blocks": [4, 6], "lanes": 2})
        total = len(oob) + len(rec)
        cut = int(round(tear * total))
        got = (os.path.getsize(os.path.join(d, "oob.log"))
               + os.path.getsize(os.path.join(d, "journal.log")))
        assert got == cut, (tear, got, cut)
        with pytest.raises(AssertionError):
            j.append(jl.FREE, {"slot": 0, "blocks": [], "lanes": 0})


def test_resume_truncates_torn_tail_and_continues_seq(tmp_path):
    d = str(tmp_path / "j")
    j = jl.Journal(d)
    j.append(jl.SUBMIT, {"rid": 0, "tokens": [1], "max_new": 1,
                         "lanes": 0})
    j.append(jl.SUBMIT, {"rid": 1, "tokens": [2], "max_new": 1,
                         "lanes": 0})
    j.close()
    with open(os.path.join(d, "journal.log"), "ab") as f:
        f.write(b"\x13\x37torn")
    j2 = jl.Journal(d, resume=True)
    assert j2.seq == 2                   # tail dropped, sequence kept
    frames, _, torn = jl.read_frames(os.path.join(d, "journal.log"))
    assert len(frames) == 2 and not torn
    s = j2.append(jl.SUBMIT, {"rid": 2, "tokens": [3], "max_new": 1,
                              "lanes": 0})
    assert s == 3
    j2.close()


# -------------------------------------------------- commit_seq lane
def test_commit_seq_lane_matches_journaled_lanes():
    """The device-resident commit_seq lane (ISSUE 7's sequence lane in
    the fused map) and the journal's cumulative record lanes advance in
    lockstep across every commit kind — alloc, batched growth, free,
    swap, retirement."""
    import jax.numpy as jnp
    with tempfile.TemporaryDirectory() as d:
        for C in (1, 2):
            kvm = KVPageManager(n_slots=4, max_pages=6,
                                n_device_blocks=16, n_host_blocks=8,
                                channels=C)
            j = jl.Journal(os.path.join(d, f"c{C}"))
            kvm.journal = j

            def lanes():
                return int(np.asarray(jax.device_get(
                    fb.commit_seq_vec(kvm.state))).sum())

            base = lanes()
            kvm.new_seq(0, 3)
            kvm.new_seq(1, 2)
            kvm.extend_seqs({0: 2, 1: 1})
            kvm.retire_bad_blocks([(1 * kvm.max_pages,
                                    kvm.seq_pages[1][0])])
            width = kvm.pool.n_device + kvm.pool.n_host + 1
            pools = [jnp.zeros((width, 2))]
            pools, _ = kvm.swap_out(0, pools)
            pools, _ = kvm.swap_in(0, pools)
            kvm.free_seq(1)
            assert lanes() - base == j.commit_lanes, C
            assert j.commit_lanes > 0
            j.close()


# ------------------------------------------- disabled path: zero cost
def test_journaling_disabled_jaxpr_identical():
    """Journaling is host-side file I/O behind ``if journal is not
    None`` — the traced serve and swap graphs must be string-identical
    with and without a journal attached (same contract, and the same
    test shape, as the ISSUE-6 fault plane's)."""
    import jax.numpy as jnp
    with tempfile.TemporaryDirectory() as d:
        plain = KVPageManager(2, 4, 8, 8)
        logged = KVPageManager(2, 4, 8, 8)
        logged.journal = jl.Journal(d)
        opc = np.zeros(4, np.int32)
        dl = np.arange(4, dtype=np.int32)

        def serve_jaxpr(k):
            return str(jax.make_jaxpr(
                lambda s: k.fns["serve"](s, opc, dl, dl, dl))(k.state))

        assert serve_jaxpr(plain) == serve_jaxpr(logged)

        pools = [jnp.zeros((17, 2))]
        lanes = (dl, dl, dl, dl, dl, np.int32(0), True)

        def swap_jaxpr(k):
            fn = k._swap_fn(4, 0, 1)
            return str(jax.make_jaxpr(
                lambda s, p: fn(s, p, *lanes))(k.state, pools))

        assert swap_jaxpr(plain) == swap_jaxpr(logged)
        logged.journal.close()
