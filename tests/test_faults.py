"""Fault-injection plane + recovery machinery (ISSUE 6).

Covers, bottom-up: plan determinism (replay-from-seed is the chaos
harness's only reproduction handle), BlockPool retirement + the typed
``PoolExhausted`` channel attribution, the pre-mutation guarantees of
injected swap/alloc failures, bad-block retirement re-driving writes
through the fused CondUpdate path, the zero-cost-when-disabled claim
(jaxpr + counter identity), the engine's retry/backoff/quarantine
state machine (hypothesis property with pinned regression examples),
the K-token detection latency of the in-graph oob flag, and the
satellite-6 same-boundary reservation release. The randomized
end-to-end sweeps live in tests/chaos/."""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.core import faults as flt
from repro.core.faults import FaultPlan, FaultPlane, SwapFault, make_plan
from repro.models import Runtime, build_model
from repro.paging.kv_manager import KVPageManager
from repro.paging.pool import BlockPool, OutOfBlocks, PoolExhausted
from repro.serving.engine import ServeEngine
from tests._hyp import example, given, settings, st

pytestmark = pytest.mark.faults

RT = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
             remat="none", page_size=8, capacity_factor=100.0)


@pytest.fixture(scope="module")
def tiny():
    """Minimal model (the serve-bench idiom): these tests exercise the
    fault/recovery plane, not the transformer — compute is kept as
    close to zero as the engine allows."""
    cfg = smoke_config(get_arch("llama3.2-1b"))
    cfg = dataclasses.replace(cfg, name="faults-tiny",
                              n_layers=cfg.period, d_model=32, n_heads=2,
                              n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab_size=128)
    m = build_model(cfg, RT)
    return m, m.init(jax.random.key(0))


def _plan(horizon=32, channels=1, **axes):
    """FaultPlan with EXPLICIT schedule bits (unit tests want exact
    fault positions, not probabilities)."""

    def sched(key):
        out = np.zeros(horizon, bool)
        for i in axes.get(key, ()):
            out[i] = True
        return out

    stall = axes.get("stall")
    return FaultPlan(
        seed=0, swap_fail=sched("swap"), program_fail=sched("program"),
        alloc_fail=sched("alloc"),
        stall=(np.ones(channels) if stall is None
               else np.asarray(stall, np.float64)))


# ---------------------------------------------------------------- plan
def test_plan_determinism_and_replay():
    a = make_plan(1234, channels=2, swap_fail_p=0.2, program_fail_p=0.1,
                  alloc_fail_p=0.05, stall=[4.0, 1.0], horizon=512)
    b = make_plan(1234, channels=2, swap_fail_p=0.2, program_fail_p=0.1,
                  alloc_fail_p=0.05, stall=[4.0, 1.0], horizon=512)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    c = make_plan(1235, swap_fail_p=0.2, horizon=512)
    assert not np.array_equal(a.swap_fail, c.swap_fail)
    # rates land near p (the hash is uniform enough for scheduling)
    assert 0.1 < a.swap_fail.mean() < 0.3
    assert a.stall.shape == (2,)
    plane = FaultPlane(a)
    assert "seed=1234" in plane.describe()
    # the consumer walks the schedule with wraparound, counting fires
    fired = sum(plane.swap_fails() for _ in range(1024))
    assert fired == plane.counts()["swap"] == 2 * int(a.swap_fail.sum())


def test_plan_validation():
    with pytest.raises(AssertionError):
        make_plan(0, channels=2, stall=[1.0])          # shape mismatch
    with pytest.raises(AssertionError):
        make_plan(0, stall=[0.5])                      # < 1 not a stall


# ---------------------------------------------------------------- pool
def test_pool_retirement_permanently_removes_blocks():
    pool = BlockPool(8, 0, n_channels=2)
    blocks = pool.alloc_for([0, 0, 1])
    pool.retire(blocks[:2])                            # both channel 0
    assert pool.stats.retired == 2
    assert pool.retired_ch == [2, 0]
    assert all(pool.is_retired(b) for b in blocks[:2])
    free0 = pool.free_device
    pool.free(blocks)               # retired blocks never re-enter
    assert pool.free_device == free0 + 1
    with pytest.raises(AssertionError):
        pool.retire([blocks[0]])                       # never twice


def test_pool_exhausted_typed_channel_attribution():
    pool = BlockPool(4, 0, n_channels=2)               # 2 blocks/channel
    pool.alloc_for([0, 0])                             # drain channel 0
    with pytest.raises(PoolExhausted) as ei:
        pool.alloc_for([0])
    assert ei.value.channel == 0 and not ei.value.transient
    assert isinstance(ei.value, OutOfBlocks)           # old handlers work
    assert pool.exhausted_ch == [1, 0]
    # aggregate (channel-agnostic) shortage attributes the emptiest
    with pytest.raises(PoolExhausted) as ei:
        pool.alloc(3)
    assert ei.value.channel == 0
    assert pool.exhausted_ch == [2, 0]


# ------------------------------------------------- kvm injection points
def test_swap_fault_raises_before_any_mutation():
    kvm = KVPageManager(2, 4, 8, 8,
                        faults=FaultPlane(_plan(swap=[0])))
    kvm.new_seq(0, 2)
    pools = [jnp.arange(17.0)[:, None] * jnp.ones((1, 3))]
    pages0 = list(kvm.seq_pages[0])
    free0 = (kvm.pool.free_device, kvm.pool.free_host)
    with pytest.raises(SwapFault) as ei:
        kvm.swap_out(0, pools, check=False)
    assert (ei.value.slot, ei.value.n_blocks) == (0, 2)
    # pure retry contract: map, pools, page lists, free lists untouched
    assert kvm.seq_pages[0] == pages0
    assert (kvm.pool.free_device, kvm.pool.free_host) == free0
    assert kvm.is_resident(0)
    assert kvm.faults.counts()["swap"] == 1
    # schedule entry 1 is clean: the identical retry succeeds
    pools, moved = kvm.swap_out(0, pools, check=True)
    assert moved == 2 and not kvm.is_resident(0)
    st = kvm.hit_stats()
    assert st["swap_faults"] == 1 and st["swaps_out"] == 2


def test_alloc_fault_is_transient_and_pre_pop():
    kvm = KVPageManager(2, 4, 8, 0,
                        faults=FaultPlane(_plan(alloc=[0])))
    free0 = kvm.pool.free_device
    with pytest.raises(PoolExhausted) as ei:
        kvm.new_seq(0, 2)
    assert ei.value.transient and ei.value.channel == 0
    assert kvm.pool.free_device == free0               # nothing popped
    assert 0 not in kvm.seq_pages
    assert kvm.pool.exhausted_ch[0] == 1
    kvm.new_seq(0, 2)                                  # retry clean
    assert len(kvm.seq_pages[0]) == 2


def test_program_fault_retires_and_redrives_same_channel():
    kvm = KVPageManager(2, 4, 8, 0,
                        faults=FaultPlane(_plan(program=[0])))
    blocks = kvm.new_seq(0, 2)
    bad_stats = kvm.hit_stats()
    # schedule: program 0 (the first freshly mapped block) failed; its
    # replacement (consult 2) succeeded. CondUpdate re-drove the map.
    assert bad_stats["retired_blocks"] == 1
    assert bad_stats["program_faults"] == 1
    retired = [b for b in range(8) if kvm.pool.is_retired(b)]
    assert len(retired) == 1
    assert retired[0] not in blocks
    assert kvm.pool.channel_of(retired[0]) == \
        kvm.pool.channel_of(blocks[0])
    # the map agrees with the page list (the re-drive committed)
    tables = np.asarray(kvm.block_tables())
    np.testing.assert_array_equal(tables[0, :2], blocks)
    # retirement shrinks capacity permanently: 8 - 2 held - 1 retired
    assert kvm.pool.free_device == 5


def test_program_fault_redrive_chain_is_bounded():
    """Every program fails (p=1 schedule): the re-drive chain retires
    at most _MAX_REDRIVE candidates, keeps the last one regardless,
    and a dry channel defers retirement instead of deadlocking."""
    from repro.paging.kv_manager import _MAX_REDRIVE
    kvm = KVPageManager(1, 4, 16, 0,
                        faults=FaultPlane(_plan(
                            horizon=1, program=[0])))   # wraps: all True
    kvm.new_seq(0, 1)
    assert len(kvm.seq_pages[0]) == 1
    assert kvm.hit_stats()["retired_blocks"] == _MAX_REDRIVE
    # mapped block is the chain's last candidate, kept despite its
    # schedule failure (bounded recovery)
    assert not kvm.pool.is_retired(kvm.seq_pages[0][0])


def test_retire_bad_blocks_moves_rows_when_data_programmed():
    """The reconcile-path variant: data already lives in the bad block,
    so retirement must move rows old->new inside the fused CondUpdate
    jit (a bad block is just another relocation)."""
    kvm = KVPageManager(2, 4, 8, 0)
    blocks = kvm.new_seq(0, 2)
    pool = jnp.arange(8.0)[:, None] * jnp.ones((1, 3))
    victim = blocks[0]
    want = np.asarray(pool)[victim].copy()   # pool donates into the jit
    kvm.faults = FaultPlane(_plan())            # no schedule needed
    (moved,), n = kvm.retire_bad_blocks([(0, victim)], pools=[pool],
                                        block_axis=0)
    assert n == 1 and kvm.pool.is_retired(victim)
    new = kvm.seq_pages[0][0]
    assert new != victim
    np.testing.assert_array_equal(np.asarray(moved)[new], want)
    np.testing.assert_array_equal(
        np.asarray(kvm.block_tables())[0, :2], kvm.seq_pages[0])


# ------------------------------------------- disabled plane: zero cost
def test_disabled_plane_jaxpr_identical():
    """Attaching a plane must not change any traced graph: the plane
    is consumed at host commit points only. Asserted, not assumed —
    the fused serve and swap jaxprs are string-identical with and
    without a plane."""
    plain = KVPageManager(2, 4, 8, 8)
    faulty = KVPageManager(2, 4, 8, 8,
                           faults=FaultPlane(make_plan(
                               7, swap_fail_p=0.5, program_fail_p=0.5,
                               alloc_fail_p=0.5, stall=[4.0])))
    opc = np.zeros(4, np.int32)
    dl = np.arange(4, dtype=np.int32)
    args = (opc, dl, dl, dl)

    def serve_jaxpr(k):
        return str(jax.make_jaxpr(
            lambda s: k.fns["serve"](s, *args))(k.state))

    assert serve_jaxpr(plain) == serve_jaxpr(faulty)

    pools = [jnp.zeros((17, 2))]
    lanes = (dl, dl, dl, dl, dl, np.int32(0), True)

    def swap_jaxpr(k):
        fn = k._swap_fn(4, 0, 1)
        return str(jax.make_jaxpr(
            lambda s, p: fn(s, p, *lanes))(k.state, pools))

    assert swap_jaxpr(plain) == swap_jaxpr(faulty)


def test_zero_probability_plan_is_counter_identical(tiny):
    """A plan with all-zero probabilities must be bit-and-counter
    identical to no plan at all: same outputs, same engine metrics,
    zero fired faults — the hot path pays nothing when faults are
    'on but quiet'."""
    m, params = tiny
    eng = ServeEngine(m, params, n_slots=4, max_ctx=64,
                      n_device_blocks=10, n_host_blocks=24, macro_k=4,
                      swap_patience=2)

    def run():
        rids = [eng.submit(list(range(1 + 7 * i, 9 + 7 * i)),
                           max_new=16) for i in range(4)]
        done = eng.run()
        return [done[r] for r in rids], dict(eng.metrics)

    out_none, met_none = run()
    eng.reset(FaultPlane(make_plan(99)))       # p=0 on every axis
    out_zero, met_zero = run()
    assert out_none == out_zero
    assert met_none == met_zero
    assert eng.faults.counts() == {"swap": 0, "program": 0, "alloc": 0,
                                   "crash": 0}
    st = eng.kvm.hit_stats()
    assert st["swap_faults"] == st["program_faults"] == \
        st["alloc_faults"] == 0
    assert st["retired_blocks"] == 0


# --------------------------------- engine retry/backoff/quarantine FSM
def _stub_engine(max_retries=3, cap=8, watchdog=4):
    """The scheduler-side recovery state machine on a stub: the methods
    under test (_note_swap_fault/_backed_off/_quarantine/_release_slot/
    _watchdog) touch only host bookkeeping, so no model is needed."""
    from repro.serving.engine import Request
    e = types.SimpleNamespace()
    e.metrics = {"swap_faults": 0, "quarantines": 0,
                 "watchdog_quarantines": 0, "requeues": 0}
    e._swap_fails, e._retry_at, e._progress = {}, {}, {}
    e._pending_since, e._resident_since = {}, {}
    e.active, e.queue = {}, __import__("collections").deque()
    e.ctx_lens = np.zeros(4, np.int32)
    e._boundary = 0
    e.max_swap_retries, e.swap_backoff_cap = max_retries, cap
    e.watchdog_rounds = watchdog
    e.kvm = types.SimpleNamespace(freed=[])
    e.kvm.free_seq = e.kvm.freed.append
    e.journal = None          # quarantine journals when attached (PR 7)
    for name in ("_note_swap_fault", "_backed_off", "_quarantine",
                 "_release_slot", "_watchdog"):
        setattr(e, name, types.MethodType(getattr(ServeEngine, name), e))
    req = Request(rid=0, tokens=[1, 2], max_new=4, out=[9], slot=1)
    e.active[0] = req
    return e, req


@example(fails=3, retries=3, cap=8)     # quarantine exactly at the cap
@example(fails=2, retries=3, cap=8)     # backoff only, no quarantine
@example(fails=6, retries=7, cap=4)     # backoff saturates at the cap
@example(fails=1, retries=1, cap=8)     # immediate quarantine
@settings(max_examples=50, deadline=None)
@given(fails=st.integers(1, 12), retries=st.integers(1, 8),
       cap=st.integers(1, 32))
def test_retry_backoff_quarantine_property(fails, retries, cap):
    """For any failure run: backoff is exactly min(2^n, cap) boundaries
    after the n-th consecutive failure, the window gates _backed_off,
    quarantine fires exactly when n reaches max_swap_retries — freeing
    pages ONCE, requeuing the request at the admission front with
    output reset — and per-slot state is fully cleared."""
    e, req = _stub_engine(max_retries=retries, cap=cap)
    for n in range(1, fails + 1):
        if 0 not in e.active:
            break                       # already quarantined
        e._note_swap_fault(1)
        if n >= retries:
            assert 0 not in e.active, "quarantine late"
            break
        assert e._retry_at[1] - e._boundary == min(2 ** n, cap)
        assert e._backed_off(1)
        e._boundary += min(2 ** n, cap)
        assert not e._backed_off(1)     # window exactly closed
    quarantined = fails >= retries
    assert e.metrics["quarantines"] == int(quarantined)
    assert e.metrics["swap_faults"] == min(fails, retries)
    if quarantined:
        assert e.kvm.freed == [1]       # pages freed exactly once
        assert list(e.queue)[0] is req  # admission FRONT
        assert req.slot == -1 and req.out == []
        for d in (e._swap_fails, e._retry_at, e._progress):
            assert 1 not in d           # slot state fully released
        assert e.ctx_lens[1] == 0


def test_watchdog_quarantines_stalled_lane_only():
    e, req = _stub_engine(watchdog=3)
    from repro.serving.engine import Request
    live = Request(rid=1, tokens=[1], max_new=4, out=[], slot=2)
    e.active[1] = live
    for _ in range(6):
        e._boundary += 1
        e._watchdog()
        live.out.append(7)              # lane 2 makes progress; 1 not
    assert 0 not in e.active, "stalled lane not quarantined"
    assert 1 in e.active, "progressing lane wrongly quarantined"
    assert e.metrics["watchdog_quarantines"] == 1
    assert list(e.queue) == [req]


def test_free_eff_degrades_stalled_channels_only():
    e = types.SimpleNamespace(channels=2)
    e.kvm = types.SimpleNamespace(
        free_device_vec=lambda: np.asarray([12, 9], np.int64))
    e._free_eff = types.MethodType(ServeEngine._free_eff, e)
    e._stall_shrink = types.MethodType(ServeEngine._stall_shrink, e)
    e.faults = None
    np.testing.assert_array_equal(e._free_eff(), [12, 9])
    e.faults = FaultPlane(_plan(channels=2, stall=[4.0, 1.0]))
    np.testing.assert_array_equal(e._free_eff(), [3, 9])


# ----------------------------------------------- engine-level recovery
def test_swap_retry_then_success_end_to_end(tiny):
    """One injected swap failure under oversubscription: the engine
    backs the slot off, retries after the window, and the outputs stay
    bit-identical to the fault-free run (retry is pure)."""
    m, params = tiny
    eng = ServeEngine(m, params, n_slots=4, max_ctx=64,
                      n_device_blocks=10, n_host_blocks=24, macro_k=4,
                      swap_patience=2)
    prompts = [list(range(1 + 7 * i, 9 + 7 * i)) for i in range(4)]

    def run():
        rids = [eng.submit(list(p), max_new=16) for p in prompts]
        done = eng.run()
        return [done[r] for r in rids]

    ref = run()
    eng.reset(FaultPlane(_plan(horizon=64, swap=[0, 3])))
    got = run()
    assert got == ref
    assert eng.metrics["swap_faults"] >= 1
    assert eng.metrics["quarantines"] == 0     # retries sufficed


def test_quarantine_releases_reservation_same_boundary(tiny):
    """Satellite 6 regression: when a preemption victim's swap-out
    fails terminally (retries exhausted -> quarantine), its freed pages
    must satisfy the blocked allocation in the SAME scheduling round —
    the engine neither raises OutOfBlocks nor deadlocks, the
    quarantined request restarts from the admission front, and every
    output matches the fault-free run."""
    m, params = tiny
    eng = ServeEngine(m, params, n_slots=2, max_ctx=64,
                      n_device_blocks=2, n_host_blocks=4,
                      fault_plane=FaultPlane(_plan(horizon=64, swap=[0])),
                      max_swap_retries=1)     # first failure quarantines
    t1, t2 = list(range(1, 9)), list(range(30, 38))
    r1 = eng.submit(t1, max_new=6)
    r2 = eng.submit(t2, max_new=6)
    done = eng.run()
    assert set(done) == {r1, r2}
    assert eng.metrics["quarantines"] >= 1
    assert eng.metrics["requeues"] >= 1
    for toks, rid in [(t1, r1), (t2, r2)]:
        solo = ServeEngine(m, params, n_slots=1, max_ctx=64)
        rs = solo.submit(list(toks), max_new=6)
        assert solo.run()[rs] == done[rid], rid


def test_transient_alloc_fault_does_not_trip_livelock_raise(tiny):
    """The _grow_pages livelock guard must distinguish injected
    transient exhaustion (schedule advances -> retry is progress) from
    genuine dry-pool pressure (same state recurs -> raise)."""
    m, params = tiny
    eng = ServeEngine(
        m, params, n_slots=1, max_ctx=64, n_device_blocks=4,
        n_host_blocks=0,
        fault_plane=FaultPlane(_plan(horizon=64, alloc=[1, 2, 5])))
    rid = eng.submit(list(range(1, 9)), max_new=12)
    done = eng.run()
    assert rid in done
    assert eng.kvm.hit_stats()["alloc_faults"] >= 1
    solo = ServeEngine(m, params, n_slots=1, max_ctx=64)
    rs = solo.submit(list(range(1, 9)), max_new=12)
    assert solo.run()[rs] == done[rid]


def test_macro_program_fault_relocates_written_rows(tiny):
    """C=1 macro path: a program fault on a block the scan already
    WROTE must relocate both the mapping and the KV rows (the
    retire-with-pools path) — tokens stay bit-identical to the
    fault-free run, which would fail if the rows were dropped."""
    m, params = tiny
    eng = ServeEngine(m, params, n_slots=2, max_ctx=64, macro_k=4)
    prompts = [list(range(1, 8)), list(range(40, 47))]

    def run():
        rids = [eng.submit(list(p), max_new=16) for p in prompts]
        done = eng.run()
        return [done[r] for r in rids]

    ref = run()
    eng.reset(FaultPlane(_plan(horizon=64, program=[2, 3, 7])))
    got = run()
    assert got == ref
    st = eng.kvm.hit_stats()
    assert st["retired_blocks"] >= 1
    assert st["program_faults"] >= 1


# ------------------------------------------------- detection latency
def test_oob_detection_latency_is_at_most_k_tokens(tiny):
    """The in-graph allocation-failure flag is written at the failing
    scan step but OBSERVABLE only at the next host sync — up to K
    tokens later (the documented detection latency; stickiness makes
    the deferred read lossless). Forcing the macro path onto a dry
    pool: the host's typed per-channel exhaustion count is zero before
    the boundary and folded exactly at it."""
    m, params = tiny
    eng = ServeEngine(m, params, n_slots=1, max_ctx=64,
                      n_device_blocks=1, n_host_blocks=0, macro_k=4)
    # bypass the proactive eligibility check so the scan really runs
    # its allocator dry (the reactive path under test)
    eng._macro_eligible = lambda: True
    eng.submit(list(range(1, 9)), max_new=3)   # budget < K: full mode
    done: dict = {}
    assert eng.kvm.hit_stats()["pool_exhausted"] == [0]
    eng.step(done)                             # scan: growth fails in-graph
    st = eng.kvm.hit_stats()
    assert st["pool_exhausted"][0] >= 1, \
        "boundary never folded the sticky oob flag"
    # the failing lane paused in-scan: nothing was emitted into the
    # scratch block's shadow (full-mode NIL masking)
    assert eng.metrics["generated"] <= 1       # prefill token only
    # the resync acknowledges + clears the flag lane
    eng.kvm.sync_allocator()
    assert not bool(np.asarray(eng.kvm.state.oob))


def test_sharded_oob_lane_folds_per_channel():
    """C>1 silent-flag regression (satellite a): each channel's sticky
    flag folds into its own typed exhaustion count at sync — before
    the fix the C>1 engine cleared the lane without ever reading it."""
    kvm = KVPageManager(2, 4, 8, 0, channels=2, use_mesh=False)
    kvm.new_seq(0, 2)
    kvm.state = kvm.state._replace(
        oob=jnp.asarray([True, False]))        # channel 0 ran dry
    kvm._alloc_dirty = True
    kvm.sync_allocator()
    assert kvm.pool.exhausted_ch == [1, 0]
    np.testing.assert_array_equal(np.asarray(kvm.state.oob),
                                  [False, False])
