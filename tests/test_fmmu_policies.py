"""Targeted tests of the paper's §4.4–4.6 policies on the oracle:
second-chance replacement, watermark flushing, DTL greedy victim
selection, WRR arbitration weights (incl. the GC-pressure adjustment)."""
import pytest

from repro.core.fmmu.oracle import FMMUOracle, Q_GCM, Q_HRM
from repro.core.fmmu.types import (LOOKUP, NIL, Request, UPDATE,
                                   small_geometry)


def _fill_set(o, set_idx, n, write=False, base_rid=0):
    """Touch n distinct blocks that map to the same CMT set."""
    g = o.g
    for i in range(n):
        block_id = set_idx + i * g.cmt_sets
        dlpn = block_id * g.cmt_entries
        o.push_request(Request(UPDATE if write else LOOKUP, dlpn,
                               dppn=100 + i, req_id=base_rid + i))
    o.run(auto_flash=True)
    o.drain_outputs()


def test_second_chance_gives_referenced_blocks_a_pass():
    g = small_geometry(cmt_ways=2)
    o = FMMUOracle(g)
    # fill both ways of set 0
    _fill_set(o, 0, 2)
    blk0, blk1 = o.cmt[0][0], o.cmt[0][1]
    tag0 = blk0.tag
    # white-box: way0 recently referenced, way1 not
    blk0.refbit = True
    blk1.refbit = False
    o.cmt_clock[0] = 0
    dlpn3 = (0 + 2 * g.cmt_sets) * g.cmt_entries
    o.push_request(Request(LOOKUP, dlpn3, req_id=70))
    o.run(auto_flash=True)
    tags = {o.cmt[0][w].tag for w in range(g.cmt_ways)
            if o.cmt[0][w].valid or o.cmt[0][w].transient}
    assert tag0 in tags, "recently-referenced block was evicted"
    # and its second chance was consumed
    assert not o.cmt[0][0].refbit or o.cmt[0][0].tag != tag0


def test_watermark_flush_triggers_and_stops():
    g = small_geometry()
    o = FMMUOracle(g)
    total = g.cmt_blocks
    # dirty enough blocks to cross the low watermark
    n_dirty_target = total - g.cmt_low() + 1
    i = 0
    while o.cmt_dirty < n_dirty_target and i < 10 * total:
        o.push_request(Request(UPDATE, (i * g.cmt_entries) %
                               (g.n_tvpns * g.entries_per_tp),
                               dppn=i, req_id=i))
        o.run(auto_flash=True)
        o.drain_outputs()
        i += 1
    # flushing must have kicked in and restored the high watermark
    assert (total - o.cmt_dirty) >= g.cmt_low()
    assert o.stats["flush_tvpns"] > 0


def test_dtl_greedy_picks_most_dirty_tvpn():
    g = small_geometry()
    o = FMMUOracle(g)
    # 3 dirty blocks in TVPN 1, 1 dirty block in TVPN 0
    for j in range(3):
        o.push_request(Request(UPDATE, g.entries_per_tp + j * g.cmt_entries,
                               dppn=j, req_id=j))
    o.push_request(Request(UPDATE, 0, dppn=9, req_id=9))
    o.run(auto_flash=True)
    victim = o._pick_flush_victim()
    assert victim["tvpn"] == 1
    assert victim["ndirty"] == 3


def test_wrr_responses_outweigh_requests():
    g = small_geometry()
    w = g.wrr_weights
    assert w[0] >= w[3] and w[1] >= w[3], \
        "response queues must have >= weight than request queues (§4.6)"
    assert w[3] >= w[4], "HRM default >= GCM"


def test_gc_pressure_shifts_weights():
    g = small_geometry()
    o = FMMUOracle(g)
    base_gcm = o.g.wrr_weights[Q_GCM]
    o.set_gc_pressure(valid_pages_in_victim=240, pages_per_block=256)
    assert o.g.wrr_weights[Q_GCM] > base_gcm, \
        "high-valid GC victim must raise GCM weight (§4.6)"


def test_arbitration_interleaves_hrm_and_gcm():
    g = small_geometry()
    o = FMMUOracle(g)
    for i in range(8):
        o.push_request(Request(LOOKUP, i * g.cmt_entries, req_id=i, src=0))
        o.push_request(Request(LOOKUP, (i + 8) * g.cmt_entries,
                               req_id=100 + i, src=1))
    o.run(auto_flash=True)
    resps, _, _ = o.drain_outputs()
    order = [r.req_id for r in resps]
    hrm_pos = [i for i, r in enumerate(order) if r < 100]
    gcm_pos = [i for i, r in enumerate(order) if r >= 100]
    assert hrm_pos and gcm_pos
    # GCM must not be starved until all HRM requests completed
    assert min(gcm_pos) < max(hrm_pos), f"GCM starved: {order}"


def test_flush_all_idempotent():
    g = small_geometry()
    o = FMMUOracle(g)
    for i in range(20):
        o.push_request(Request(UPDATE, i * 3, dppn=i, req_id=i))
    o.run(auto_flash=True)
    o.flush_all()
    p1 = o.stats["programs"]
    o.flush_all()
    assert o.stats["programs"] == p1, "second flush_all wrote again"
