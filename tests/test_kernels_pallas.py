"""Per-kernel Pallas validation (interpret mode on CPU): sweep shapes and
dtypes, assert_allclose against the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fmmu.types import small_geometry
from repro.kernels import ref
from repro.kernels import flash_attention as fa
from repro.kernels import paged_attention as pa
from repro.kernels import mamba_scan as ms
from repro.kernels import fmmu_lookup as fl


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("sq,skv,h,kv,d", [
    (128, 128, 4, 4, 32),
    (128, 128, 4, 2, 64),     # GQA
    (64, 192, 2, 1, 32),      # cross-length (right-aligned causal)
    (256, 256, 2, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(sq, skv, h, kv, d, dtype):
    k = jax.random.key(0)
    q = jax.random.normal(jax.random.fold_in(k, 1), (2, sq, h, d), dtype)
    kk = jax.random.normal(jax.random.fold_in(k, 2), (2, skv, kv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(k, 3), (2, skv, kv, d), dtype)
    out = fa.flash_attention(q, kk, v, causal=True, q_block=64, kv_block=64,
                             interpret=True)
    want = ref.attention_naive(q, kk, v, causal=True)
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=TOL[dtype],
                               rtol=TOL[dtype])


@pytest.mark.parametrize("kwargs", [
    dict(window=64), dict(softcap=30.0), dict(window=96, softcap=20.0),
    dict(causal=False, bidirectional=True),
])
def test_flash_attention_variants(kwargs):
    k = jax.random.key(1)
    q = jax.random.normal(jax.random.fold_in(k, 1), (1, 256, 4, 64))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (1, 256, 2, 64))
    v = jax.random.normal(jax.random.fold_in(k, 3), (1, 256, 2, 64))
    kwargs.setdefault("causal", True)
    out = fa.flash_attention(q, kk, v, q_block=64, kv_block=64,
                             interpret=True, **kwargs)
    want = ref.attention_naive(q, kk, v, **kwargs)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_flash_attention_unaligned_seq():
    """Sequence not a block multiple -> padded, result identical."""
    k = jax.random.key(2)
    q = jax.random.normal(jax.random.fold_in(k, 1), (1, 100, 2, 32))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (1, 100, 2, 32))
    v = jax.random.normal(jax.random.fold_in(k, 3), (1, 100, 2, 32))
    out = fa.flash_attention(q, kk, v, q_block=64, kv_block=64,
                             interpret=True)
    want = ref.attention_naive(q, kk, v)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kv,d,page,maxp", [
    (2, 4, 4, 32, 16, 8),
    (3, 8, 2, 64, 8, 6),      # GQA
    (1, 4, 1, 128, 32, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_shapes(b, h, kv, d, page, maxp, dtype):
    k = jax.random.key(3)
    nb = b * maxp + 4
    q = jax.random.normal(jax.random.fold_in(k, 1), (b, h, d), dtype)
    kp = jax.random.normal(jax.random.fold_in(k, 2), (nb, page, kv, d), dtype)
    vp = jax.random.normal(jax.random.fold_in(k, 3), (nb, page, kv, d), dtype)
    table = jax.random.permutation(
        jax.random.fold_in(k, 4), jnp.arange(nb))[:b * maxp].reshape(b, maxp)
    ctx = jnp.asarray([(maxp * page * (i + 1)) // (b + 1) + 1
                       for i in range(b)], jnp.int32)
    out, (m, l) = pa.paged_attention(q, kp, vp, table, ctx,
                                     return_stats=True, interpret=True)
    want, (wm, wl) = ref.paged_attention_naive(q, kp, vp, table, ctx,
                                               return_stats=True)
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])
    np.testing.assert_allclose(m, wm, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(l, wl, atol=1e-4, rtol=1e-4)


def test_paged_attention_softcap():
    k = jax.random.key(4)
    b, h, kv, d, page, maxp = 2, 4, 2, 32, 8, 4
    nb = b * maxp
    q = jax.random.normal(jax.random.fold_in(k, 1), (b, h, d))
    kp = jax.random.normal(jax.random.fold_in(k, 2), (nb, page, kv, d))
    vp = jax.random.normal(jax.random.fold_in(k, 3), (nb, page, kv, d))
    table = jnp.arange(nb).reshape(b, maxp)
    ctx = jnp.array([17, 30])
    out = pa.paged_attention(q, kp, vp, table, ctx, softcap=25.0,
                             interpret=True)
    want = ref.paged_attention_naive(q, kp, vp, table, ctx, softcap=25.0)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("bt,s,h,p,n,chunk", [
    (2, 128, 2, 16, 8, 32),
    (1, 256, 4, 64, 128, 64),   # production-ish head
    (2, 96, 2, 32, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_shapes(bt, s, h, p, n, chunk, dtype):
    k = jax.random.key(5)
    x = jax.random.normal(jax.random.fold_in(k, 1), (bt, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 2),
                                           (bt, s, h))).astype(dtype)
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 3), (h,)))
    B = jax.random.normal(jax.random.fold_in(k, 4), (bt, s, n), dtype)
    C = jax.random.normal(jax.random.fold_in(k, 5), (bt, s, n), dtype)
    D = jnp.ones((h,))
    y, fin = ms.mamba_chunk_scan(x, dt, A, B, C, D, chunk=chunk,
                                 interpret=True)
    yw, fw = ref.mamba_chunk_scan_naive(x, dt, A, B, C, D, chunk=chunk)
    tol = 5e-3 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(y.astype(np.float32), yw.astype(np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(fin, fw, atol=tol, rtol=tol)


def test_mamba_scan_initial_state():
    k = jax.random.key(6)
    bt, s, h, p, n, chunk = 1, 64, 2, 8, 4, 16
    x = jax.random.normal(jax.random.fold_in(k, 1), (bt, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 2), (bt, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 3), (h,)))
    B = jax.random.normal(jax.random.fold_in(k, 4), (bt, s, n))
    C = jax.random.normal(jax.random.fold_in(k, 5), (bt, s, n))
    D = jnp.zeros((h,))
    s0 = jax.random.normal(jax.random.fold_in(k, 7), (bt, h, p, n))
    y, fin = ms.mamba_chunk_scan(x, dt, A, B, C, D, chunk=chunk,
                                 initial_state=s0, interpret=True)
    yw, fw = ref.mamba_chunk_scan_naive(x, dt, A, B, C, D, chunk=chunk,
                                        initial_state=s0)
    np.testing.assert_allclose(y, yw, atol=5e-3, rtol=5e-3)
    np.testing.assert_allclose(fin, fw, atol=5e-3, rtol=5e-3)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("n_sets,n_ways,e,bq", [
    (8, 2, 4, 64), (16, 4, 8, 256), (4, 1, 4, 33)])
def test_fmmu_lookup_vs_ref(n_sets, n_ways, e, bq):
    k = jax.random.key(7)
    tags = jax.random.randint(jax.random.fold_in(k, 1),
                              (n_sets, n_ways), 0, 64)
    # force tag-set consistency: tags in set s must be ≡ s (mod n_sets)
    tags = tags * n_sets + jnp.arange(n_sets)[:, None]
    valid = jax.random.bernoulli(jax.random.fold_in(k, 2), 0.7,
                                 (n_sets, n_ways))
    data = jax.random.randint(jax.random.fold_in(k, 3),
                              (n_sets, n_ways, e), -1, 1 << 26)
    dlpns = jax.random.randint(jax.random.fold_in(k, 4), (bq,), -2,
                               64 * n_sets * e)
    got = fl.fmmu_lookup(tags, valid, data, dlpns, entries_per_block=e,
                         block_size=32, interpret=True)
    want = ref.fmmu_lookup_ref(tags, valid, data, dlpns,
                               entries_per_block=e)
    np.testing.assert_array_equal(got[0], want[0])  # hit
    np.testing.assert_array_equal(got[1], want[1])  # dppn
    np.testing.assert_array_equal(got[2], want[2])  # set
    # way only meaningful on hits
    np.testing.assert_array_equal(np.where(got[0], got[3], 0),
                                  np.where(want[0], want[3], 0))


def test_ops_dispatch_pallas_interpret():
    """ops.py dispatch: pallas_interpret path matches blocked path."""
    from repro.kernels import ops
    k = jax.random.key(8)
    q = jax.random.normal(jax.random.fold_in(k, 1), (1, 128, 2, 32))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(k, 3), (1, 128, 2, 32))
    a = ops.flash_attention(q, kk, v, impl="pallas_interpret")
    b = ops.flash_attention(q, kk, v, impl="blocked")
    np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("n_sets,n_ways,e,bq,np_sz", [
    (8, 2, 4, 64, 256), (16, 4, 8, 300, 5000), (4, 1, 4, 33, 100)])
def test_fmmu_translate_vs_ref(n_sets, n_ways, e, bq, np_sz):
    """Fused translate kernel (probe + backing fallback + ref touch)
    matches the reference lowering bit-for-bit, including the streamed
    backing gather crossing chunk boundaries and the [S,W] ref output."""
    from repro.kernels import fmmu_translate as ft
    k = jax.random.key(11)
    tags = jax.random.randint(jax.random.fold_in(k, 1),
                              (n_sets, n_ways), 0, 64)
    tags = tags * n_sets + jnp.arange(n_sets)[:, None]
    valid = jax.random.bernoulli(jax.random.fold_in(k, 2), 0.7,
                                 (n_sets, n_ways))
    refb = jax.random.bernoulli(jax.random.fold_in(k, 6), 0.3,
                                (n_sets, n_ways))
    # value range deliberately crosses 2^24: host-tier block ids are
    # tagged at 1<<24 and above, so value gathers must stay bit-exact
    # past f32's exact-integer range
    data = jax.random.randint(jax.random.fold_in(k, 3),
                              (n_sets, n_ways, e), -1, 1 << 26)
    backing = jax.random.randint(jax.random.fold_in(k, 5), (np_sz,),
                                 -1, 1 << 26)
    # upper range deliberately exceeds NP: out-of-contract dlpns must
    # clip to backing[NP-1] identically on every impl path
    dlpns = jax.random.randint(jax.random.fold_in(k, 4), (bq,), -2,
                               np_sz + 3)
    touch = jax.random.bernoulli(jax.random.fold_in(k, 8), 0.6, (bq,))
    got = ft.fmmu_translate(tags, valid, refb, data, backing, dlpns,
                            touch, entries_per_block=e, block_size=32,
                            backing_chunk=96, interpret=True)
    want = ref.fmmu_translate_ref(tags, valid, refb, data, backing,
                                  dlpns, touch, entries_per_block=e)
    np.testing.assert_array_equal(got[0], want[0])  # hit
    np.testing.assert_array_equal(got[1], want[1])  # out dppn
    np.testing.assert_array_equal(got[2], want[2])  # set
    np.testing.assert_array_equal(np.where(got[0], got[3], 0),
                                  np.where(want[0], want[3], 0))
    np.testing.assert_array_equal(got[4], want[4])  # ref bits


def test_fmmu_translate_partial_last_chunk():
    """ISSUE-3 chunk-grid edge: n_backing NOT a multiple of
    backing_chunk — misses whose dlpn lands in the final partial chunk
    (and right at the chunk seam) must gather their backing value from
    the padded tile bit-exactly, interpret-vs-ref."""
    from repro.kernels import fmmu_translate as ft
    n_sets, n_ways, e = 4, 2, 4
    np_sz, chunk = 130, 64            # 130 = 64 + 64 + 2: last tile 2/64
    k = jax.random.key(3)
    tags = jnp.full((n_sets, n_ways), -1)
    valid = jnp.zeros((n_sets, n_ways), bool)    # empty cache: all miss
    refb = jnp.zeros((n_sets, n_ways), bool)
    data = jnp.full((n_sets, n_ways, e), -1)
    backing = jax.random.randint(k, (np_sz,), -1, 1 << 26)
    # seam and tail coverage: last entry of tile 0, first of tile 1,
    # the two real entries of the partial tile 2, plus interior points
    dlpns = jnp.array([63, 64, 127, 128, 129, 0, 65, 120], jnp.int32)
    touch = jnp.ones(dlpns.shape, bool)
    got = ft.fmmu_translate(tags, valid, refb, data, backing, dlpns,
                            touch, entries_per_block=e, block_size=8,
                            backing_chunk=chunk, interpret=True)
    want = ref.fmmu_translate_ref(tags, valid, refb, data, backing,
                                  dlpns, touch, entries_per_block=e)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    np.testing.assert_array_equal(got[1],
                                  backing[jnp.clip(dlpns, 0, np_sz - 1)])


def test_fmmu_translate_all_dlpns_beyond_np_clip():
    """ISSUE-3 chunk-grid edge: every dlpn >= NP — the out-of-contract
    clip must serve backing[NP-1] on every lane (not the pad region,
    not a silent no-match), identically on interpret and ref paths."""
    from repro.kernels import fmmu_translate as ft
    n_sets, n_ways, e = 4, 2, 4
    np_sz = 100                       # padded to 192 with chunk 96
    k = jax.random.key(4)
    tags = jnp.full((n_sets, n_ways), -1)
    valid = jnp.zeros((n_sets, n_ways), bool)
    refb = jnp.zeros((n_sets, n_ways), bool)
    data = jnp.full((n_sets, n_ways, e), -1)
    backing = jax.random.randint(k, (np_sz,), -1, 1 << 26)
    dlpns = jnp.array([100, 101, 150, 191, 192, 1000], jnp.int32)
    touch = jnp.ones(dlpns.shape, bool)
    got = ft.fmmu_translate(tags, valid, refb, data, backing, dlpns,
                            touch, entries_per_block=e, block_size=8,
                            backing_chunk=96, interpret=True)
    want = ref.fmmu_translate_ref(tags, valid, refb, data, backing,
                                  dlpns, touch, entries_per_block=e)
    np.testing.assert_array_equal(got[1], want[1])
    assert (np.asarray(got[1]) == int(backing[np_sz - 1])).all()
