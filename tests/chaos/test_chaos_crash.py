"""Chaos crash sweep (ISSUE 7): randomized sudden-power-off schedules
— composed with the ISSUE-6 fault axes — against the journaled serving
engine, across channel counts.

Every seed draws a crash probability, a tear distribution, a snapshot
interval, and mild swap/program/alloc fault rates. The run submits the
fixed oversubscribed workload, and every time the scheduled power cut
fires (``faults.Crash`` escaping the engine), the harness recovers
from the journal directory and keeps going — exactly a client that
re-submits what was never durably accepted. The invariants:

  1. the run DRAINS across any number of crashes (bounded, since
     FINISH records make completed work durable and snapshots bound
     replay);
  2. the union of durable + resumed outputs is BIT-IDENTICAL to the
     fault-free oracle — greedy determinism + the quarantine-restart
     discipline make a recovered in-flight request reproduce its
     tokens.

Failures print the schedule seed; ``make_plan(seed, ...)`` with the
printed parameters reproduces the run. Vacuity is asserted on the
aggregate: schedules must actually crash, tear records mid-byte, and
recover torn map commits through the OOB reverse-map scan.
"""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.core import faults as flt
from repro.core.faults import FaultPlane, make_plan
from repro.models import Runtime, build_model
from repro.serving.engine import ServeEngine

pytestmark = pytest.mark.recovery

RT = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
             remat="none", page_size=8, capacity_factor=100.0)

CHANNELS = (1, 2, 4)
PROMPTS = [list(range(3 + 11 * i, 10 + 11 * i)) for i in range(6)]
MAX_NEW = 10
MAX_STEPS = 4000
MAX_CRASHES = 30

_CACHE: dict = {}


def _engine(C: int) -> ServeEngine:
    eng = _CACHE.get(C)
    if eng is None:
        m = _CACHE.get("model")
        if m is None:
            cfg = smoke_config(get_arch("llama3.2-1b"))
            cfg = dataclasses.replace(
                cfg, name="chaos-crash-tiny", n_layers=cfg.period,
                d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
                d_ff=64, vocab_size=128)
            model = build_model(cfg, RT)
            m = (model, model.init(jax.random.key(0)))
            _CACHE["model"] = m
        model, params = m
        eng = ServeEngine(model, params, n_slots=4, max_ctx=64,
                          n_device_blocks=12, n_host_blocks=24,
                          macro_k=4, swap_patience=2, channels=C,
                          watchdog_rounds=16)
        _CACHE[C] = eng
    return eng


def _oracle(C: int):
    key = ("oracle", C)
    if key not in _CACHE:
        eng = _engine(C)
        eng.reset(None)
        rids = [eng.submit(list(p), max_new=MAX_NEW) for p in PROMPTS]
        done = eng.run(max_steps=MAX_STEPS)
        assert not eng.active and not eng.queue, "oracle did not drain"
        _CACHE[key] = [done[r] for r in rids]
    return _CACHE[key]


def _schedule(seed: int, C: int):
    rng = np.random.default_rng(seed)
    stall = np.ones(C)
    if rng.random() < 0.3:
        stall[rng.integers(C)] = rng.uniform(2.0, 4.0)
    return (dict(channels=C,
                 crash_p=float(rng.uniform(0.01, 0.05)),
                 swap_fail_p=float(rng.uniform(0, 0.15)),
                 program_fail_p=float(rng.uniform(0, 0.1)),
                 alloc_fail_p=float(rng.uniform(0, 0.1)),
                 stall=stall.tolist()),
            int(rng.choice([1, 4, 16])))


def _run_one(C: int, seed: int, ref):
    """One schedule: journaled run, recover on every scheduled power
    cut, re-submit what was never durable, drain. Returns per-run
    coverage counters."""
    eng = _engine(C)
    kw, snap_every = _schedule(seed, C)
    plane = FaultPlane(make_plan(seed, **kw))
    msg = f"chaos-crash seed={seed} channels={C} plan={plane.describe()}"
    cov = {"crashes": 0, "torn": 0, "oob_scans": 0, "replayed": 0}
    with tempfile.TemporaryDirectory() as d:
        eng.reset(plane)
        eng.attach_journal(d, snapshot_every=snap_every)
        to_submit = list(range(len(PROMPTS)))
        rid_to_idx: dict = {}
        final: dict = {}
        while True:
            try:
                for i in to_submit:
                    rid_to_idx[eng.submit(list(PROMPTS[i]),
                                          max_new=MAX_NEW)] = i
                to_submit = []
                done = eng.run(max_steps=MAX_STEPS)
                break
            except flt.Crash:
                cov["crashes"] += 1
                if cov["crashes"] > MAX_CRASHES:
                    print(f"\nCHAOS-CRASH FAILURE {msg}: "
                          f">{MAX_CRASHES} crashes without draining")
                    raise
                # the SAME plane resumes: its op counters carry across
                # the recovery, so later scheduled cuts still fire
                durable = eng.recover(d, fault_plane=plane)
                info = eng.last_recovery
                cov["torn"] += int(info["torn"])
                cov["oob_scans"] += int(info["oob_scan"])
                cov["replayed"] += int(info["replayed"])
                present = set(durable) | {r.rid for r in eng.queue}
                rid_to_idx = {r: i for r, i in rid_to_idx.items()
                              if r in present}
                for r, out in durable.items():
                    if r in rid_to_idx:
                        final[rid_to_idx[r]] = out
                covered = set(rid_to_idx.values())
                to_submit = [i for i in range(len(PROMPTS))
                             if i not in covered]
        for r, out in done.items():
            if r in rid_to_idx:
                final[rid_to_idx[r]] = out
        final.update({rid_to_idx[r]: out
                      for r, out in eng._finished.items()
                      if r in rid_to_idx})
        undrained = [i for i in range(len(PROMPTS)) if i not in final]
        if undrained or eng.active or eng.queue:
            print(f"\nCHAOS-CRASH FAILURE {msg} undrained={undrained}")
        assert not undrained and not eng.active and not eng.queue, msg
        got = [final[i] for i in range(len(PROMPTS))]
        if got != ref:
            print(f"\nCHAOS-CRASH FAILURE {msg} "
                  f"metrics={eng.metrics} cov={cov}")
        assert got == ref, msg
        assert eng.journal_lane_check(), msg
        eng.reset(None)        # close the journal before the dir goes
    return cov


@pytest.mark.parametrize("channels", CHANNELS)
def test_chaos_crash_quick(channels):
    """A few crash schedules per channel count in the default lanes —
    the canary for the @slow acceptance sweep below."""
    ref = _oracle(channels)
    agg = {"crashes": 0, "torn": 0, "oob_scans": 0, "replayed": 0}
    for seed in range(300, 304):
        cov = _run_one(channels, seed, ref)
        for k in agg:
            agg[k] += cov[k]
    assert agg["crashes"] > 0, "no schedule ever crashed (vacuous)"


@pytest.mark.gc
def test_crash_during_gc_walk_recovers_bit_identical():
    """ISSUE 9: GC relocations are journaled host commits, so a power
    cut landing ON the GC record itself must recover bit-identically.
    The schedule is pinned: an uncrashed journaled GC-enabled run
    locates its first 'gc' record, then a second run crashes exactly
    there (make_plan crash_at) and recovers."""
    from repro.core import journal as jl
    from repro.serving.config import GCConfig, ServeConfig
    model, params = _CACHE["model"] if "model" in _CACHE else (None,)*2
    if model is None:
        _engine(1)                       # populate the model cache
        model, params = _CACHE["model"]
    cfg = ServeConfig(
        n_slots=4, max_ctx=64, n_device_blocks=12, n_host_blocks=24,
        macro_k=4, swap_patience=2,
        faults=FaultPolicy_watchdog16(),
        gc=GCConfig(watermark=3, pages_per_boundary=8, block_pages=2,
                    prefetch=True))
    eng = ServeEngine(model, params, config=cfg)
    # longer prompts than the sweep's: 4-page sequences over a
    # 12-block pool churn the free lists enough to fragment erase
    # blocks, which is what gives the victim walk real work
    prompts = [list(range(1 + i, 20 + i)) for i in range(6)]

    def drive(plane):
        rids = [eng.submit(list(p), max_new=MAX_NEW) for p in prompts]
        done = eng.run(max_steps=MAX_STEPS)
        return rids, done

    # fault-free oracle (no journal) — must actually run GC (vacuity)
    eng.reset(None)
    rids, done = drive(None)
    ref = [done[r] for r in rids]
    assert eng.metrics["gc_moves"] > 0, "workload never triggered GC"

    # journaled uncrashed run: find the first gc record's append index
    with tempfile.TemporaryDirectory() as d:
        eng.reset(None)
        eng.attach_journal(d, snapshot_every=4)
        drive(None)
        frames, _, _ = jl.read_frames(os.path.join(d, jl._JOURNAL))
        gc_at = next(i for i, (_, k, _p) in enumerate(frames)
                     if jl._KIND_NAMES.get(k) == "gc")
        eng.reset(None)

    # pinned crash exactly at that commit, torn or whole per the tear
    # schedule; recover and drain — outputs bit-identical
    plane = FaultPlane(make_plan(0, crash_at=gc_at, horizon=4096))
    with tempfile.TemporaryDirectory() as d:
        eng.reset(plane)
        eng.attach_journal(d, snapshot_every=4)
        rid_to_idx: dict = {}
        final: dict = {}
        to_submit = list(range(len(prompts)))
        crashed_on: list = []
        for _ in range(MAX_CRASHES):
            try:
                for i in to_submit:
                    rid_to_idx[eng.submit(list(prompts[i]),
                                          max_new=MAX_NEW)] = i
                to_submit = []
                done = eng.run(max_steps=MAX_STEPS)
                break
            except flt.Crash as e:
                crashed_on.append(e.kind)
                durable = eng.recover(d, fault_plane=plane)
                present = set(durable) | {r.rid for r in eng.queue}
                rid_to_idx = {r: i for r, i in rid_to_idx.items()
                              if r in present}
                for r, out in durable.items():
                    if r in rid_to_idx:
                        final[rid_to_idx[r]] = out
                covered = set(rid_to_idx.values())
                to_submit = [i for i in range(len(prompts))
                             if i not in covered]
        assert "gc" in crashed_on, crashed_on   # the cut hit the walk
        for r, out in done.items():
            if r in rid_to_idx:
                final[rid_to_idx[r]] = out
        final.update({rid_to_idx[r]: out
                      for r, out in eng._finished.items()
                      if r in rid_to_idx})
        assert [final[i] for i in range(len(prompts))] == ref
        assert eng.journal_lane_check()
        eng.reset(None)


def FaultPolicy_watchdog16():
    from repro.serving.config import FaultPolicy
    return FaultPolicy(watchdog_rounds=16)


@pytest.mark.slow
@pytest.mark.parametrize("channels", CHANNELS)
def test_chaos_crash_sweep(channels):
    """Acceptance sweep: 25 schedules per channel count, every one
    draining bit-identical to the fault-free oracle across its crashes.
    The aggregate must have exercised the whole recovery surface:
    crashes fired, records tore mid-byte, and at least one torn MAP
    commit was rebuilt by the OOB reverse-map scan."""
    ref = _oracle(channels)
    agg = {"crashes": 0, "torn": 0, "oob_scans": 0, "replayed": 0}
    for seed in range(2000, 2025):
        cov = _run_one(channels, seed, ref)
        for k in agg:
            agg[k] += cov[k]
    assert agg["crashes"] >= 10, f"sweep barely crashed: {agg}"
    assert agg["torn"] > 0, "no schedule ever tore a record mid-byte"
    assert agg["oob_scans"] > 0, \
        "no schedule ever exercised the OOB reverse-map scan"
    assert agg["replayed"] > 0, "no schedule ever replayed records"
