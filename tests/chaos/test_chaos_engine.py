"""Chaos harness (ISSUE 6): randomized fault schedules against the
full serving engine, across channel counts.

Every run draws per-axis fault probabilities (swap / program / alloc)
and an optional channel brownout from a seed, replays a FIXED
oversubscribed workload under that schedule, and asserts the two
invariants the recovery plane promises:

  1. the engine DRAINS — every request completes, no exception
     escapes, nothing left active or queued;
  2. outputs are BIT-IDENTICAL to the fault-free run — retries are
     pure, retirement relocates data losslessly, and a quarantined
     request's deterministic greedy restart reproduces its tokens.

Failures print the schedule seed: ``make_plan(seed, ...)`` with the
parameters in the message reproduces the exact run (the plan is a pure
function of the seed — see core/faults.py).

Engines are module-cached per channel count and reused via
``ServeEngine.reset``: the compiled decode/macro/swap closures trace
per instance, so the sweep replays hundreds of schedules with zero
recompiles. The quick test covers a few seeds per channel count in the
default lanes; the @slow sweep is the >=200-schedule acceptance run
(CI tier1-faults / local ``-m faults``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.core.faults import FaultPlane, make_plan
from repro.models import Runtime, build_model
from repro.serving.engine import ServeEngine

pytestmark = pytest.mark.faults

RT = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
             remat="none", page_size=8, capacity_factor=100.0)

CHANNELS = (1, 2, 4)
# fixed workload: 6 requests over 4 slots (queueing + admission churn),
# prompts sized to cross page boundaries mid-decode
PROMPTS = [list(range(3 + 11 * i, 10 + 11 * i)) for i in range(6)]
MAX_NEW = 10
MAX_STEPS = 4000

_CACHE: dict = {}


def _engine(C: int) -> ServeEngine:
    eng = _CACHE.get(C)
    if eng is None:
        m = _CACHE.get("model")
        if m is None:
            cfg = smoke_config(get_arch("llama3.2-1b"))
            cfg = dataclasses.replace(
                cfg, name="chaos-tiny", n_layers=cfg.period, d_model=32,
                n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                vocab_size=128)
            model = build_model(cfg, RT)
            m = (model, model.init(jax.random.key(0)))
            _CACHE["model"] = m
        model, params = m
        # oversubscribed: 4 slots x 3 pages worst-case = 12 = exactly
        # the device pool, so growth pressure, preemption and swaps all
        # fire; watchdog explicit so it survives fault-free resets too
        eng = ServeEngine(model, params, n_slots=4, max_ctx=64,
                          n_device_blocks=12, n_host_blocks=24,
                          macro_k=4, swap_patience=2, channels=C,
                          watchdog_rounds=16)
        _CACHE[C] = eng
    return eng


def _drain(eng: ServeEngine):
    rids = [eng.submit(list(p), max_new=MAX_NEW) for p in PROMPTS]
    done = eng.run(max_steps=MAX_STEPS)
    return rids, done


def _oracle(C: int):
    """Fault-free outputs for the fixed workload (cached per C)."""
    key = ("oracle", C)
    if key not in _CACHE:
        eng = _engine(C)
        eng.reset(None)
        rids, done = _drain(eng)
        assert not eng.active and not eng.queue, "oracle did not drain"
        _CACHE[key] = [done[r] for r in rids]
    return _CACHE[key]


def _schedule(seed: int, C: int):
    """Seed -> plan parameters: probabilities and an optional brownout
    drawn from the seed, so every seed is a distinct scenario and the
    whole run reproduces from the one integer."""
    rng = np.random.default_rng(seed)
    stall = np.ones(C)
    if rng.random() < 0.5:
        stall[rng.integers(C)] = rng.uniform(2.0, 6.0)
    return dict(channels=C,
                swap_fail_p=float(rng.uniform(0, 0.25)),
                program_fail_p=float(rng.uniform(0, 0.2)),
                alloc_fail_p=float(rng.uniform(0, 0.2)),
                stall=stall.tolist())


def _run_one(C: int, seed: int, ref):
    eng = _engine(C)
    kw = _schedule(seed, C)
    plane = FaultPlane(make_plan(seed, **kw))
    eng.reset(plane)
    try:
        rids, done = _drain(eng)
    except Exception:
        print(f"\nCHAOS FAILURE seed={seed} channels={C}: "
              f"escaped exception under {plane.describe()}")
        raise
    msg = (f"chaos seed={seed} channels={C} plan={plane.describe()} "
           f"metrics={eng.metrics}")
    undrained = [r for r in rids if r not in done]
    if undrained or eng.active or eng.queue:
        print(f"\nCHAOS FAILURE {msg}")
    assert not undrained and not eng.active and not eng.queue, msg
    got = [done[r] for r in rids]
    if got != ref:
        print(f"\nCHAOS FAILURE {msg}")
    assert got == ref, msg
    return eng.metrics


@pytest.mark.parametrize("channels", CHANNELS)
def test_chaos_quick(channels):
    """A few schedules per channel count in the default lanes — the
    canary for the @slow acceptance sweep below."""
    ref = _oracle(channels)
    for seed in range(100, 104):
        _run_one(channels, seed, ref)


@pytest.mark.slow
@pytest.mark.parametrize("channels", CHANNELS)
def test_chaos_sweep(channels):
    """Acceptance sweep: 70 schedules per channel count (210 total
    with test_chaos_quick's 12 on top) — every one must drain with
    outputs bit-identical to the fault-free oracle. At least some
    schedules must actually have exercised each recovery path, or the
    sweep is vacuous (asserted on the aggregate)."""
    ref = _oracle(channels)
    agg = {"swap_faults": 0, "quarantines": 0, "requeues": 0}
    retired = 0
    for seed in range(1000, 1070):
        metrics = _run_one(channels, seed, ref)
        for k in agg:
            agg[k] += metrics[k]
        retired += _engine(channels).kvm.hit_stats()["retired_blocks"]
    assert agg["swap_faults"] > 0, "no schedule ever failed a swap"
    assert retired > 0, "no schedule ever retired a block"
