"""Decode-throughput benchmark for the serving map path.

Measures steady-state decode steps/sec of `ServeEngine` at
n_slots=16, max_pages=64 (the ISSUE-2 reference point) across three
interleaved groups: the six historical modes below, plus the ISSUE-5
``channel_scaling`` sweep (the fused macro engine with the FMMU map
sharded across N in {1,2,4,8} channels). The sweep's ``cpu_bound``
flag records the lowering that actually ran (``kvm.mesh is None``) —
today always true, since the serving engine pins the vmap lowering
until model/map mesh co-residency lands (ROADMAP) — and in that
regime the per-channel routed-lane counters carry the
1/N-translate-work claim instead of wall clock. Core modes:

  * ``fused_macro``  — the live path: K-step fused decode macro-steps
    (K=8, ONE donated jit runs attention + sampling + page-boundary
    detection + device-side block allocation + map commit for K
    tokens, one host dispatch and one device->host sync per K steps)
    plus this PR's graph optimizations (live-page bucketing,
    single-chunk paged attention);
  * ``single_step``  — the live single-step path (same graph
    optimizations, no macro fusion): isolates the macro-step
    contribution;
  * ``incremental``  — the PR-2 incremental baseline restored
    faithfully (single-step, full-width tables, 8-page attention
    chunks): the ISSUE-3 acceptance reference;
  * ``rebuild_legacy`` — pre-PR-2: rebuilds the full table by
    re-translating every DLPN each step and masks it on host;
  * ``oversub_fused`` / ``oversub_fallback`` — the ISSUE-4 pair: the
    same engine under ~2x OVERSUBSCRIPTION (16 requests whose working
    set is about twice the device pool; a host tier holds the
    overflow), measured as CONTINUOUS-BATCHING COMPLETION rounds: each
    interleaved round submits a fresh batch of 16 finite requests and
    times delivered tokens/sec from the first decode step until every
    request completes. Completion rounds make fairness part of the
    metric — a scheduler cannot win by starving the swapped-out
    sequences, the failure mode an open-ended steps/sec window hides.
    ``oversub_fused`` is the non-blocking swap pipeline: swap-pending
    slots are masked scan lanes, the boundary scheduler rotates
    residency, and the measured rounds perform ZERO single-step
    fallbacks (counter-asserted). ``oversub_fallback`` is the PR-3
    behavior restored faithfully: ``nonblocking_swap=False`` (any
    non-resident slot drops every round to single-step mode) AND the
    PR-3 swap data movement (eager un-donated jnp row moves that
    functionally copy the pools, a separate fused map call, and a
    blocking guard readback per swap — ``_patch_pr3_swap``).
    Acceptance: oversub_fused >= 1.3x oversub_fallback tokens/sec.

All modes run in-process because this box's 2-core timings are too
noisy to compare across runs; per-window dispersion (median/min/IQR
over ``--repeats`` consecutive windows) is recorded so the noise is
visible in the artifact. In ``--quick`` (CI smoke) mode, speedup
shortfalls against the targets and regressions against the committed
BENCH_serve.json are REPORTED as warnings, not failures — the runner
is too noisy for a hard gate.

Emits CSV rows (shared benchmark format) and writes ``BENCH_serve.json``
(repo root or $REPRO_BENCH_OUT) so CI can archive the perf trajectory.
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit

N_SLOTS = 16
MAX_PAGES = 64
MACRO_K = 8
WARM_STEPS = 3
# oversubscription workload (ISSUE 4): 80-token prompts = 10 pages per
# sequence at admission, 16 sequences = 160 pages of working set vs a
# 76-block device pool (~2.1x, deepening to ~3x as contexts grow to
# prompt+max_new); the host tier absorbs the overflow
OVERSUB_PROMPT = 80
OVERSUB_MAX_NEW = 48
OVERSUB_DEV = 76
OVERSUB_HOST = 640
# channel-scaling sweep (ISSUE 5): the fused macro engine with the map
# state sharded across N channels; measured with the same interleaved
# windows as the main decode group, in its own group (its engines are
# only comparable to each other)
CHANNEL_SWEEP = (1, 2, 4, 8)
# fault-injection degraded mode (ISSUE 6): the oversubscribed fused
# engine, channel-sharded, with ONE channel browned out 4x and a 1%
# injected swap-failure rate — measured as the same completion rounds
# as the oversub pair against an identical healthy engine. The
# deterministic plan regenerates from the seed (core/faults.make_plan)
FAULT_CHANNELS = 4
FAULT_STALL = (4.0, 1.0, 1.0, 1.0)
FAULT_SWAP_P = 0.01
FAULT_SEED = 2026
# crash/recovery measurement (ISSUE 7): a journaled channel-sharded
# oversubscribed engine killed at a deterministic commit point, then
# recovered from the journal directory. MTTR = power cut -> first
# RESUMED token (replay + map restore + re-admission + prefill), swept
# over the snapshot interval: tighter snapshots replay fewer records
# at recovery but pay more snapshot writes while healthy — the
# committed sweep records both sides of that tradeoff.
RECOVERY_CHANNELS = 2
RECOVERY_SEED = 2027
RECOVERY_CRASH_AT = 80
RECOVERY_SNAPSHOT_SWEEP = (1, 4, 16)
# GC victim-eviction walk (ISSUE 9): the oversubscribed fused engine
# with the boundary GC walk + CTP prefetch on vs off, measured as the
# same completion rounds as the oversub pair. The gc section records
# the write-amplification axis (host writes vs flash programs) and
# the reclaim counters; acceptance is gc_on retaining >= 0.9x gc_off
# delivered tokens/sec while actually reclaiming victims.
GC_WATERMARK = 6
GC_BUDGET = 8
GC_BLOCK_PAGES = 4
# prefix sharing (ISSUE 10): B requests with a common 80-token prompt
# prefix (10 full pages) + a short unique tail. The shared engine must
# admit the followers on the leader's physical pages (ONE prefill for
# the whole batch), COW each diverging tail, and emit tokens
# bit-identical to the sharing-off control. Acceptance: prefill-FLOP
# proxy (prompt tokens through prefill + forced lanes) and distinct
# device pages after admission both <= 1/4 of the unshared baseline
PREFIX_B = 8
PREFIX_COMMON = 80
PREFIX_TAIL = 4
PREFIX_MAX_NEW = 4
PREFIX_RATIO_TARGET = 0.25
# in-run speedup targets (ISSUE 3: fused >= 1.5x incremental;
# ISSUE 4: non-blocking swap >= 1.3x the fall-back-on-pressure PR-3
# behavior under 2x oversubscription; ISSUE 6: the degraded engine
# retains >= 60% of the healthy fused engine's delivered tokens/sec;
# ISSUE 9: the GC-enabled engine retains >= 90% of the GC-off
# engine's delivered tokens/sec under the same oversubscription)
TARGETS = {"fused_macro_vs_incremental": 1.5,
           "incremental_vs_rebuild": 1.5,
           "oversub_fused_vs_fallback": 1.3,
           "degraded_retention": 0.6,
           "gc_retention": 0.9}


def _build_engine(mode: str):
    import dataclasses

    import jax

    from repro.configs import get_arch, smoke_config
    from repro.models import Runtime, build_model
    from repro.serving.config import GCConfig, PrefixConfig, ServeConfig
    from repro.serving.engine import ServeEngine

    # the PR-2-faithful baselines pin the pre-ISSUE-3 decode graph:
    # 8-page attention chunks (no auto-widening) and full-width tables
    pr2 = mode in ("incremental", "rebuild_legacy")
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 remat="none", page_size=8, capacity_factor=100.0,
                 paged_chunk=8 if pr2 else None)
    # minimal model: this benchmark isolates the serving *map* path
    # (the paper's FTL-exec-time claim), so model compute is kept as
    # close to zero as the engine allows — with the full smoke config
    # the transformer forward drowns the map delta on this 2-core box
    cfg = smoke_config(get_arch("llama3.2-1b"))
    cfg = dataclasses.replace(cfg, name="serve-bench-tiny",
                              n_layers=cfg.period, d_model=32, n_heads=2,
                              n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab_size=128)
    m = build_model(cfg, rt)
    params = m.init(jax.random.key(0))
    max_ctx = MAX_PAGES * rt.page_size
    if mode in ("oversub_fused", "oversub_fallback"):
        # identical engine + workload; the differences are exactly the
        # ISSUE-4 tentpole: masked swap-pending scan lanes + boundary
        # scheduler vs per-round single-step fallback, and the fused
        # donated swap jit vs PR-3's eager copy-per-swap data movement
        eng = ServeEngine(m, params, config=ServeConfig(
            n_slots=N_SLOTS, max_ctx=max_ctx,
            n_device_blocks=OVERSUB_DEV, n_host_blocks=OVERSUB_HOST,
            macro_k=MACRO_K, swap_patience=4,
            nonblocking_swap=(mode == "oversub_fused")))
        if mode == "oversub_fused":
            # pin the swap-lane pad so the fused swap fn compiles ONCE
            # per direction (during warm-up) instead of re-tracing at
            # every pow2 cap crossing as sequences grow — a mid-round
            # XLA compile would poison that round's sample
            eng.kvm.swap_pad = MAX_PAGES
        else:
            _patch_pr3_swap(eng)
        return eng
    if mode.startswith("channels_"):
        # ISSUE-5 sweep: the fused macro engine with the map sharded
        # across N channels (N=1 is the unsharded tentpole baseline,
        # rebuilt per mode so the windows interleave fairly)
        return ServeEngine(m, params, config=ServeConfig(
            n_slots=N_SLOTS, max_ctx=max_ctx, macro_k=MACRO_K,
            channels=int(mode.rsplit("_", 1)[1])))
    if mode.startswith("faults_"):
        # ISSUE-6 pair: identical channel-sharded oversubscribed fused
        # engines; the degraded one carries the fault plane (brownout
        # on channel 0 + injected swap failures) — the delta measured
        # is exactly the cost of degradation plus recovery
        from repro.core.faults import FaultPlane, make_plan
        plane = None
        if mode == "faults_degraded":
            plane = FaultPlane(make_plan(
                FAULT_SEED, channels=FAULT_CHANNELS,
                swap_fail_p=FAULT_SWAP_P, stall=list(FAULT_STALL)))
        eng = ServeEngine(m, params, config=ServeConfig(
            n_slots=N_SLOTS, max_ctx=max_ctx,
            n_device_blocks=OVERSUB_DEV, n_host_blocks=OVERSUB_HOST,
            macro_k=MACRO_K, swap_patience=4,
            channels=FAULT_CHANNELS), fault_plane=plane)
        eng.kvm.swap_pad = MAX_PAGES
        return eng
    if mode in ("gc_off", "gc_on"):
        # ISSUE-9 pair: identical oversubscribed fused engines; the
        # gc_on one adds the boundary victim walk + CTP prefetch. The
        # delta measured is exactly the GC tax (relocations ride the
        # same fused CondUpdate path decode uses), and the reclaim /
        # write-amp counters prove the walk did real work
        gc = GCConfig(watermark=GC_WATERMARK,
                      pages_per_boundary=GC_BUDGET,
                      block_pages=GC_BLOCK_PAGES,
                      prefetch=True) if mode == "gc_on" else None
        eng = ServeEngine(m, params, config=ServeConfig(
            n_slots=N_SLOTS, max_ctx=max_ctx,
            n_device_blocks=OVERSUB_DEV, n_host_blocks=OVERSUB_HOST,
            macro_k=MACRO_K, swap_patience=4, gc=gc))
        eng.kvm.swap_pad = MAX_PAGES
        return eng
    if mode in ("prefix_on", "prefix_off"):
        # ISSUE-10 pair: identical single-step engines; the on one arms
        # the radix prefix cache + refcnt lane. No oversubscription —
        # the section measures the prompt-work and footprint deltas,
        # and swaps would blur the page accounting. Single-step (not
        # macro) so the per-step peak-footprint probe actually observes
        # the mapped working set (a K=8 macro drains the whole short
        # workload inside one step call); the macro path's sharing
        # bit-identity is pinned by tests/test_prefix.py instead
        eng = ServeEngine(m, params, config=ServeConfig(
            n_slots=PREFIX_B, max_ctx=max_ctx, macro_k=0,
            prefix=(PrefixConfig(min_tokens=16)
                    if mode == "prefix_on" else None)))
        return eng
    if mode == "recovery":
        # ISSUE-7: the journaled engine for the crash/recover sweep —
        # oversubscribed + channel-sharded so the journal carries every
        # record kind (swaps included); the caller attaches the journal
        # and the crash plan per sweep point
        eng = ServeEngine(m, params, config=ServeConfig(
            n_slots=N_SLOTS, max_ctx=max_ctx,
            n_device_blocks=OVERSUB_DEV, n_host_blocks=OVERSUB_HOST,
            macro_k=MACRO_K, swap_patience=4,
            channels=RECOVERY_CHANNELS))
        eng.kvm.swap_pad = MAX_PAGES
        return eng
    eng = ServeEngine(m, params, config=ServeConfig(
        n_slots=N_SLOTS, max_ctx=max_ctx,
        macro_k=MACRO_K if mode == "fused_macro" else 0))
    if pr2:
        eng.min_page_bucket = MAX_PAGES    # PR 2 had no page bucketing
    if mode == "rebuild_legacy":
        _patch_legacy(eng)
    return eng


def _patch_legacy(eng):
    """Pre-PR serving map behaviour, restored for an in-run baseline:

    * admission preallocates prompt+max_new pages up front (so decode
      never grows the map — the old engine's steady state);
    * every decode step rebuilds the full [n_slots, max_pages] table by
      re-translating every DLPN through the FMMU (`retranslate_tables`,
      the churn-test oracle) and masks paused/invalid rows on host via
      numpy before shipping the table back to device;
    * the decode jit takes the host-masked table directly and does NOT
      donate the KV caches (the pre-PR jit functionally copied the
      whole pool every step)."""
    import types

    import jax

    from repro.paging.pool import OutOfBlocks

    def _legacy_decode_fn(self, params, tokens, caches, ctx_lens, tables,
                          src_valid=None):
        logits, caches = self.m.decode_step(
            params, tokens, caches, ctx_lens=ctx_lens, block_table=tables,
            src_valid=src_valid)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    def _legacy_admit(self):
        free = self._free_slots()
        while self.queue and free:
            req = self.queue[0]
            slot = free[0]
            n_pages = -(-(len(req.tokens) + req.max_new) // self.page)
            n_pages = min(n_pages, self.max_pages)
            try:
                self.kvm.new_seq(slot, n_pages)
            except OutOfBlocks:
                if not self._preempt(exclude=slot):
                    return
                continue
            self.queue.popleft()
            free.pop(0)
            req.slot = slot
            self.active[req.rid] = req
            self._do_prefill(req)

    def _legacy_decode_step(self, done):
        self._ensure_resident()
        residents = [r for r in self.active.values()
                     if self.kvm.is_resident(r.slot)]
        if not residents:
            return
        resident_slots = {r.slot for r in residents}
        tokens = np.zeros(self.n_slots, np.int32)
        for r in residents:
            tokens[r.slot] = r.out[-1] if r.out else r.tokens[-1]
        tables = np.array(self.kvm.retranslate_tables())
        step_ctx = np.asarray(self.ctx_lens, np.int64).copy()
        for slot in range(self.n_slots):
            if slot not in resident_slots:
                tables[slot, :] = self.scratch_block
                step_ctx[slot] = 0
        tables = np.where((tables < 0) | (tables >= self.scratch_block),
                          self.scratch_block, tables)
        next_tok, self.caches = self._legacy_decode(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.asarray(step_ctx, jnp.int32),
            jnp.asarray(tables, jnp.int32), None)
        self._finish_step(residents, np.asarray(next_tok), done)

    eng._admit = types.MethodType(_legacy_admit, eng)
    eng._decode_step = types.MethodType(_legacy_decode_step, eng)
    eng._legacy_decode = jax.jit(types.MethodType(_legacy_decode_fn, eng))


def _patch_pr3_swap(eng):
    """Restore the PR-3 swap data movement faithfully (the baseline
    the ISSUE-4 acceptance compares against): eager, un-jitted,
    un-donated jnp row moves — every swap functionally copies the
    whole KV pools — with a separate fused map call and a BLOCKING
    guard-mask readback per swap. Verbatim from the pre-ISSUE-4
    KVPageManager (git 39799ba)."""
    import types

    import numpy as np

    from repro.core.fmmu.types import COND_UPDATE, HOST_BASE
    from repro.paging.kv_manager import _move_rows
    from repro.paging.pool import BlockPool

    def swap_out(self, slot, pools, block_axis=0, check=True):
        blocks = self.seq_pages[slot]
        dev = [b for b in blocks if not BlockPool.is_host(b)]
        if not dev:
            return pools, 0
        host = self.pool.alloc(len(dev), host=True)
        self._alloc_dirty = True
        dl = [slot * self.max_pages + i for i, b in enumerate(blocks)
              if not BlockPool.is_host(b)]
        _, ok = self._xlate(COND_UPDATE, dl, host, dev)
        assert np.asarray(ok).all(), "swap_out raced"
        src = jnp.asarray(dev, jnp.int32)
        dst = jnp.asarray([self.pool.n_device + (h - HOST_BASE)
                           for h in host], jnp.int32)
        pools = [_move_rows(p, src, dst, block_axis) for p in pools]
        self.pool.free(dev)
        self.seq_pages[slot] = [
            host[dev.index(b)] if b in dev else b for b in blocks]
        self._host_pages[slot] = sum(
            BlockPool.is_host(b) for b in self.seq_pages[slot])
        self.pool.stats.swaps_out += len(dev)
        return pools, len(dev)

    def swap_in(self, slot, pools, block_axis=0, check=True):
        blocks = self.seq_pages[slot]
        hostb = [b for b in blocks if BlockPool.is_host(b)]
        if not hostb:
            return pools, 0
        dev = self.pool.alloc(len(hostb))
        self._alloc_dirty = True
        dl = [slot * self.max_pages + i for i, b in enumerate(blocks)
              if BlockPool.is_host(b)]
        _, ok = self._xlate(COND_UPDATE, dl, dev, hostb)
        assert np.asarray(ok).all()
        src = jnp.asarray([self.pool.n_device + (h - HOST_BASE)
                           for h in hostb], jnp.int32)
        dst = jnp.asarray(dev, jnp.int32)
        pools = [_move_rows(p, src, dst, block_axis) for p in pools]
        self.pool.free(hostb)
        self.seq_pages[slot] = [
            dev[hostb.index(b)] if b in hostb else b for b in blocks]
        self._host_pages[slot] = sum(
            BlockPool.is_host(b) for b in self.seq_pages[slot])
        self.pool.stats.swaps_in += len(hostb)
        return pools, len(hostb)

    eng.kvm.swap_out = types.MethodType(swap_out, eng.kvm)
    eng.kvm.swap_in = types.MethodType(swap_in, eng.kvm)


def _run_decode(modes, n_steps: int, repeats: int, prompt_len: int = 8):
    """One serving run per mode, windows INTERLEAVED across modes: for
    each of `repeats` rounds, every mode times one window of n_steps
    decode steps (counted via engine metrics, so a fused macro-step
    contributes K). Interleaving matters on this 2-core virtualized
    box: CPU steal drifts on multi-second scales, so consecutive
    same-mode windows correlate and back-to-back mode blocks skew the
    ratio; round-robin windows see the same noise. Context grows
    slowly across windows (8 tokens/page) but every mode walks the
    identical schedule, so windows stay comparable. Returns
    ({mode: [steps/sec per window]},
     {mode: [generated tokens/sec per window]}, {mode: engine})."""
    engines, dones, fb0 = {}, {}, {}
    # decode jits are specialized on the live-page bucket; pin the
    # bucket that covers the whole timed range so no window eats a
    # mid-run re-trace (a bucket crossing costs seconds of XLA compile,
    # which would make that window's sample garbage)
    end_ctx = prompt_len + 1 + (1 + WARM_STEPS) * MACRO_K \
        + repeats * n_steps + MACRO_K
    bucket = 4
    while bucket * 8 < end_ctx + 8:
        bucket *= 2
    for mode in modes:
        eng = _build_engine(mode)
        eng.min_page_bucket = max(eng.min_page_bucket,
                                  min(bucket, MAX_PAGES))
        for i in range(N_SLOTS):
            eng.submit(list(range(1 + i, 1 + i + prompt_len)),
                       max_new=10 ** 9)
        done = {}
        eng.step(done)                   # admits + prefills + first step
        for _ in range(WARM_STEPS):
            eng.step(done)
        engines[mode], dones[mode] = eng, done
        # the zero-fallback claim is STEADY-STATE: admission under an
        # oversubscribed pool may legitimately fall back while slots
        # are first preempted to fit; snapshot after warm-up
        fb0[mode] = eng.metrics["macro_fallbacks"]
    sps = {mode: [] for mode in modes}
    tps = {mode: [] for mode in modes}
    for rep in range(repeats):
        # rotate the order each round: the mode that follows the heavy
        # legacy window inherits its cache damage, so a fixed order
        # biases one mode systematically
        order = modes[rep % len(modes):] + modes[:rep % len(modes)]
        for mode in order:
            eng, done = engines[mode], dones[mode]
            s0 = eng.metrics["decode_steps"]
            g0 = eng.metrics["generated"]
            t0 = time.perf_counter()
            while eng.metrics["decode_steps"] - s0 < n_steps:
                eng.step(done)
            dt = time.perf_counter() - t0
            sps[mode].append((eng.metrics["decode_steps"] - s0) / dt)
            tps[mode].append((eng.metrics["generated"] - g0) / dt)
    for mode, eng in engines.items():
        assert len(eng.active) == N_SLOTS, "sequences finished mid-bench"
        assert int(max(eng.ctx_lens)) < MAX_PAGES * eng.page, "ctx overflow"
        if mode == "fused_macro" or mode.startswith("channels_"):
            assert eng.metrics["macro_steps"] > 0, "fused mode never fused"
            assert eng.metrics["macro_fallbacks"] == fb0[mode], \
                f"{mode}: single-step fallback during steady state"
    return sps, tps, engines


def _run_oversub(modes, repeats: int):
    """ISSUE-4 measurement: interleaved CONTINUOUS-BATCHING COMPLETION
    rounds under ~2x oversubscription. Each round submits a fresh
    batch of N_SLOTS finite requests, performs one engine step
    (admissions + prefills + first decode — identical work in both
    modes, excluded from the window), then times delivered tokens/sec
    until every request completes. Completion rounds bake fairness
    into the metric: the swapped-out sequences must finish, so a
    scheduler cannot look fast by starving them. Round 0 per mode is
    an unmeasured warm-up (XLA compiles for the scan variants and the
    pinned swap jit). Returns ({mode: [steps/s]}, {mode: [tokens/s]},
    {mode: engine})."""
    engines, fb_warm = {}, {}

    def one_round(eng):
        for i in range(N_SLOTS):
            eng.submit(list(range(1 + i, 1 + i + OVERSUB_PROMPT)),
                       max_new=OVERSUB_MAX_NEW)
        done: dict = {}
        eng.step(done)          # admissions + prefills + first step
        s0, g0 = eng.metrics["decode_steps"], eng.metrics["generated"]
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        assert not eng.active and not eng.queue, "round did not drain"
        return ((eng.metrics["decode_steps"] - s0) / dt,
                (eng.metrics["generated"] - g0) / dt)

    for mode in modes:
        eng = _build_engine(mode)
        # pin the live-page bucket covering prompt+max_new so no round
        # eats a bucket-crossing re-trace
        need = -(-(OVERSUB_PROMPT + OVERSUB_MAX_NEW) // 8)
        eng.min_page_bucket = 1 << (need - 1).bit_length()
        one_round(eng)                       # warm-up, unmeasured
        engines[mode] = eng
        # the zero-fallback claim is for the measured rounds; warm-up
        # includes first-ever admissions while jits are still cold
        fb_warm[mode] = eng.metrics["macro_fallbacks"]
    sps = {mode: [] for mode in modes}
    tps = {mode: [] for mode in modes}
    for rep in range(repeats):
        order = list(modes)[rep % len(modes):] \
            + list(modes)[:rep % len(modes)]
        for mode in order:
            s, t = one_round(engines[mode])
            sps[mode].append(s)
            tps[mode].append(t)
    for mode, eng in engines.items():
        assert eng.metrics["swaps_out"] > 0, \
            f"{mode}: never swapped — pool not oversubscribed"
        if mode == "oversub_fused":
            assert eng.metrics["macro_steps"] > 0
            assert eng.metrics["macro_fallbacks"] == fb_warm[mode], \
                "fused mode fell back to single-step during a " \
                "measured round"
        else:
            assert eng.metrics["macro_fallbacks"] > fb_warm[mode], \
                "fallback baseline stayed fused: no pressure applied"
    return sps, tps, engines


def _run_faults(repeats: int):
    """ISSUE-6 measurement: graceful degradation under an adverse
    fault schedule. Two identical channel-sharded oversubscribed fused
    engines run interleaved completion rounds (same protocol as
    ``_run_oversub``); the degraded one carries a deterministic fault
    plane — one channel browned out 4x (its advertised free-block
    budget shrinks, pushing residency/growth to healthy channels) and
    a 1% injected swap-failure rate (retried with backoff; persistent
    failers quarantine and restart). Throughput is DELIVERED
    tokens/sec — tokens in completed outputs, not raw generation — so
    a quarantined request's regenerated prefix cannot pad the degraded
    number. Acceptance: the degraded engine retains >= 60% of healthy
    throughput (TARGETS['degraded_retention'])."""
    modes = ("faults_healthy", "faults_degraded")
    engines = {}

    def one_round(eng):
        for i in range(N_SLOTS):
            eng.submit(list(range(1 + i, 1 + i + OVERSUB_PROMPT)),
                       max_new=OVERSUB_MAX_NEW)
        done: dict = {}
        eng.step(done)          # admissions + prefills + first step
        t0 = time.perf_counter()
        done.update(eng.run())
        dt = time.perf_counter() - t0
        assert not eng.active and not eng.queue, "round did not drain"
        # the handful of pre-window tokens (prefill + first step) is
        # identical across modes, so the retention ratio is unbiased
        return sum(len(v) for v in done.values()) / dt

    for mode in modes:
        eng = _build_engine(mode)
        need = -(-(OVERSUB_PROMPT + OVERSUB_MAX_NEW) // 8)
        eng.min_page_bucket = 1 << (need - 1).bit_length()
        one_round(eng)                       # warm-up, unmeasured
        engines[mode] = eng
    tps = {mode: [] for mode in modes}
    for rep in range(repeats):
        order = list(modes)[rep % len(modes):] \
            + list(modes)[:rep % len(modes)]
        for mode in order:
            tps[mode].append(one_round(engines[mode]))
    deg = engines["faults_degraded"]
    assert deg.metrics["swap_faults"] > 0, \
        "degraded mode never fired an injected swap failure"
    assert engines["faults_healthy"].metrics["swap_faults"] == 0
    return tps, engines


def _run_gc(repeats: int):
    """ISSUE-9 measurement: the write-amplification axis of the GC
    victim-eviction walk. Two identical oversubscribed fused engines
    run interleaved completion rounds (same protocol as
    ``_run_oversub``); the gc_on one adds the budgeted boundary walk
    (watermark-triggered victim selection from the fused-path live
    counts, relocations through the same single-probe CondUpdate
    commit decode uses) plus the CTP map-segment prefetch. Delivered
    tokens/sec gives the retention headline; the hit_stats
    write-amplification fields (host_writes vs flash_programs) and
    the reclaim counters prove the walk did real work. Acceptance:
    gc_on retains >= 90% of gc_off throughput
    (TARGETS['gc_retention']) while gc_moves/victims stay non-zero,
    and the gc_off control never relocates a page."""
    modes = ("gc_off", "gc_on")
    engines = {}

    def one_round(eng):
        for i in range(N_SLOTS):
            eng.submit(list(range(1 + i, 1 + i + OVERSUB_PROMPT)),
                       max_new=OVERSUB_MAX_NEW)
        done: dict = {}
        eng.step(done)          # admissions + prefills + first step
        g0 = eng.metrics["generated"]
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        assert not eng.active and not eng.queue, "round did not drain"
        return (eng.metrics["generated"] - g0) / dt

    for mode in modes:
        eng = _build_engine(mode)
        need = -(-(OVERSUB_PROMPT + OVERSUB_MAX_NEW) // 8)
        eng.min_page_bucket = 1 << (need - 1).bit_length()
        one_round(eng)                       # warm-up, unmeasured
        engines[mode] = eng
    tps = {mode: [] for mode in modes}
    for rep in range(repeats):
        order = list(modes)[rep % len(modes):] \
            + list(modes)[:rep % len(modes)]
        for mode in order:
            tps[mode].append(one_round(engines[mode]))
    on, off = engines["gc_on"], engines["gc_off"]
    assert on.metrics["gc_moves"] > 0, \
        "gc_on never relocated a live page (walk found no work)"
    assert on.metrics["gc_victims"] > 0, \
        "gc_on never reclaimed a victim block"
    assert off.metrics["gc_moves"] == 0, \
        "gc_off control relocated pages (GC not actually disabled)"
    return tps, engines


def _run_prefix():
    """ISSUE-10 measurement: copy-on-write prefix sharing.

    Three runs of the same B-request batch (80 common prompt tokens +
    a unique 4-token tail each):

      * ``prefix_off`` — the control: every request prefills its whole
        prompt and owns every page;
      * ``prefix_on``  — the leader prefills once, followers admit on
        the leader's physical pages and stream only their tails;
      * forced divergence — ``prefix_on`` again with IDENTICAL
        80-token prompts, so every follower's first forced write lands
        INSIDE a shared page and must relocate copy-on-write.

    The prefill-FLOP proxy is prompt tokens through the prefill path
    plus forced pending-prompt lanes (engine ``prefill_tokens``);
    device pages are the distinct blocks mapped after the admission
    step. Acceptance: both ratios <= PREFIX_RATIO_TARGET and outputs
    bit-identical to the control; the off engine must stay inert
    (no refcnt lane, zero shared admissions)."""
    common = list(range(1, 1 + PREFIX_COMMON))
    tailed = [common + [100 + i] * PREFIX_TAIL for i in range(PREFIX_B)]
    flat = [list(common) for _ in range(PREFIX_B)]

    def one(mode, prompts):
        eng = _build_engine(mode)
        done: dict = {}
        rids = [eng.submit(list(t), max_new=PREFIX_MAX_NEW)
                for t in prompts]
        pages, t0 = 0, time.perf_counter()
        alive = True
        while alive:           # step-at-a-time so the PEAK distinct
            alive = eng.step(done)     # mapped-block footprint is seen
            pages = max(pages, len({b for ps in
                                    eng.kvm.seq_pages.values()
                                    for b in ps}))
        dt = time.perf_counter() - t0
        assert not eng.active and not eng.queue, \
            "prefix bench: round did not drain"
        return eng, [done[r] for r in rids], pages, dt

    off, out_off, pages_off, _ = one("prefix_off", tailed)
    assert off.kvm.state.refcnt is None, \
        "prefix_off control armed the refcnt lane"
    assert off.metrics["shared_admits"] == 0 \
        and off.metrics["cow_moves"] == 0, \
        "prefix_off control shared pages (sharing not actually off)"
    on, out_on, pages_on, _ = one("prefix_on", tailed)
    assert out_on == out_off, \
        "prefix sharing changed emitted tokens (must be bit-identical)"
    assert on.metrics["shared_admits"] == PREFIX_B - 1, \
        f"expected {PREFIX_B - 1} shared admissions, " \
        f"got {on.metrics['shared_admits']}"
    assert on.metrics["cow_moves"] > 0, \
        "prefix_on run never diverged copy-on-write"
    flop_ratio = on.metrics["prefill_tokens"] \
        / max(1, off.metrics["prefill_tokens"])
    page_ratio = pages_on / max(1, pages_off)
    assert flop_ratio <= PREFIX_RATIO_TARGET, \
        f"prefill-FLOP ratio {flop_ratio:.3f} above " \
        f"{PREFIX_RATIO_TARGET} target"
    assert page_ratio <= PREFIX_RATIO_TARGET, \
        f"device-page ratio {page_ratio:.3f} above " \
        f"{PREFIX_RATIO_TARGET} target"
    # forced divergence: identical prompts share ALL pages (the skip
    # caps at len-1), so the one forced token per follower writes into
    # a shared page and must COW first — control run with the same
    # prompts proves relocation never changes tokens
    offd, out_offd, _, _ = one("prefix_off", flat)
    ond, out_ond, _, _ = one("prefix_on", flat)
    assert out_ond == out_offd, \
        "forced-divergence outputs differ from the unshared control"
    assert ond.metrics["cow_moves"] >= PREFIX_B - 1, \
        "forced divergence produced no COW relocations"
    return {
        "batch": PREFIX_B,
        "common_tokens": PREFIX_COMMON,
        "tail_tokens": PREFIX_TAIL,
        "max_new": PREFIX_MAX_NEW,
        "prefill_tokens": {"prefix_off": off.metrics["prefill_tokens"],
                           "prefix_on": on.metrics["prefill_tokens"]},
        "prefill_flop_ratio": round(flop_ratio, 4),
        "device_pages": {"prefix_off": pages_off,
                         "prefix_on": pages_on},
        "device_page_ratio": round(page_ratio, 4),
        "shared_admits": on.metrics["shared_admits"],
        "shared_pages": on.metrics["shared_pages"],
        "cow_moves": on.metrics["cow_moves"],
        "outputs_bit_identical": out_on == out_off,
        "off_inert": True,
        "forced_divergence": {
            "cow_moves": ond.metrics["cow_moves"],
            "outputs_bit_identical": out_ond == out_offd,
        },
    }


def _run_recovery():
    """ISSUE-7 measurement: bounded MTTR after a sudden power-off.

    One journaled engine, reused across the snapshot-interval sweep
    (reset keeps the compiled jits, so recovery timings measure the
    SPOR path, not XLA compiles — a warm-up crash/recover cycle runs
    first for the same reason). Per sweep point: run the
    oversubscribed workload under a deterministic plan that kills the
    process at the same commit point, recover, and time

      * ``recover_s``  — replay + map restore + journal re-arm,
      * ``mttr_s``     — power cut to the first RESUMED token
                         (recover_s + re-admission + prefill).

    Replayed-record counts expose the snapshot tradeoff: a tighter
    interval replays fewer records at the same crash point."""
    import tempfile

    from repro.core import faults as flt
    from repro.core.faults import FaultPlane, make_plan

    eng = _build_engine("recovery")
    need = -(-(OVERSUB_PROMPT + OVERSUB_MAX_NEW) // 8)
    eng.min_page_bucket = 1 << (need - 1).bit_length()

    def crash_recover(snap_every):
        with tempfile.TemporaryDirectory() as d:
            plan = make_plan(RECOVERY_SEED, channels=RECOVERY_CHANNELS,
                             crash_at=RECOVERY_CRASH_AT)
            eng.reset(FaultPlane(plan))
            eng.attach_journal(d, snapshot_every=snap_every)
            t_crash = None
            try:
                for i in range(N_SLOTS):
                    eng.submit(list(range(1 + i,
                                          1 + i + OVERSUB_PROMPT)),
                               max_new=OVERSUB_MAX_NEW)
                eng.run()
            except flt.Crash:
                t_crash = time.perf_counter()
            assert t_crash is not None, \
                "recovery bench: scheduled power cut never fired"
            durable = eng.recover(d, fault_plane=None)
            info = dict(eng.last_recovery)
            # first resumed token: admission + prefill + one decode
            g0 = eng.metrics["generated"]
            done: dict = {}
            while eng.step(done) and eng.metrics["generated"] == g0:
                pass
            assert eng.metrics["generated"] > g0, \
                "recovery bench: no token after recovery"
            info["mttr_s"] = time.perf_counter() - t_crash
            done.update(eng.run())
            assert not eng.active and not eng.queue, \
                "recovery bench: recovered run did not drain"
            assert len(set(durable) | set(done)) == N_SLOTS, \
                "recovery bench: lost requests across the crash"
            assert eng.journal_lane_check(), \
                "recovery bench: journal/device lane divergence"
            eng.reset(None)       # close the journal before the dir goes
            return info

    crash_recover(RECOVERY_SNAPSHOT_SWEEP[0])     # warm-up, unmeasured
    sweep = {}
    for snap_every in RECOVERY_SNAPSHOT_SWEEP:
        info = crash_recover(snap_every)
        sweep[f"snap{snap_every}"] = {
            "snapshot_every": snap_every,
            "mttr_s": round(info["mttr_s"], 4),
            "recover_s": round(info["recover_s"], 4),
            "replayed_records": int(info["replayed"]),
            "snapshot_seq": int(info["snap_seq"]),
            "last_seq": int(info["last_seq"]),
            "torn": bool(info["torn"]),
            "oob_scan": bool(info["oob_scan"]),
            "requeued": int(info["requeued"]),
        }
    return sweep


def _dispersion(sps):
    qs = statistics.quantiles(sps, n=4) if len(sps) >= 2 else [sps[0]] * 3
    return {"median": round(statistics.median(sps), 2),
            "min": round(min(sps), 2),
            "iqr": round(qs[2] - qs[0], 2),
            "windows": [round(s, 2) for s in sps]}


def main() -> None:
    repeats = 8        # multiple of the mode count: every mode sees
    if "--repeats" in sys.argv:   # every rotation position equally
        repeats = int(sys.argv[sys.argv.index("--repeats") + 1])
    quick = "--quick" in sys.argv
    n_steps = max(MACRO_K, int(24 * SCALE) // MACRO_K * MACRO_K)
    results, windows = {}, {}
    all_sps, _, _ = _run_decode(("fused_macro", "single_step",
                                 "incremental", "rebuild_legacy"),
                                n_steps, repeats)
    # ISSUE-4 group: same engine pair under ~2x oversubscription,
    # measured as continuous-batching COMPLETION rounds (its own
    # interleaved rounds — the workload differs, so its windows are
    # only comparable to each other). The acceptance ratio is computed
    # from delivered TOKENS/sec: scheduling rounds decode only
    # resident lanes, so steps/sec is not apples-to-apples here.
    over_sps, over_tps, over_eng = _run_oversub(
        ("oversub_fused", "oversub_fallback"), repeats)
    all_sps.update(over_sps)
    # ISSUE-6 group: graceful degradation under faults (its own
    # interleaved completion rounds; delivered tokens/sec)
    fault_tps, fault_eng = _run_faults(repeats)
    # ISSUE-9 group: GC walk on/off under the same oversubscription
    # (its own interleaved completion rounds; delivered tokens/sec)
    gc_tps, gc_eng = _run_gc(repeats)
    # ISSUE-10 group: copy-on-write prefix sharing — the section
    # asserts bit-identical outputs and the <= 1/4 prompt-work and
    # footprint ratios internally; the artifact records the evidence
    shared_prefix = _run_prefix()
    emit("serve_prefix_flop_ratio", 0.0,
         f"x{shared_prefix['prefill_flop_ratio']:.3f}"
         f"_pages_x{shared_prefix['device_page_ratio']:.3f}"
         f"_cow={shared_prefix['cow_moves']}")
    # ISSUE-7 group: crash -> recover MTTR across snapshot intervals
    recovery_sweep = _run_recovery()
    for name, r in recovery_sweep.items():
        emit(f"serve_recovery_mttr_{name}", r["mttr_s"] * 1e6,
             f"mttr_s={r['mttr_s']:.3f}_recover_s={r['recover_s']:.3f}"
             f"_replayed={r['replayed_records']}")
    # ISSUE-5 group: the fused macro engine across channel counts (its
    # own interleaved group — the engines are only comparable to each
    # other). On a host with fewer devices than channels the sharded
    # map lowers to vmap on ONE device (`cpu_bound` below): the sweep
    # then measures sharding overhead rather than channel parallelism,
    # and the 1/N-translate-work claim is carried by the per-channel
    # routed-lane counters instead of wall clock (EXPERIMENTS.md
    # §Channel-scaling). With >= 8 devices (tier1-sharded lane /
    # real hardware) the same engines run the shard_map lowering.
    import jax

    ch_modes = tuple(f"channels_{n}" for n in CHANNEL_SWEEP)
    ch_sps, _, ch_eng = _run_decode(ch_modes, n_steps, repeats)
    ch_disp = {f"n{n}": _dispersion(ch_sps[f"channels_{n}"])
               for n in CHANNEL_SWEEP}
    for name, d in ch_disp.items():
        emit(f"serve_decode_channels_{name}", 1e6 / d["median"],
             f"steps_per_sec={d['median']:.2f}"
             f"_min={d['min']:.2f}_iqr={d['iqr']:.2f}")
    # cpu_bound reflects the lowering that actually RAN, not the device
    # count: ServeEngine pins the vmap lowering until model/map mesh
    # co-residency lands (DESIGN.md trade-offs; ROADMAP multi-host
    # item), so today this is true even on an 8-device host — the
    # wall-clock acceptance gate only arms once kvm.mesh is real
    mesh_used = all(ch_eng[f"channels_{n}"].kvm.mesh is not None
                    for n in CHANNEL_SWEEP if n > 1)
    channel_scaling = {
        "channels": list(CHANNEL_SWEEP),
        "device_count": jax.device_count(),
        "cpu_bound": not mesh_used,
        "steps_per_sec": {k: d["median"] for k, d in ch_disp.items()},
        "dispersion": ch_disp,
        "speedup_n8_vs_n1": round(statistics.median(
            x / y for x, y in zip(ch_sps[f"channels_{max(CHANNEL_SWEEP)}"],
                                  ch_sps["channels_1"])), 2),
        # routed active lanes per channel, accumulated over every fused
        # map call of the run: each channel must carry ~1/N of the
        # translate work regardless of the lowering
        "per_channel_lanes": {
            f"n{n}": [int(x)
                      for x in ch_eng[f"channels_{n}"].kvm.channel_lanes]
            for n in CHANNEL_SWEEP if n > 1},
    }
    emit("serve_decode_channel_speedup_n8_vs_n1", 0.0,
         f"x{channel_scaling['speedup_n8_vs_n1']:.2f}"
         + ("_cpu_bound" if channel_scaling["cpu_bound"] else ""))
    for name, lanes in channel_scaling["per_channel_lanes"].items():
        # 1/N guard is an UPPER bound on skew (no channel carries more
        # than 2x its fair share): a lower bound on the minimum would
        # be wrong for short windows — page p routes to channel
        # p mod C (max_pages divides by C), so a run that has not yet
        # grown into page C-1 leaves that channel legitimately idle
        tot = max(1, sum(lanes))
        assert max(lanes) * len(lanes) <= 2 * tot, \
            f"channel routing skewed: {name} lanes {lanes}"
    for mode, sps in all_sps.items():
        windows[mode] = _dispersion(sps)
        results[mode] = windows[mode]["median"]
        emit(f"serve_decode_{mode}", 1e6 / results[mode],
             f"steps_per_sec={results[mode]:.2f}"
             f"_min={windows[mode]['min']:.2f}"
             f"_iqr={windows[mode]['iqr']:.2f}")
    # speedups as the MEDIAN OF PER-ROUND RATIOS, not the ratio of
    # medians: this box's CPU-steal bursts last seconds, so whole
    # windows get hit; windows of the same round are adjacent in time
    # and see correlated noise, making their ratio far more stable
    def med_ratio(a, b):
        return round(statistics.median(
            x / y for x, y in zip(all_sps[a], all_sps[b])), 2)

    speedups = {
        # ISSUE-3 acceptance headline: live fused path vs the PR 2
        # incremental baseline
        "fused_macro_vs_incremental":
            med_ratio("fused_macro", "incremental"),
        # macro fusion isolated from this PR's graph optimizations
        "fused_macro_vs_single_step":
            med_ratio("fused_macro", "single_step"),
        "single_step_vs_incremental":
            med_ratio("single_step", "incremental"),
        "incremental_vs_rebuild":
            med_ratio("incremental", "rebuild_legacy"),
        # ISSUE-4 acceptance headline: non-blocking swap pipeline vs
        # the PR-3 fall-back-on-pressure behavior, 2x oversubscribed —
        # measured in delivered tokens/sec (see _run_decode docstring)
        "oversub_fused_vs_fallback": round(statistics.median(
            x / y for x, y in zip(over_tps["oversub_fused"],
                                  over_tps["oversub_fallback"])), 2),
    }
    over_tokens = {mode: _dispersion(w) for mode, w in over_tps.items()}
    for mode, d in over_tokens.items():
        emit(f"serve_decode_{mode}_tokens", 1e6 / max(d["median"], 1e-9),
             f"tokens_per_sec={d['median']:.2f}"
             f"_min={d['min']:.2f}_iqr={d['iqr']:.2f}")
    # ISSUE-6 headline: median of per-round delivered-throughput ratios
    # (same correlated-noise rationale as the other speedups)
    retention = round(statistics.median(
        x / y for x, y in zip(fault_tps["faults_degraded"],
                              fault_tps["faults_healthy"])), 2)
    fault_tokens = {m: _dispersion(w) for m, w in fault_tps.items()}
    for mode, d in fault_tokens.items():
        emit(f"serve_decode_{mode}_tokens", 1e6 / max(d["median"], 1e-9),
             f"tokens_per_sec={d['median']:.2f}"
             f"_min={d['min']:.2f}_iqr={d['iqr']:.2f}")
    emit("serve_decode_degraded_retention", 0.0, f"x{retention:.2f}")
    # ISSUE-9 headline pair: GC retention (median of per-round
    # delivered-throughput ratios) and the write-amplification axis
    gc_retention = round(statistics.median(
        x / y for x, y in zip(gc_tps["gc_on"], gc_tps["gc_off"])), 2)
    gc_tokens = {m: _dispersion(w) for m, w in gc_tps.items()}
    gc_stats = {m: eng.kvm.hit_stats() for m, eng in gc_eng.items()}
    for mode, d in gc_tokens.items():
        emit(f"serve_decode_{mode}_tokens", 1e6 / max(d["median"], 1e-9),
             f"tokens_per_sec={d['median']:.2f}"
             f"_min={d['min']:.2f}_iqr={d['iqr']:.2f}")
    emit("serve_decode_gc_retention", 0.0, f"x{gc_retention:.2f}")
    emit("serve_gc_write_amp", 0.0,
         f"x{gc_stats['gc_on']['write_amp']:.3f}"
         f"_moves={gc_stats['gc_on']['gc_moves']}"
         f"_victims={sum(gc_stats['gc_on']['victims_ch'])}")
    for name, x in speedups.items():
        emit(f"serve_decode_speedup_{name}", 0.0, f"x{x:.2f}")

    path = os.environ.get("REPRO_BENCH_OUT", "BENCH_serve.json")
    # regression smoke: compare against targets and the committed
    # trajectory, but only WARN — the 2-core CI runner swings 2-3x
    # between runs, so a hard gate would be pure noise
    warnings = []
    for name, target in TARGETS.items():
        if name == "degraded_retention":
            got = retention
        elif name == "gc_retention":
            got = gc_retention
        else:
            got = speedups[name]
        if got < target:
            warnings.append(f"speedup {name} x{got:.2f} "
                            f"below x{target:.2f} target")
    # ISSUE-5 acceptance: >= 1.5x at N=8 on a real 8-device mesh; on a
    # CPU-bound host the lane counters above carry the claim instead
    if not channel_scaling["cpu_bound"] \
            and channel_scaling["speedup_n8_vs_n1"] < 1.5:
        warnings.append(
            f"channel scaling x{channel_scaling['speedup_n8_vs_n1']:.2f}"
            " below x1.50 target on an 8-device mesh")
    try:
        with open(path) as f:
            prev = json.load(f).get("steps_per_sec", {})
        for mode, now in results.items():
            old = prev.get(mode)
            if old and now < 0.6 * old:
                warnings.append(f"{mode} {now:.0f} steps/s vs "
                                f"{old:.0f} committed (>40% drop)")
    except (OSError, ValueError):
        pass
    for w in warnings:
        print(f"# WARNING: possible regression: {w}", flush=True)
    if warnings and quick:
        print("# (smoke mode: reported, not failed)", flush=True)

    out = {
        "bench": "serve_decode",
        "n_slots": N_SLOTS,
        "max_pages": MAX_PAGES,
        "macro_k": MACRO_K,
        "steps_timed": n_steps,
        "repeats": repeats,
        "steps_per_sec": results,
        "dispersion": windows,
        "speedups": speedups,
        # ISSUE-5: channel-scaling sweep of the sharded fused engine
        "channel_scaling": channel_scaling,
        # ISSUE-4: the zero-fallback claim is recorded from counters
        # so the trajectory artifact is assertable, not inferential
        "oversubscription": {
            "prompt_len": OVERSUB_PROMPT,
            "max_new": OVERSUB_MAX_NEW,
            "n_device_blocks": OVERSUB_DEV,
            "n_host_blocks": OVERSUB_HOST,
            # delivered-token throughput: the rate the acceptance
            # ratio uses (a scheduling round decodes only resident
            # lanes, so the shared steps/sec table under-specifies
            # this pair)
            "tokens_per_sec": {m: d["median"]
                               for m, d in over_tokens.items()},
            "tokens_dispersion": over_tokens,
            "modes": {
                mode: {
                    "macro_steps": eng.metrics["macro_steps"],
                    "macro_fallbacks": eng.metrics["macro_fallbacks"],
                    "swaps_out": eng.metrics["swaps_out"],
                    "swaps_in": eng.metrics["swaps_in"],
                    "pool": {
                        "swaps_out_blocks": eng.kvm.pool.stats.swaps_out,
                        "swaps_in_blocks": eng.kvm.pool.stats.swaps_in,
                    },
                } for mode, eng in over_eng.items()
            },
        },
        # ISSUE-6: graceful degradation under a deterministic fault
        # plan — retention is the acceptance headline, the recovery
        # counters prove the degraded run actually exercised the plane
        "fault_injection": {
            "channels": FAULT_CHANNELS,
            "stall": list(FAULT_STALL),
            "swap_fail_p": FAULT_SWAP_P,
            "seed": FAULT_SEED,
            "retention_degraded_vs_healthy": retention,
            "tokens_per_sec": {m: d["median"]
                               for m, d in fault_tokens.items()},
            "tokens_dispersion": fault_tokens,
            "modes": {
                mode: {
                    "swap_faults": eng.metrics["swap_faults"],
                    "quarantines": eng.metrics["quarantines"],
                    "watchdog_quarantines":
                        eng.metrics["watchdog_quarantines"],
                    "requeues": eng.metrics["requeues"],
                    "retired_blocks":
                        eng.kvm.hit_stats()["retired_blocks"],
                    "program_faults":
                        eng.kvm.hit_stats()["program_faults"],
                } for mode, eng in fault_eng.items()
            },
        },
        # ISSUE-9: the GC victim-eviction walk's write-amplification
        # axis — host writes vs flash programs (fused-path commits +
        # swap-ins + GC relocations), reclaim counters, and the CTP
        # prefetch hit accounting; retention is the acceptance headline
        "gc": {
            "watermark": GC_WATERMARK,
            "pages_per_boundary": GC_BUDGET,
            "block_pages": GC_BLOCK_PAGES,
            "retention_gc_on_vs_off": gc_retention,
            "tokens_per_sec": {m: d["median"]
                               for m, d in gc_tokens.items()},
            "tokens_dispersion": gc_tokens,
            "modes": {
                mode: {
                    "gc_walks": eng.metrics["gc_walks"],
                    "gc_moves": eng.metrics["gc_moves"],
                    "gc_victims": eng.metrics["gc_victims"],
                    "host_writes": gc_stats[mode]["host_writes"],
                    "flash_programs": gc_stats[mode]["flash_programs"],
                    "write_amp": round(gc_stats[mode]["write_amp"], 4),
                    "victims_per_channel":
                        list(gc_stats[mode]["victims_ch"]),
                    "prefetch_hits": gc_stats[mode]["prefetch_hits"],
                    "prefetch_misses": gc_stats[mode]["prefetch_misses"],
                } for mode, eng in gc_eng.items()
            },
        },
        # ISSUE-10: copy-on-write prefix sharing — prompt-work and
        # footprint ratios vs the sharing-off control, the COW
        # evidence, and the bit-identity / inertness proofs
        "shared_prefix": shared_prefix,
        # ISSUE-7: sudden-power-off recovery — MTTR per snapshot
        # interval (same deterministic crash point throughout, so the
        # replayed-record counts are the interval tradeoff, not noise)
        "recovery": {
            "channels": RECOVERY_CHANNELS,
            "seed": RECOVERY_SEED,
            "crash_at": RECOVERY_CRASH_AT,
            "snapshot_sweep": recovery_sweep,
            "mttr_s": {name: r["mttr_s"]
                       for name, r in recovery_sweep.items()},
        },
    }
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
