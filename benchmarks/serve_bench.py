"""Decode-throughput benchmark for the serving map path.

Measures steady-state decode steps/sec of `ServeEngine` at
n_slots=16, max_pages=64 (the ISSUE-2 reference point) across four
modes:

  * ``fused_macro``  — the live path: K-step fused decode macro-steps
    (K=8, ONE donated jit runs attention + sampling + page-boundary
    detection + device-side block allocation + map commit for K
    tokens, one host dispatch and one device->host sync per K steps)
    plus this PR's graph optimizations (live-page bucketing,
    single-chunk paged attention);
  * ``single_step``  — the live single-step path (same graph
    optimizations, no macro fusion): isolates the macro-step
    contribution;
  * ``incremental``  — the PR-2 incremental baseline restored
    faithfully (single-step, full-width tables, 8-page attention
    chunks): the ISSUE-3 acceptance reference;
  * ``rebuild_legacy`` — pre-PR-2: rebuilds the full table by
    re-translating every DLPN each step and masks it on host.

All modes run in-process because this box's 2-core timings are too
noisy to compare across runs; per-window dispersion (median/min/IQR
over ``--repeats`` consecutive windows) is recorded so the noise is
visible in the artifact. In ``--quick`` (CI smoke) mode, speedup
shortfalls against the targets and regressions against the committed
BENCH_serve.json are REPORTED as warnings, not failures — the runner
is too noisy for a hard gate.

Emits CSV rows (shared benchmark format) and writes ``BENCH_serve.json``
(repo root or $REPRO_BENCH_OUT) so CI can archive the perf trajectory.
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit

N_SLOTS = 16
MAX_PAGES = 64
MACRO_K = 8
WARM_STEPS = 3
# in-run speedup targets (ISSUE 3 acceptance: fused >= 1.5x incremental)
TARGETS = {"fused_macro_vs_incremental": 1.5,
           "incremental_vs_rebuild": 1.5}


def _build_engine(mode: str):
    import dataclasses

    import jax

    from repro.configs import get_arch, smoke_config
    from repro.models import Runtime, build_model
    from repro.serving.engine import ServeEngine

    # the PR-2-faithful baselines pin the pre-ISSUE-3 decode graph:
    # 8-page attention chunks (no auto-widening) and full-width tables
    pr2 = mode in ("incremental", "rebuild_legacy")
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 remat="none", page_size=8, capacity_factor=100.0,
                 paged_chunk=8 if pr2 else None)
    # minimal model: this benchmark isolates the serving *map* path
    # (the paper's FTL-exec-time claim), so model compute is kept as
    # close to zero as the engine allows — with the full smoke config
    # the transformer forward drowns the map delta on this 2-core box
    cfg = smoke_config(get_arch("llama3.2-1b"))
    cfg = dataclasses.replace(cfg, name="serve-bench-tiny",
                              n_layers=cfg.period, d_model=32, n_heads=2,
                              n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab_size=128)
    m = build_model(cfg, rt)
    params = m.init(jax.random.key(0))
    max_ctx = MAX_PAGES * rt.page_size
    eng = ServeEngine(m, params, n_slots=N_SLOTS, max_ctx=max_ctx,
                      macro_k=MACRO_K if mode == "fused_macro" else 0)
    if pr2:
        eng.min_page_bucket = MAX_PAGES    # PR 2 had no page bucketing
    if mode == "rebuild_legacy":
        _patch_legacy(eng)
    return eng


def _patch_legacy(eng):
    """Pre-PR serving map behaviour, restored for an in-run baseline:

    * admission preallocates prompt+max_new pages up front (so decode
      never grows the map — the old engine's steady state);
    * every decode step rebuilds the full [n_slots, max_pages] table by
      re-translating every DLPN through the FMMU (`retranslate_tables`,
      the churn-test oracle) and masks paused/invalid rows on host via
      numpy before shipping the table back to device;
    * the decode jit takes the host-masked table directly and does NOT
      donate the KV caches (the pre-PR jit functionally copied the
      whole pool every step)."""
    import types

    import jax

    from repro.paging.pool import OutOfBlocks

    def _legacy_decode_fn(self, params, tokens, caches, ctx_lens, tables,
                          src_valid=None):
        logits, caches = self.m.decode_step(
            params, tokens, caches, ctx_lens=ctx_lens, block_table=tables,
            src_valid=src_valid)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    def _legacy_admit(self):
        free = self._free_slots()
        while self.queue and free:
            req = self.queue[0]
            slot = free[0]
            n_pages = -(-(len(req.tokens) + req.max_new) // self.page)
            n_pages = min(n_pages, self.max_pages)
            try:
                self.kvm.new_seq(slot, n_pages)
            except OutOfBlocks:
                if not self._preempt(exclude=slot):
                    return
                continue
            self.queue.popleft()
            free.pop(0)
            req.slot = slot
            self.active[req.rid] = req
            self._do_prefill(req)

    def _legacy_decode_step(self, done):
        self._ensure_resident()
        residents = [r for r in self.active.values()
                     if self.kvm.is_resident(r.slot)]
        if not residents:
            return
        resident_slots = {r.slot for r in residents}
        tokens = np.zeros(self.n_slots, np.int32)
        for r in residents:
            tokens[r.slot] = r.out[-1] if r.out else r.tokens[-1]
        tables = np.array(self.kvm.retranslate_tables())
        step_ctx = np.asarray(self.ctx_lens, np.int64).copy()
        for slot in range(self.n_slots):
            if slot not in resident_slots:
                tables[slot, :] = self.scratch_block
                step_ctx[slot] = 0
        tables = np.where((tables < 0) | (tables >= self.scratch_block),
                          self.scratch_block, tables)
        next_tok, self.caches = self._legacy_decode(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.asarray(step_ctx, jnp.int32),
            jnp.asarray(tables, jnp.int32), None)
        self._finish_step(residents, np.asarray(next_tok), done)

    eng._admit = types.MethodType(_legacy_admit, eng)
    eng._decode_step = types.MethodType(_legacy_decode_step, eng)
    eng._legacy_decode = jax.jit(types.MethodType(_legacy_decode_fn, eng))


def _run_decode(modes, n_steps: int, repeats: int):
    """One serving run per mode, windows INTERLEAVED across modes: for
    each of `repeats` rounds, every mode times one window of n_steps
    decode steps (counted via engine metrics, so a fused macro-step
    contributes K). Interleaving matters on this 2-core virtualized
    box: CPU steal drifts on multi-second scales, so consecutive
    same-mode windows correlate and back-to-back mode blocks skew the
    ratio; round-robin windows see the same noise. Context grows
    slowly across windows (8 tokens/page) but every mode walks the
    identical schedule, so windows stay comparable. Returns
    {mode: [steps/sec per window]}."""
    engines, dones = {}, {}
    # decode jits are specialized on the live-page bucket; pin the
    # bucket that covers the whole timed range so no window eats a
    # mid-run re-trace (a bucket crossing costs seconds of XLA compile,
    # which would make that window's sample garbage)
    end_ctx = 9 + (1 + WARM_STEPS) * MACRO_K + repeats * n_steps \
        + MACRO_K
    bucket = 4
    while bucket * 8 < end_ctx + 8:
        bucket *= 2
    for mode in modes:
        eng = _build_engine(mode)
        eng.min_page_bucket = max(eng.min_page_bucket,
                                  min(bucket, MAX_PAGES))
        for i in range(N_SLOTS):
            eng.submit(list(range(1 + i, 9 + i)), max_new=10 ** 9)
        done = {}
        eng.step(done)                   # admits + prefills + first step
        for _ in range(WARM_STEPS):
            eng.step(done)
        engines[mode], dones[mode] = eng, done
    sps = {mode: [] for mode in modes}
    for rep in range(repeats):
        # rotate the order each round: the mode that follows the heavy
        # legacy window inherits its cache damage, so a fixed order
        # biases one mode systematically
        order = modes[rep % len(modes):] + modes[:rep % len(modes)]
        for mode in order:
            eng, done = engines[mode], dones[mode]
            s0 = eng.metrics["decode_steps"]
            t0 = time.perf_counter()
            while eng.metrics["decode_steps"] - s0 < n_steps:
                eng.step(done)
            sps[mode].append((eng.metrics["decode_steps"] - s0)
                             / (time.perf_counter() - t0))
    for mode, eng in engines.items():
        assert len(eng.active) == N_SLOTS, "sequences finished mid-bench"
        assert int(max(eng.ctx_lens)) < MAX_PAGES * eng.page, "ctx overflow"
        if mode == "fused_macro":
            assert eng.metrics["macro_steps"] > 0, "fused mode never fused"
            assert eng.metrics["macro_fallbacks"] == 0, "unexpected fallback"
    return sps


def _dispersion(sps):
    qs = statistics.quantiles(sps, n=4) if len(sps) >= 2 else [sps[0]] * 3
    return {"median": round(statistics.median(sps), 2),
            "min": round(min(sps), 2),
            "iqr": round(qs[2] - qs[0], 2),
            "windows": [round(s, 2) for s in sps]}


def main() -> None:
    repeats = 8        # multiple of the mode count: every mode sees
    if "--repeats" in sys.argv:   # every rotation position equally
        repeats = int(sys.argv[sys.argv.index("--repeats") + 1])
    quick = "--quick" in sys.argv
    n_steps = max(MACRO_K, int(24 * SCALE) // MACRO_K * MACRO_K)
    results, windows = {}, {}
    all_sps = _run_decode(("fused_macro", "single_step", "incremental",
                           "rebuild_legacy"), n_steps, repeats)
    for mode, sps in all_sps.items():
        windows[mode] = _dispersion(sps)
        results[mode] = windows[mode]["median"]
        emit(f"serve_decode_{mode}", 1e6 / results[mode],
             f"steps_per_sec={results[mode]:.2f}"
             f"_min={windows[mode]['min']:.2f}"
             f"_iqr={windows[mode]['iqr']:.2f}")
    # speedups as the MEDIAN OF PER-ROUND RATIOS, not the ratio of
    # medians: this box's CPU-steal bursts last seconds, so whole
    # windows get hit; windows of the same round are adjacent in time
    # and see correlated noise, making their ratio far more stable
    def med_ratio(a, b):
        return round(statistics.median(
            x / y for x, y in zip(all_sps[a], all_sps[b])), 2)

    speedups = {
        # ISSUE-3 acceptance headline: live fused path vs the PR 2
        # incremental baseline
        "fused_macro_vs_incremental":
            med_ratio("fused_macro", "incremental"),
        # macro fusion isolated from this PR's graph optimizations
        "fused_macro_vs_single_step":
            med_ratio("fused_macro", "single_step"),
        "single_step_vs_incremental":
            med_ratio("single_step", "incremental"),
        "incremental_vs_rebuild":
            med_ratio("incremental", "rebuild_legacy"),
    }
    for name, x in speedups.items():
        emit(f"serve_decode_speedup_{name}", 0.0, f"x{x:.2f}")

    path = os.environ.get("REPRO_BENCH_OUT", "BENCH_serve.json")
    # regression smoke: compare against targets and the committed
    # trajectory, but only WARN — the 2-core CI runner swings 2-3x
    # between runs, so a hard gate would be pure noise
    warnings = []
    for name, target in TARGETS.items():
        if speedups[name] < target:
            warnings.append(f"speedup {name} x{speedups[name]:.2f} "
                            f"below x{target:.2f} target")
    try:
        with open(path) as f:
            prev = json.load(f).get("steps_per_sec", {})
        for mode, now in results.items():
            old = prev.get(mode)
            if old and now < 0.6 * old:
                warnings.append(f"{mode} {now:.0f} steps/s vs "
                                f"{old:.0f} committed (>40% drop)")
    except (OSError, ValueError):
        pass
    for w in warnings:
        print(f"# WARNING: possible regression: {w}", flush=True)
    if warnings and quick:
        print("# (smoke mode: reported, not failed)", flush=True)

    out = {
        "bench": "serve_decode",
        "n_slots": N_SLOTS,
        "max_pages": MAX_PAGES,
        "macro_k": MACRO_K,
        "steps_timed": n_steps,
        "repeats": repeats,
        "steps_per_sec": results,
        "dispersion": windows,
        "speedups": speedups,
    }
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
