"""Decode-throughput benchmark for the serving map path.

Measures steady-state decode steps/sec of `ServeEngine` at
n_slots=16, max_pages=64 (the ISSUE-2 reference point) and compares the
device-resident incremental block table (the live path) against a
legacy mode that rebuilds the full [n_slots, max_pages] table by
re-translating every DLPN through the FMMU each step and masks it on
host — the pre-PR behaviour, kept here as the in-run baseline because
this box's 2-core timings are too noisy to compare across runs.

Emits CSV rows (shared benchmark format) and writes ``BENCH_serve.json``
(repo root or $REPRO_BENCH_OUT) so CI can archive the perf trajectory.
Medians over ``--repeats`` runs (default 5).
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit

N_SLOTS = 16
MAX_PAGES = 64
WARM_STEPS = 3


def _build_engine(legacy: bool):
    import dataclasses

    import jax

    from repro.configs import get_arch, smoke_config
    from repro.models import Runtime, build_model
    from repro.serving.engine import ServeEngine

    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 remat="none", page_size=8, capacity_factor=100.0)
    # minimal model: this benchmark isolates the serving *map* path
    # (the paper's FTL-exec-time claim), so model compute is kept as
    # close to zero as the engine allows — with the full smoke config
    # the transformer forward drowns the map delta on this 2-core box
    cfg = smoke_config(get_arch("llama3.2-1b"))
    cfg = dataclasses.replace(cfg, name="serve-bench-tiny",
                              n_layers=cfg.period, d_model=32, n_heads=2,
                              n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab_size=128)
    m = build_model(cfg, rt)
    params = m.init(jax.random.key(0))
    max_ctx = MAX_PAGES * rt.page_size
    eng = ServeEngine(m, params, n_slots=N_SLOTS, max_ctx=max_ctx)
    if legacy:
        _patch_legacy(eng)
    return eng


def _patch_legacy(eng):
    """Pre-PR serving map behaviour, restored for an in-run baseline:

    * admission preallocates prompt+max_new pages up front (so decode
      never grows the map — the old engine's steady state);
    * every decode step rebuilds the full [n_slots, max_pages] table by
      re-translating every DLPN through the FMMU (`retranslate_tables`,
      the churn-test oracle) and masks paused/invalid rows on host via
      numpy before shipping the table back to device;
    * the decode jit takes the host-masked table directly and does NOT
      donate the KV caches (the pre-PR jit functionally copied the
      whole pool every step)."""
    import types

    import jax

    from repro.paging.pool import OutOfBlocks

    def _legacy_decode_fn(self, params, tokens, caches, ctx_lens, tables,
                          src_valid=None):
        logits, caches = self.m.decode_step(
            params, tokens, caches, ctx_lens=ctx_lens, block_table=tables,
            src_valid=src_valid)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    def _legacy_admit(self):
        free = self._free_slots()
        while self.queue and free:
            req = self.queue[0]
            slot = free[0]
            n_pages = -(-(len(req.tokens) + req.max_new) // self.page)
            n_pages = min(n_pages, self.max_pages)
            try:
                self.kvm.new_seq(slot, n_pages)
            except OutOfBlocks:
                if not self._preempt(exclude=slot):
                    return
                continue
            self.queue.popleft()
            free.pop(0)
            req.slot = slot
            self.active[req.rid] = req
            self._do_prefill(req)

    def _legacy_decode_step(self, done):
        self._ensure_resident()
        residents = [r for r in self.active.values()
                     if self.kvm.is_resident(r.slot)]
        if not residents:
            return
        resident_slots = {r.slot for r in residents}
        tokens = np.zeros(self.n_slots, np.int32)
        for r in residents:
            tokens[r.slot] = r.out[-1] if r.out else r.tokens[-1]
        tables = np.array(self.kvm.retranslate_tables())
        step_ctx = np.asarray(self.ctx_lens, np.int64).copy()
        for slot in range(self.n_slots):
            if slot not in resident_slots:
                tables[slot, :] = self.scratch_block
                step_ctx[slot] = 0
        tables = np.where((tables < 0) | (tables >= self.scratch_block),
                          self.scratch_block, tables)
        next_tok, self.caches = self._legacy_decode(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.asarray(step_ctx, jnp.int32),
            jnp.asarray(tables, jnp.int32), None)
        self._finish_step(residents, np.asarray(next_tok), done)

    eng._admit = types.MethodType(_legacy_admit, eng)
    eng._decode_step = types.MethodType(_legacy_decode_step, eng)
    eng._legacy_decode = jax.jit(types.MethodType(_legacy_decode_fn, eng))


def _run_decode(legacy: bool, n_steps: int, repeats: int) -> float:
    """One serving run: fill all slots once, warm up, then time
    `repeats` consecutive windows of n_steps decode steps. Context
    grows slowly across windows (8 tokens/page), but both modes walk
    the identical schedule, so windows are comparable and the median
    is a stable quantity; no re-submission, so the queue stays empty."""
    eng = _build_engine(legacy)
    for i in range(N_SLOTS):
        eng.submit(list(range(1 + i, 9 + i)), max_new=10 ** 9)
    done = {}
    eng.step(done)                       # admits + prefills + first step
    for _ in range(WARM_STEPS):
        eng.step(done)
    sps = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n_steps):
            eng.step(done)
        sps.append(n_steps / (time.perf_counter() - t0))
    assert len(eng.active) == N_SLOTS, "sequences finished mid-bench"
    assert int(max(eng.ctx_lens)) < MAX_PAGES * eng.page, "ctx overflow"
    return statistics.median(sps)


def main() -> None:
    repeats = 5
    if "--repeats" in sys.argv:
        repeats = int(sys.argv[sys.argv.index("--repeats") + 1])
    n_steps = max(8, int(24 * SCALE))
    results = {}
    for mode, legacy in [("incremental", False), ("rebuild_legacy", True)]:
        results[mode] = _run_decode(legacy, n_steps, repeats)
        emit(f"serve_decode_{mode}",
             1e6 / results[mode],
             f"steps_per_sec={results[mode]:.2f}")
    speedup = results["incremental"] / results["rebuild_legacy"]
    emit("serve_decode_speedup", 0.0, f"x{speedup:.2f}_vs_rebuild")
    out = {
        "bench": "serve_decode",
        "n_slots": N_SLOTS,
        "max_pages": MAX_PAGES,
        "steps_timed": n_steps,
        "repeats": repeats,
        "steps_per_sec": {k: round(v, 2) for k, v in results.items()},
        "speedup_incremental_vs_rebuild": round(speedup, 2),
    }
    path = os.environ.get("REPRO_BENCH_OUT", "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
