"""Fig. 10 — FTL execution times of map-cache schemes (hit / miss /
flush), DFTL & CDFTL at 1/2/4 cores vs FMMU hardware, from the
calibrated micro-op cost model, validated against the paper's anchors.
"""
from __future__ import annotations

from benchmarks.common import bench_ssd_config, emit
from repro.core.ftl.costmodel import HW, SW, us
from repro.core.ftl.mapcache import CDFTLCache, DFTLCache, FMMUCache

# Paper anchors (400 MHz): value_us
PAPER_ANCHORS = {
    "dftl_hit_1c": 1.5,
    "dftl_hit_4c": 0.4,
    "cdftl_hit_1c": 4.0,     # CMT miss + CTP hit (the scheme's hit case)
    "cdftl_hit_4c": 1.0,
    "fmmu_hit": 0.16,
    "t_ftl_cmd": 0.2,
    "fmmu_flush_max": 10.0,
}


def measured_paths(cfg):
    """Drive each scheme through controlled hit/miss/flush sequences and
    read back the per-access exec cycles."""
    out = {}
    # DFTL hit: touch a block twice -> second access is a hit
    d = DFTLCache(cfg)
    d.access(0, False)
    plan = d.access(1, False)
    out["dftl_hit"] = us(plan.cycles)
    plan = d.access(10_000_000 % (cfg.logical_pages), False)  # fresh miss
    out["dftl_miss"] = us(plan.cycles + plan.fill_cycles)
    # DFTL flush: dirty a block, force eviction pressure via same-set fills
    fw = d._flush_tvpn(0)
    out["dftl_flush"] = us(fw.cycles)

    c = CDFTLCache(cfg)
    c.access(0, False)                       # cold: CMT+CTP miss
    plan = c.access(cfg.entries_per_tp // 2, False)  # same TP: CMT miss, CTP hit
    out["cdftl_hit"] = us(plan.cycles)       # the paper's CDFTL 'hit' case
    plan = c.access(5 * cfg.entries_per_tp, False)
    out["cdftl_miss"] = us(plan.cycles + plan.fill_cycles)
    fw = c._flush_cmt(0)
    out["cdftl_flush"] = us(fw.cycles)

    f = FMMUCache(cfg)
    f.access(0, True)
    plan = f.access(1, False)
    out["fmmu_hit"] = us(plan.cycles)
    plan = f.access(5 * cfg.entries_per_tp, False)
    out["fmmu_miss"] = us(plan.cycles + plan.fill_cycles)
    # flush a full chain (8 dirty blocks of one TP)
    for j in range(8):
        f.access(j * cfg.cmt_block_entries, True)
    fw = f._flush_chain(0)
    out["fmmu_flush"] = us(fw.cycles)
    return out


def main():
    cfg = bench_ssd_config()
    m = measured_paths(cfg)
    rows = []
    for cores in (1, 2, 4):
        for scheme in ("dftl", "cdftl"):
            for path in ("hit", "miss", "flush"):
                v = m[f"{scheme}_{path}"] / cores  # statically partitioned
                emit(f"fig10_{scheme}_{path}_{cores}c", v,
                     "effective per-request exec time")
                rows.append((f"{scheme}_{path}_{cores}c", v))
    for path in ("hit", "miss", "flush"):
        emit(f"fig10_fmmu_{path}", m[f"fmmu_{path}"], "hardware pipeline")

    # anchor validation
    checks = [
        ("dftl_hit_1c", m["dftl_hit"]),
        ("dftl_hit_4c", m["dftl_hit"] / 4),
        ("cdftl_hit_1c", m["cdftl_hit"]),
        ("cdftl_hit_4c", m["cdftl_hit"] / 4),
        ("fmmu_hit", m["fmmu_hit"]),
    ]
    for name, got in checks:
        want = PAPER_ANCHORS[name]
        err = abs(got - want) / want
        emit(f"fig10_anchor_{name}", got,
             f"paper={want}us err={err * 100:.1f}%")
    emit("fig10_anchor_fmmu_flush", m["fmmu_flush"],
         f"paper<=10us ok={m['fmmu_flush'] <= 10}")
    emit("fig10_claim_flush_orders", m["dftl_flush"],
         f"dftl/cdftl flush ratio={m['dftl_flush'] / m['cdftl_flush']:.1f}x "
         f"(paper: orders of magnitude)")
    return m


if __name__ == "__main__":
    main()
