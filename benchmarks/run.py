"""Run every benchmark (one per paper table/figure + kernels).
``PYTHONPATH=src python -m benchmarks.run``           full sweep
``PYTHONPATH=src python -m benchmarks.run --quick``   kernels-only smoke
(CI runs --quick per push so translate-path perf regressions surface)
CSV rows: name,us_per_call,derived
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (fig2_perf_model, fig10_ftl_exec, fig11_synthetic,
                            fig13_traces, fig14_scalability, kernel_bench,
                            serve_bench)
    quick = "--quick" in sys.argv[1:]
    mods = [
        ("fig10 (FTL exec times)", fig10_ftl_exec),
        ("fig2 (perf model)", fig2_perf_model),
        ("fig11/12 (synthetic)", fig11_synthetic),
        ("fig13 (traces)", fig13_traces),
        ("fig14 (scalability)", fig14_scalability),
        ("kernels", kernel_bench),
        ("serve (decode throughput)", serve_bench),
    ]
    if quick:
        mods = [("kernels", kernel_bench),
                ("serve (decode throughput)", serve_bench)]
    failures = 0
    print("name,us_per_call,derived")
    for name, mod in mods:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod.main()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# --- {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
