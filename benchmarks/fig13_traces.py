"""Fig. 13 — trace-driven normalized elapsed time (MSR_proj / MSR_hm /
WebSearch *surrogates* matched to Table 3; see workloads.py docstring)."""
from __future__ import annotations

from benchmarks.common import bench_ssd_config, emit, n_cmds
from repro.core.sim.ssd import SSDSim
from repro.core.sim import workloads as W

SCHEMES = [("ideal", 1), ("dftl", 1), ("dftl", 4), ("cdftl", 1),
           ("cdftl", 4), ("fmmu", 1)]
# paper's normalized-elapsed anchors (scheme/ideal)
PAPER = {("MSR_proj", "dftl1c"): 10.63, ("MSR_proj", "cdftl4c"): 1.47,
         ("MSR_hm", "dftl4c"): 3.35, ("MSR_hm", "cdftl4c"): 1.32}


def main():
    for tname, spec in W.TRACES.items():
        cmds = n_cmds(20000)
        warm = cmds // 2
        elapsed = {}
        for scheme, cores in SCHEMES:
            tag = f"{scheme}{cores}c" if scheme != "ideal" else "ideal"
            cfg = bench_ssd_config()
            if scheme == "ideal":
                sim = SSDSim(cfg, scheme="fmmu", zero_exec=True)
            else:
                sim = SSDSim(cfg, scheme=scheme, n_cores=cores)
            sim.precondition_sequential()
            r = sim.run_closed_loop(W.trace_surrogate(cfg, spec), cmds,
                                    warmup_cmds=warm)
            elapsed[tag] = r["elapsed_us"]
            norm = r["elapsed_us"] / elapsed.get("ideal", r["elapsed_us"])
            extra = ""
            if (tname, tag) in PAPER:
                extra = f" paper_norm={PAPER[(tname, tag)]}"
            emit(f"fig13_{tname}_{tag}", r["elapsed_us"] / max(cmds, 1),
                 f"normalized={norm:.2f}{extra}")
        emit(f"fig13_claim_{tname}", 0.0,
             f"fmmu_norm={elapsed['fmmu1c'] / elapsed['ideal']:.3f} "
             f"(paper: ~1.0, approaches ideal)")


if __name__ == "__main__":
    main()
