"""Fig. 11/12 — synthetic workloads (64K seq R/W, 4K rand R/W) across
map-cache schemes + ideal, with component utilizations."""
from __future__ import annotations

from benchmarks.common import bench_ssd_config, emit, n_cmds
from repro.core.sim.ssd import SSDSim
from repro.core.sim import workloads as W

SCHEMES = [("ideal", 1), ("dftl", 1), ("dftl", 4), ("cdftl", 1),
           ("cdftl", 4), ("fmmu", 1)]


def run_one(workload_fn, cmds, scheme, cores, stop_before_gc=False):
    cfg = bench_ssd_config()
    if scheme == "ideal":
        # the paper's ideal: FTL exec time = 0, map-cache flash IO kept
        sim = SSDSim(cfg, scheme="fmmu", zero_exec=True)
    else:
        sim = SSDSim(cfg, scheme=scheme, n_cores=cores)
    sim.precondition_sequential()
    if stop_before_gc:
        # paper: "random write test is performed until GC is triggered";
        # bound commands by the over-provisioning headroom
        headroom = sim.free_pages - sim.GC_LOW * sim.ppb
        cmds = min(cmds, max(1000, headroom - 64))
    res = sim.run_closed_loop(workload_fn(cfg), cmds)
    return res


def main():
    results = {}
    for wname, fn, cmds, is_bw, stop in [
        ("seqwrite64k", W.seq_write_64k, n_cmds(4000), True, False),
        ("seqread64k", W.seq_read_64k, n_cmds(6000), True, False),
        ("randwrite4k", W.rand_write_4k, n_cmds(20000), False, True),
        ("randread4k", W.rand_read_4k, n_cmds(20000), False, False),
    ]:
        for scheme, cores in SCHEMES:
            tag = f"{scheme}{cores}c" if scheme != "ideal" else "ideal"
            r = run_one(fn, cmds, scheme, cores, stop_before_gc=stop)
            results[(wname, tag)] = r
            val = r["gbps"] if is_bw else r["iops"] / 1e3
            unit = "GB/s" if is_bw else "KIOPS"
            emit(f"fig11_{wname}_{tag}", 1e6 / max(r["iops"], 1),
                 f"{val:.2f}{unit} utils[ftl={r['util_ftl']:.2f} "
                 f"chip={r['util_chip']:.2f} bus={r['util_bus']:.2f} "
                 f"host={r['util_host']:.2f}]")
    # paper claims
    for wname in ("seqwrite64k", "seqread64k", "randwrite4k", "randread4k"):
        ideal = results[(wname, "ideal")]["iops"]
        fmmu = results[(wname, "fmmu1c")]["iops"]
        d1 = results[(wname, "dftl1c")]["iops"]
        emit(f"fig11_claim_{wname}", 0.0,
             f"fmmu/ideal={fmmu / max(ideal, 1):.3f} (paper ~1.0) "
             f"dftl1c/ideal={d1 / max(ideal, 1):.3f} (<1: FTL-bound)")
    rr = results[("randread4k", "fmmu1c")]
    emit("fig12_claim_fmmu_ftl_util", rr["util_ftl"],
         f"paper ~0.17 at full randread load")


if __name__ == "__main__":
    main()
