"""Fig. 14 — FMMU scalability: 4KB random read (map-hit case) under
PCIe 3.0 x32 while scaling NAND from 1ch/1way to 32ch/8way. The claim:
the FMMU never becomes the bottleneck; the NAND bus does."""
from __future__ import annotations

from benchmarks.common import bench_ssd_config, emit, n_cmds
from repro.core.sim.ssd import SSDSim
from repro.core.sim import workloads as W

CONFIGS = [(1, 1), (2, 2), (4, 4), (8, 8), (16, 8), (32, 8)]


def main():
    last = None
    for ch, way in CONFIGS:
        cfg = bench_ssd_config(channels=ch, ways=way, capacity_gb=1,
                               host_bw_gbps=31.52)  # PCIe 3.0 x32
        sim = SSDSim(cfg, scheme="fmmu")
        sim.precondition_sequential()
        r = sim.run_closed_loop(W.rand_read_4k(cfg), n_cmds(20000))
        miops = r["iops"] / 1e6
        bottleneck = max(("ftl", r["util_ftl"]), ("bus", r["util_bus"]),
                         ("chip", r["util_chip"]), ("host", r["util_host"]),
                         key=lambda kv: kv[1])
        emit(f"fig14_fmmu_{ch}ch{way}w", 1e6 / max(r["iops"], 1),
             f"{miops:.2f}MIOPS bottleneck={bottleneck[0]}"
             f"@{bottleneck[1]:.2f}")
        last = (miops, bottleneck, r)
    miops, bottleneck, r = last
    emit("fig14_claim_32ch8w", miops,
         f"paper=4.3MIOPS/bus-bound; ours={miops:.2f}MIOPS "
         f"bottleneck={bottleneck[0]} ftl_util={r['util_ftl']:.2f} "
         f"(FTL not the bottleneck: {r['util_ftl'] < 0.9})")


if __name__ == "__main__":
    main()
