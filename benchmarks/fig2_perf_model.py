"""Fig. 2 — 4KB random-read performance vs NAND configuration and FTL
execution time (map-hit and map-miss cases). Shows when the FTL becomes
the SSD bottleneck as parallelism scales (§3.2)."""
from __future__ import annotations

from benchmarks.common import bench_ssd_config, emit, n_cmds
from repro.core.sim.ssd import SSDSim
from repro.core.sim import workloads as W


CONFIGS = [(1, 1), (2, 2), (4, 4), (8, 4), (8, 8), (16, 8)]
T_FTLS = [0.0, 0.5, 1.0, 2.0, 4.0]


def run_cell(ch, way, t_ftl, miss: bool, cmds: int):
    cfg = bench_ssd_config(channels=ch, ways=way, capacity_gb=1)
    sim = SSDSim(cfg, scheme="ideal", t_ftl_us=t_ftl, fixed_miss=miss)
    sim.precondition_sequential()
    res = sim.run_closed_loop(W.rand_read_4k(cfg), cmds)
    return res


def main():
    cmds = n_cmds(8000)
    for miss in (False, True):
        tagm = "miss" if miss else "hit"
        for ch, way in CONFIGS:
            for t in T_FTLS:
                r = run_cell(ch, way, t, miss, cmds)
                kiops = r["iops"] / 1e3
                bottleneck = max(
                    ("ftl", r["util_ftl"]), ("bus", r["util_bus"]),
                    ("chip", r["util_chip"]), ("host", r["util_host"]),
                    key=lambda kv: kv[1])
                emit(f"fig2_{tagm}_{ch}ch{way}w_tftl{t}", 1e6 / max(r['iops'], 1),
                     f"{kiops:.0f}KIOPS bottleneck={bottleneck[0]}"
                     f"@{bottleneck[1]:.2f}")
    # paper claim checks: with 1us FTL, hit case bottlenecks by 8ch8way;
    # miss case only by 16ch8way (two flash ops amortize the FTL).
    r_hit = run_cell(8, 8, 1.0, False, cmds)
    r_miss = run_cell(8, 8, 1.0, True, cmds)
    emit("fig2_claim_hit_8ch8w_ftl_bound", r_hit["util_ftl"],
         f"ftl_util={r_hit['util_ftl']:.2f} (paper: FTL is bottleneck)")
    emit("fig2_claim_miss_8ch8w_not_bound", r_miss["util_ftl"],
         f"ftl_util={r_miss['util_ftl']:.2f} (paper: bottleneck arrives "
         f"later, at 16ch8way)")


if __name__ == "__main__":
    main()
