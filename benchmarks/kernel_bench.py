"""Microbenchmarks of the compute kernels (CPU: blocked-jnp lowering —
the same graphs the dry-run compiles; Mosaic timing requires real TPU)
and of the batched FMMU translation engine."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.fmmu import batch as B
from repro.core.fmmu.types import small_geometry, FMMUGeometry
from repro.kernels import ops


def _time(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    k = jax.random.key(0)
    # flash attention (train-ish tile)
    b, s, h, kv, d = 1, 2048, 8, 2, 64
    q = jax.random.normal(k, (b, s, h, d), jnp.bfloat16)
    kk = jax.random.normal(k, (b, s, kv, d), jnp.bfloat16)
    v = jax.random.normal(k, (b, s, kv, d), jnp.bfloat16)
    fa = jax.jit(lambda q, kk, v: ops.flash_attention(q, kk, v, impl="blocked"))
    us = _time(fa, q, kk, v)
    flops = 4 * b * h * d * s * s / 2
    emit("kernel_flash_attention_2k", us, f"{flops / us / 1e3:.1f} GFLOP/s cpu")

    # paged decode attention
    nb, p = 512, 64
    qd = jax.random.normal(k, (8, h, d), jnp.bfloat16)
    kp = jax.random.normal(k, (nb, p, kv, d), jnp.bfloat16)
    vp = jax.random.normal(k, (nb, p, kv, d), jnp.bfloat16)
    table = jnp.tile(jnp.arange(64)[None], (8, 1))
    ctx = jnp.full((8,), 64 * p - 3)
    pa = jax.jit(lambda *a: ops.paged_attention(*a, impl="blocked"))
    us = _time(pa, qd, kp, vp, table, ctx)
    emit("kernel_paged_attention_4kctx", us, "8 seqs x 4096 ctx decode")

    # mamba chunk scan
    x = jax.random.normal(k, (2, 2048, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(k, (2, 2048, 8)))
    A = -jnp.exp(jax.random.normal(k, (8,)))
    Bm = jax.random.normal(k, (2, 2048, 16))
    C = jax.random.normal(k, (2, 2048, 16))
    D = jnp.ones((8,))
    ms = jax.jit(lambda *a: ops.mamba_chunk_scan(*a, chunk=256, impl="blocked")[0])
    us = _time(ms, x, dt, A, Bm, C, D)
    emit("kernel_mamba_scan_2k", us, "2x2048 SSD chunked")

    # batched FMMU translate (the paper's hot path, vectorized)
    g = FMMUGeometry(cmt_sets=512, cmt_ways=4, cmt_entries=8,
                     ctp_sets=16, ctp_ways=4, entries_per_tp=4096,
                     n_tvpns=256, queue_cap=64)
    st = B.init_batch_state(g)
    fns = B.make_jitted(g)
    dl = jax.random.randint(k, (512,), 0, g.n_tvpns * g.entries_per_tp)
    st = fns["update"](st, dl, dl)
    us = _time(lambda s_, d_: fns["lookup"](s_, d_)[1], st, dl, iters=20)
    emit("kernel_fmmu_lookup_512", us,
         f"{512 / us:.1f} translations/us vectorized "
         f"(paper FSM: 1 per 0.16us)")


if __name__ == "__main__":
    main()
