"""Microbenchmarks of the compute kernels (CPU: blocked-jnp lowering —
the same graphs the dry-run compiles; Mosaic timing requires real TPU)
and of the batched FMMU translation engine (fused single-probe
translate pipeline vs the unfused pre-fusion sequence)."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.fmmu import batch as B
from repro.core.fmmu.types import (COND_UPDATE, LOOKUP, UPDATE,
                                   FMMUGeometry, small_geometry)
from repro.kernels import ops


def _time(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _time_state(step, st, iters=20):
    """Time a state-threading FMMU step: jitted closures DONATE the
    state buffer, so each call must consume the previous call's
    output rather than reuse a stale (already-donated) argument."""
    st = step(st)                 # warmup + compile
    jax.block_until_ready(st)
    t0 = time.perf_counter()
    for _ in range(iters):
        st = step(st)
    jax.block_until_ready(st)
    return (time.perf_counter() - t0) / iters * 1e6, st


def main():
    k = jax.random.key(0)
    # flash attention (train-ish tile)
    b, s, h, kv, d = 1, 2048, 8, 2, 64
    q = jax.random.normal(k, (b, s, h, d), jnp.bfloat16)
    kk = jax.random.normal(k, (b, s, kv, d), jnp.bfloat16)
    v = jax.random.normal(k, (b, s, kv, d), jnp.bfloat16)
    fa = jax.jit(lambda q, kk, v: ops.flash_attention(q, kk, v, impl="blocked"))
    us = _time(fa, q, kk, v)
    flops = 4 * b * h * d * s * s / 2
    emit("kernel_flash_attention_2k", us, f"{flops / us / 1e3:.1f} GFLOP/s cpu")

    # paged decode attention
    nb, p = 512, 64
    qd = jax.random.normal(k, (8, h, d), jnp.bfloat16)
    kp = jax.random.normal(k, (nb, p, kv, d), jnp.bfloat16)
    vp = jax.random.normal(k, (nb, p, kv, d), jnp.bfloat16)
    table = jnp.tile(jnp.arange(64)[None], (8, 1))
    ctx = jnp.full((8,), 64 * p - 3)
    pa = jax.jit(lambda *a: ops.paged_attention(*a, impl="blocked"))
    us = _time(pa, qd, kp, vp, table, ctx)
    emit("kernel_paged_attention_4kctx", us, "8 seqs x 4096 ctx decode")

    # mamba chunk scan
    x = jax.random.normal(k, (2, 2048, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(k, (2, 2048, 8)))
    A = -jnp.exp(jax.random.normal(k, (8,)))
    Bm = jax.random.normal(k, (2, 2048, 16))
    C = jax.random.normal(k, (2, 2048, 16))
    D = jnp.ones((8,))
    ms = jax.jit(lambda *a: ops.mamba_chunk_scan(*a, chunk=256, impl="blocked")[0])
    us = _time(ms, x, dt, A, Bm, C, D)
    emit("kernel_mamba_scan_2k", us, "2x2048 SSD chunked")

    # batched FMMU translate (the paper's hot path, vectorized)
    g = FMMUGeometry(cmt_sets=512, cmt_ways=4, cmt_entries=8,
                     ctp_sets=16, ctp_ways=4, entries_per_tp=4096,
                     n_tvpns=256, queue_cap=64)
    bq = 512
    st = B.init_batch_state(g)
    fns = B.make_jitted(g)
    dl = jax.random.randint(k, (bq,), 0, g.n_tvpns * g.entries_per_tp)
    st = fns["update"](st, dl, dl)
    us, st = _time_state(lambda s_: fns["lookup"](s_, dl)[0], st)
    emit("kernel_fmmu_lookup_512", us,
         f"{bq / us:.1f} translations/us vectorized "
         f"(paper FSM: 1 per 0.16us)")

    # fused mixed-op translate (one probe + one insert for the whole
    # LOOKUP/UPDATE/COND_UPDATE mix) vs the unfused pre-fusion sequence
    # (one call per op kind; CondUpdate alone re-probes + re-inserts)
    kb = jax.random.key(1)
    opc = jnp.asarray([LOOKUP] * (bq // 2) + [UPDATE] * (bq // 4)
                      + [COND_UPDATE] * (bq // 4), jnp.int32)
    opc = jax.random.permutation(kb, opc)
    dl2 = jax.random.permutation(
        kb, g.n_tvpns * g.entries_per_tp)[:bq].astype(jnp.int32)
    dp2 = jax.random.randint(jax.random.fold_in(kb, 1), (bq,), 0, 10 ** 6)
    old2 = jax.random.randint(jax.random.fold_in(kb, 2), (bq,), 0, 10 ** 6)
    old2 = jnp.where(jax.random.bernoulli(jax.random.fold_in(kb, 3), 0.5,
                                          (bq,)), dp2, old2)  # ~half apply
    ml, mu, mc = (opc == LOOKUP), (opc == UPDATE), (opc == COND_UPDATE)
    dll, dlu, dlc = dl2[ml], dl2[mu], dl2[mc]
    dpu, dpc, oldc = dp2[mu], dp2[mc], old2[mc]

    st = B.init_batch_state(g)
    st = fns["update"](st, dl2, dp2)
    us_fused, st = _time_state(
        lambda s_: fns["translate"](s_, opc, dl2, dp2, old2)[0], st)

    # baseline donates too: the ratio must measure fusion, not the
    # state-copy elimination donation buys both paths equally
    lu = jax.jit(functools.partial(B.lookup_batch_unfused, g),
                 donate_argnums=(0,))
    uu = jax.jit(functools.partial(B.update_batch_unfused, g),
                 donate_argnums=(0,))
    cu = jax.jit(functools.partial(B.cond_update_batch_unfused, g),
                 donate_argnums=(0,))

    def legacy_seq(s_):
        s_, _ = lu(s_, dll)
        s_ = uu(s_, dlu, dpu)
        s_, _ = cu(s_, dlc, dpc, oldc)
        return s_

    st2 = B.init_batch_state(g)
    st2 = fns["update"](st2, dl2, dp2)
    us_legacy, _ = _time_state(legacy_seq, st2)
    emit("fmmu_translate_mixed_512", us_fused,
         f"{us_legacy / us_fused:.2f}x vs unfused 3-call sequence "
         f"({us_legacy:.1f}us); lookup-only {us:.1f}us")


if __name__ == "__main__":
    main()
