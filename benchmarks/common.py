"""Shared benchmark helpers. Scale via env:
REPRO_BENCH_SCALE  — command-count multiplier (default 1.0; paper-full ~20)
REPRO_BENCH_FULL=1 — paper-exact 16GB / full geometry (slow)
"""
from __future__ import annotations

import dataclasses
import os
import sys
import time

from repro.configs.fmmu_paper import PAPER_SSD, SSDConfig

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def bench_ssd_config(channels=None, ways=None, capacity_gb=None,
                     host_bw_gbps=None) -> SSDConfig:
    """Paper config, optionally reduced for bench wall-time."""
    kw = {}
    # paper geometry by default: the 16GB/1,088KB-RAM ratio is what makes
    # DFTL/CDFTL map-RAM-bound (shrinking capacity hides the effect)
    kw["capacity_gb"] = capacity_gb or 16
    if channels:
        kw["channels"] = channels
    if ways:
        kw["ways"] = ways
    if host_bw_gbps:
        kw["host_bw_gbps"] = host_bw_gbps
    return dataclasses.replace(PAPER_SSD, **kw)


def n_cmds(base: int) -> int:
    return max(500, int(base * SCALE))


def emit(name: str, value_us: float, derived: str = ""):
    """CSV row: name,us_per_call,derived"""
    print(f"{name},{value_us:.4f},{derived}", flush=True)


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.wall = time.time() - self.t0
