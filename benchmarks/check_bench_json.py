"""Schema gate for the serve-bench trajectory artifact.

CI's bench-smoke lane pipes serve_bench output into BENCH_serve.json
and archives a BENCH_history line per push. Perf regressions stay
warn-not-fail (the 2-core runner is too noisy for a hard gate — see
serve_bench's measurement protocol), but a MALFORMED or MISSING
artifact is a build bug, not noise: this checker hard-fails CI on it
so the trajectory stays machine-readable across pushes.

Usage:
    python benchmarks/check_bench_json.py BENCH_serve.json \
        [--append-history BENCH_history.jsonl]

``--append-history`` appends one compact JSON line (commit stamp from
$GITHUB_SHA when set, plus the headline numbers) after validation —
the file accretes across pushes via the CI cache and is uploaded as an
artifact, giving a greppable perf trajectory without a dashboard.
"""
from __future__ import annotations

import json
import os
import sys

# every mode serve_bench must have timed, and the speedup ratios the
# acceptance criteria quote — a missing key means the bench silently
# stopped measuring something the trajectory tracks
REQUIRED_MODES = ("fused_macro", "single_step", "incremental",
                  "rebuild_legacy", "oversub_fused", "oversub_fallback")
REQUIRED_SPEEDUPS = ("fused_macro_vs_incremental",
                     "fused_macro_vs_single_step",
                     "incremental_vs_rebuild",
                     "oversub_fused_vs_fallback")
DISPERSION_KEYS = ("median", "min", "iqr", "windows")


class SchemaError(Exception):
    pass


def _req(cond: bool, msg: str):
    if not cond:
        raise SchemaError(msg)


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def check(doc: dict) -> None:
    """Raise SchemaError unless `doc` is a well-formed BENCH_serve."""
    _req(isinstance(doc, dict), "top level is not an object")
    for key in ("bench", "n_slots", "max_pages", "macro_k",
                "steps_timed", "repeats", "steps_per_sec", "dispersion",
                "speedups", "oversubscription"):
        _req(key in doc, f"missing top-level key {key!r}")
    _req(doc["bench"] == "serve_decode",
         f"bench is {doc['bench']!r}, expected 'serve_decode'")
    for key in ("n_slots", "max_pages", "macro_k", "steps_timed",
                "repeats"):
        _req(isinstance(doc[key], int) and doc[key] > 0,
             f"{key} is not a positive int")
    sps, disp = doc["steps_per_sec"], doc["dispersion"]
    for mode in REQUIRED_MODES:
        _req(mode in sps, f"steps_per_sec missing mode {mode!r}")
        _req(_num(sps[mode]) and sps[mode] > 0,
             f"steps_per_sec[{mode!r}] is not a positive number")
        _req(mode in disp, f"dispersion missing mode {mode!r}")
        d = disp[mode]
        for k in DISPERSION_KEYS:
            _req(k in d, f"dispersion[{mode!r}] missing {k!r}")
        _req(isinstance(d["windows"], list) and d["windows"]
             and all(_num(w) for w in d["windows"]),
             f"dispersion[{mode!r}].windows is not a number list")
        _req(len(d["windows"]) == doc["repeats"],
             f"dispersion[{mode!r}] has {len(d['windows'])} windows, "
             f"expected repeats={doc['repeats']}")
    for name in REQUIRED_SPEEDUPS:
        _req(name in doc["speedups"], f"speedups missing {name!r}")
        _req(_num(doc["speedups"][name]) and doc["speedups"][name] > 0,
             f"speedups[{name!r}] is not a positive number")
    over = doc["oversubscription"]
    for key in ("prompt_len", "max_new", "n_device_blocks",
                "n_host_blocks", "tokens_per_sec", "modes"):
        _req(key in over, f"oversubscription missing {key!r}")
    for mode in ("oversub_fused", "oversub_fallback"):
        # the acceptance ratio is computed from delivered tokens/sec,
        # so the trajectory must record it per mode
        _req(_num(over["tokens_per_sec"].get(mode))
             and over["tokens_per_sec"][mode] > 0,
             f"oversubscription.tokens_per_sec[{mode!r}] "
             "is not a positive number")
        _req(mode in over["modes"],
             f"oversubscription.modes missing {mode!r}")
        counters = over["modes"][mode]
        for key in ("macro_steps", "macro_fallbacks", "swaps_out",
                    "swaps_in"):
            _req(isinstance(counters.get(key), int),
                 f"oversubscription.modes[{mode!r}].{key} "
                 "is not an int")


def history_line(doc: dict) -> dict:
    """One compact trajectory record for BENCH_history.jsonl."""
    return {
        "sha": os.environ.get("GITHUB_SHA", "local"),
        "steps_per_sec": doc["steps_per_sec"],
        "speedups": doc["speedups"],
        "oversub_tokens_per_sec": doc["oversubscription"]["tokens_per_sec"],
        "oversub_fallbacks": {
            mode: counters["macro_fallbacks"]
            for mode, counters in doc["oversubscription"]["modes"].items()
        },
    }


def main(argv) -> int:
    if not argv or argv[0].startswith("-"):
        print("usage: check_bench_json.py BENCH_serve.json "
              "[--append-history FILE]", file=sys.stderr)
        return 2
    path = argv[0]
    hist = None
    if "--append-history" in argv:
        hist = argv[argv.index("--append-history") + 1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        print(f"FAIL: {path} missing or unreadable: {e}", file=sys.stderr)
        return 1
    except ValueError as e:
        print(f"FAIL: {path} is not valid JSON: {e}", file=sys.stderr)
        return 1
    try:
        check(doc)
    except SchemaError as e:
        print(f"FAIL: {path} malformed: {e}", file=sys.stderr)
        return 1
    print(f"OK: {path} conforms "
          f"({len(doc['steps_per_sec'])} modes, "
          f"{len(doc['speedups'])} speedups)")
    if hist:
        with open(hist, "a") as f:
            json.dump(history_line(doc), f, separators=(",", ":"))
            f.write("\n")
        print(f"OK: appended trajectory line to {hist}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
