"""Schema gate for the serve-bench trajectory artifact.

CI's bench-smoke lane pipes serve_bench output into BENCH_serve.json
and archives a BENCH_history line per push. Perf regressions stay
warn-not-fail (the 2-core runner is too noisy for a hard gate — see
serve_bench's measurement protocol), but a MALFORMED or MISSING
artifact is a build bug, not noise: this checker hard-fails CI on it
so the trajectory stays machine-readable across pushes.

Usage:
    python benchmarks/check_bench_json.py BENCH_serve.json \
        [--append-history BENCH_history.jsonl]

``--append-history`` appends one compact JSON line (commit stamp from
$GITHUB_SHA when set, plus the headline numbers) after validation —
the file accretes across pushes via the CI cache and is uploaded as an
artifact, giving a greppable perf trajectory without a dashboard.
"""
from __future__ import annotations

import json
import os
import sys

# every mode serve_bench must have timed, and the speedup ratios the
# acceptance criteria quote — a missing key means the bench silently
# stopped measuring something the trajectory tracks
REQUIRED_MODES = ("fused_macro", "single_step", "incremental",
                  "rebuild_legacy", "oversub_fused", "oversub_fallback")
REQUIRED_SPEEDUPS = ("fused_macro_vs_incremental",
                     "fused_macro_vs_single_step",
                     "incremental_vs_rebuild",
                     "oversub_fused_vs_fallback")
DISPERSION_KEYS = ("median", "min", "iqr", "windows")


class SchemaError(Exception):
    pass


def _req(cond: bool, msg: str):
    if not cond:
        raise SchemaError(msg)


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def check(doc: dict) -> None:
    """Raise SchemaError unless `doc` is a well-formed BENCH_serve."""
    _req(isinstance(doc, dict), "top level is not an object")
    for key in ("bench", "n_slots", "max_pages", "macro_k",
                "steps_timed", "repeats", "steps_per_sec", "dispersion",
                "speedups", "oversubscription", "channel_scaling",
                "fault_injection", "gc", "shared_prefix", "recovery"):
        _req(key in doc, f"missing top-level key {key!r}")
    _req(doc["bench"] == "serve_decode",
         f"bench is {doc['bench']!r}, expected 'serve_decode'")
    for key in ("n_slots", "max_pages", "macro_k", "steps_timed",
                "repeats"):
        _req(isinstance(doc[key], int) and doc[key] > 0,
             f"{key} is not a positive int")
    sps, disp = doc["steps_per_sec"], doc["dispersion"]
    for mode in REQUIRED_MODES:
        _req(mode in sps, f"steps_per_sec missing mode {mode!r}")
        _req(_num(sps[mode]) and sps[mode] > 0,
             f"steps_per_sec[{mode!r}] is not a positive number")
        _req(mode in disp, f"dispersion missing mode {mode!r}")
        d = disp[mode]
        for k in DISPERSION_KEYS:
            _req(k in d, f"dispersion[{mode!r}] missing {k!r}")
        _req(isinstance(d["windows"], list) and d["windows"]
             and all(_num(w) for w in d["windows"]),
             f"dispersion[{mode!r}].windows is not a number list")
        _req(len(d["windows"]) == doc["repeats"],
             f"dispersion[{mode!r}] has {len(d['windows'])} windows, "
             f"expected repeats={doc['repeats']}")
    for name in REQUIRED_SPEEDUPS:
        _req(name in doc["speedups"], f"speedups missing {name!r}")
        _req(_num(doc["speedups"][name]) and doc["speedups"][name] > 0,
             f"speedups[{name!r}] is not a positive number")
    over = doc["oversubscription"]
    for key in ("prompt_len", "max_new", "n_device_blocks",
                "n_host_blocks", "tokens_per_sec", "modes"):
        _req(key in over, f"oversubscription missing {key!r}")
    for mode in ("oversub_fused", "oversub_fallback"):
        # the acceptance ratio is computed from delivered tokens/sec,
        # so the trajectory must record it per mode
        _req(_num(over["tokens_per_sec"].get(mode))
             and over["tokens_per_sec"][mode] > 0,
             f"oversubscription.tokens_per_sec[{mode!r}] "
             "is not a positive number")
        _req(mode in over["modes"],
             f"oversubscription.modes missing {mode!r}")
        counters = over["modes"][mode]
        for key in ("macro_steps", "macro_fallbacks", "swaps_out",
                    "swaps_in"):
            _req(isinstance(counters.get(key), int),
                 f"oversubscription.modes[{mode!r}].{key} "
                 "is not an int")
    # ISSUE-5: the channel-scaling sweep must record every swept N, the
    # N8-vs-N1 headline, the CPU-bound caveat flag, and the per-channel
    # routed-lane counters that carry the 1/N claim on CPU-bound hosts
    cs = doc["channel_scaling"]
    for key in ("channels", "device_count", "cpu_bound",
                "steps_per_sec", "dispersion", "speedup_n8_vs_n1",
                "per_channel_lanes"):
        _req(key in cs, f"channel_scaling missing {key!r}")
    _req(isinstance(cs["channels"], list) and cs["channels"]
         and all(isinstance(n, int) and n > 0 for n in cs["channels"]),
         "channel_scaling.channels is not a positive-int list")
    # the headline key is literally n8-vs-n1: a trimmed sweep must not
    # silently record a mislabeled ratio under the unchanged name
    _req(1 in cs["channels"] and 8 in cs["channels"],
         "channel_scaling.channels must include 1 and 8 (the "
         "speedup_n8_vs_n1 endpoints)")
    _req(isinstance(cs["cpu_bound"], bool),
         "channel_scaling.cpu_bound is not a bool")
    _req(isinstance(cs["device_count"], int) and cs["device_count"] > 0,
         "channel_scaling.device_count is not a positive int")
    _req(_num(cs["speedup_n8_vs_n1"]) and cs["speedup_n8_vs_n1"] > 0,
         "channel_scaling.speedup_n8_vs_n1 is not a positive number")
    for n in cs["channels"]:
        key = f"n{n}"
        _req(_num(cs["steps_per_sec"].get(key))
             and cs["steps_per_sec"][key] > 0,
             f"channel_scaling.steps_per_sec[{key!r}] "
             "is not a positive number")
        d = cs["dispersion"].get(key)
        _req(isinstance(d, dict), f"channel_scaling.dispersion missing "
             f"{key!r}")
        for k in DISPERSION_KEYS:
            _req(k in d, f"channel_scaling.dispersion[{key!r}] "
                 f"missing {k!r}")
        _req(isinstance(d["windows"], list) and d["windows"]
             and all(_num(w) for w in d["windows"]),
             f"channel_scaling.dispersion[{key!r}].windows is not a "
             "number list")
        _req(len(d["windows"]) == doc["repeats"],
             f"channel_scaling.dispersion[{key!r}] has "
             f"{len(d['windows'])} windows, expected "
             f"repeats={doc['repeats']}")
        if n > 1:
            lanes = cs["per_channel_lanes"].get(key)
            _req(isinstance(lanes, list) and len(lanes) == n
                 and all(isinstance(x, int) and x >= 0 for x in lanes)
                 and sum(lanes) > 0,
                 f"channel_scaling.per_channel_lanes[{key!r}] is not "
                 f"a length-{n} non-negative int list with a positive "
                 "sum")
    # ISSUE-6: the fault-injection group must record the degraded
    # retention headline, both modes' delivered throughput, and the
    # recovery counters that prove the degraded run exercised the plane
    fi = doc["fault_injection"]
    for key in ("channels", "stall", "swap_fail_p", "seed",
                "retention_degraded_vs_healthy", "tokens_per_sec",
                "modes"):
        _req(key in fi, f"fault_injection missing {key!r}")
    _req(isinstance(fi["channels"], int) and fi["channels"] > 0,
         "fault_injection.channels is not a positive int")
    _req(isinstance(fi["stall"], list)
         and len(fi["stall"]) == fi["channels"]
         and all(_num(s) and s >= 1.0 for s in fi["stall"]),
         "fault_injection.stall is not a per-channel >=1 number list")
    _req(_num(fi["retention_degraded_vs_healthy"])
         and fi["retention_degraded_vs_healthy"] > 0,
         "fault_injection.retention_degraded_vs_healthy is not a "
         "positive number")
    for mode in ("faults_healthy", "faults_degraded"):
        _req(_num(fi["tokens_per_sec"].get(mode))
             and fi["tokens_per_sec"][mode] > 0,
             f"fault_injection.tokens_per_sec[{mode!r}] "
             "is not a positive number")
        counters = fi["modes"].get(mode)
        _req(isinstance(counters, dict),
             f"fault_injection.modes missing {mode!r}")
        for key in ("swap_faults", "quarantines",
                    "watchdog_quarantines", "requeues",
                    "retired_blocks", "program_faults"):
            _req(isinstance(counters.get(key), int),
                 f"fault_injection.modes[{mode!r}].{key} is not an int")
    # the degraded run must actually have hit faults, and the healthy
    # control must not have — otherwise the retention number is
    # measuring nothing
    _req(fi["modes"]["faults_degraded"]["swap_faults"] > 0,
         "fault_injection degraded run fired zero swap faults")
    _req(fi["modes"]["faults_healthy"]["swap_faults"] == 0,
         "fault_injection healthy control fired swap faults")
    # ISSUE-9: the gc group must record the write-amplification axis
    # (WA is flash/host, so it can never be < 1), the retention
    # headline, and the reclaim counters — and the counters must prove
    # the gc_on run actually walked (non-zero moves) while the gc_off
    # control stayed inert (zero moves), or the retention number is
    # measuring nothing
    gc = doc["gc"]
    for key in ("watermark", "pages_per_boundary", "block_pages",
                "retention_gc_on_vs_off", "tokens_per_sec", "modes"):
        _req(key in gc, f"gc missing {key!r}")
    for key in ("watermark", "pages_per_boundary", "block_pages"):
        _req(isinstance(gc[key], int) and gc[key] > 0,
             f"gc.{key} is not a positive int")
    _req(_num(gc["retention_gc_on_vs_off"])
         and gc["retention_gc_on_vs_off"] > 0,
         "gc.retention_gc_on_vs_off is not a positive number")
    for mode in ("gc_off", "gc_on"):
        _req(_num(gc["tokens_per_sec"].get(mode))
             and gc["tokens_per_sec"][mode] > 0,
             f"gc.tokens_per_sec[{mode!r}] is not a positive number")
        counters = gc["modes"].get(mode)
        _req(isinstance(counters, dict), f"gc.modes missing {mode!r}")
        for key in ("gc_walks", "gc_moves", "gc_victims",
                    "host_writes", "flash_programs",
                    "prefetch_hits", "prefetch_misses"):
            _req(isinstance(counters.get(key), int)
                 and counters[key] >= 0,
                 f"gc.modes[{mode!r}].{key} is not a "
                 "non-negative int")
        _req(_num(counters.get("write_amp"))
             and counters["write_amp"] >= 1.0,
             f"gc.modes[{mode!r}].write_amp is not a number >= 1.0")
        vpc = counters.get("victims_per_channel")
        _req(isinstance(vpc, list) and vpc
             and all(isinstance(x, int) and x >= 0 for x in vpc),
             f"gc.modes[{mode!r}].victims_per_channel is not a "
             "non-negative int list")
    _req(gc["modes"]["gc_on"]["gc_moves"] > 0,
         "gc_on run relocated zero pages (walk measured nothing)")
    _req(gc["modes"]["gc_off"]["gc_moves"] == 0,
         "gc_off control relocated pages (GC not actually disabled)")
    # ISSUE-10: the prefix-sharing group must record the prefill-FLOP
    # and device-page ratios (both in (0, 1] — sharing can only shrink
    # prompt work), the shared-page evidence, COW relocations (> 0 in
    # the forced-divergence sub-case, or divergence measured nothing),
    # and the bit-identity / sharing-off-inert proofs
    sp = doc["shared_prefix"]
    for key in ("batch", "common_tokens", "tail_tokens", "max_new",
                "prefill_tokens", "prefill_flop_ratio", "device_pages",
                "device_page_ratio", "shared_admits", "shared_pages",
                "cow_moves", "outputs_bit_identical", "off_inert",
                "forced_divergence"):
        _req(key in sp, f"shared_prefix missing {key!r}")
    for key in ("batch", "common_tokens", "tail_tokens", "max_new"):
        _req(isinstance(sp[key], int) and sp[key] > 0,
             f"shared_prefix.{key} is not a positive int")
    for key in ("prefill_flop_ratio", "device_page_ratio"):
        _req(_num(sp[key]) and 0 < sp[key] <= 1.0,
             f"shared_prefix.{key} is not a number in (0, 1]")
    for group, kind in (("prefill_tokens", "prefill_tokens"),
                        ("device_pages", "device_pages")):
        for mode in ("prefix_off", "prefix_on"):
            _req(isinstance(sp[group].get(mode), int)
                 and sp[group][mode] > 0,
                 f"shared_prefix.{kind}[{mode!r}] is not a "
                 "positive int")
    for key in ("shared_admits", "shared_pages", "cow_moves"):
        _req(isinstance(sp[key], int) and sp[key] > 0,
             f"shared_prefix.{key} is not a positive int "
             "(sharing measured nothing)")
    _req(sp["outputs_bit_identical"] is True,
         "shared_prefix outputs are not bit-identical to the control")
    _req(sp["off_inert"] is True,
         "shared_prefix off control was not inert")
    fd = sp["forced_divergence"]
    _req(isinstance(fd, dict)
         and isinstance(fd.get("cow_moves"), int) and fd["cow_moves"] > 0,
         "shared_prefix.forced_divergence.cow_moves is not a positive "
         "int (no COW under forced divergence)")
    _req(fd.get("outputs_bit_identical") is True,
         "shared_prefix forced-divergence outputs are not "
         "bit-identical to the control")
    # ISSUE-7: the recovery group must record the MTTR sweep over
    # snapshot intervals, and every sweep point must prove it measured
    # a real recovery (records replayed + requests requeued; MTTR can
    # never be smaller than its recover_s component)
    rec = doc["recovery"]
    for key in ("channels", "seed", "crash_at", "snapshot_sweep",
                "mttr_s"):
        _req(key in rec, f"recovery missing {key!r}")
    _req(isinstance(rec["channels"], int) and rec["channels"] > 0,
         "recovery.channels is not a positive int")
    _req(isinstance(rec["crash_at"], int) and rec["crash_at"] >= 0,
         "recovery.crash_at is not a non-negative int")
    sweep = rec["snapshot_sweep"]
    _req(isinstance(sweep, dict) and sweep,
         "recovery.snapshot_sweep is not a non-empty object")
    for name, r in sweep.items():
        for key in ("snapshot_every", "mttr_s", "recover_s",
                    "replayed_records", "snapshot_seq", "last_seq",
                    "torn", "oob_scan", "requeued"):
            _req(isinstance(r, dict) and key in r,
                 f"recovery.snapshot_sweep[{name!r}] missing {key!r}")
        _req(isinstance(r["snapshot_every"], int)
             and r["snapshot_every"] > 0,
             f"recovery.snapshot_sweep[{name!r}].snapshot_every "
             "is not a positive int")
        for key in ("mttr_s", "recover_s"):
            _req(_num(r[key]) and r[key] > 0,
                 f"recovery.snapshot_sweep[{name!r}].{key} "
                 "is not a positive number")
        _req(r["mttr_s"] >= r["recover_s"],
             f"recovery.snapshot_sweep[{name!r}]: mttr_s < recover_s")
        for key in ("replayed_records", "snapshot_seq", "last_seq",
                    "requeued"):
            _req(isinstance(r[key], int) and r[key] >= 0,
                 f"recovery.snapshot_sweep[{name!r}].{key} "
                 "is not a non-negative int")
        _req(isinstance(r["torn"], bool)
             and isinstance(r["oob_scan"], bool),
             f"recovery.snapshot_sweep[{name!r}] torn/oob_scan "
             "are not bools")
        _req(r["replayed_records"] > 0,
             f"recovery.snapshot_sweep[{name!r}] replayed no records "
             "(recovery measured nothing)")
        _req(r["requeued"] > 0,
             f"recovery.snapshot_sweep[{name!r}] requeued no "
             "in-flight requests (crash point hit an idle engine)")
        _req(_num(rec["mttr_s"].get(name)),
             f"recovery.mttr_s missing {name!r}")


def history_line(doc: dict) -> dict:
    """One compact trajectory record for BENCH_history.jsonl."""
    return {
        "sha": os.environ.get("GITHUB_SHA", "local"),
        "steps_per_sec": doc["steps_per_sec"],
        "speedups": doc["speedups"],
        "channel_speedup_n8_vs_n1":
            doc["channel_scaling"]["speedup_n8_vs_n1"],
        "channel_cpu_bound": doc["channel_scaling"]["cpu_bound"],
        "oversub_tokens_per_sec": doc["oversubscription"]["tokens_per_sec"],
        "oversub_fallbacks": {
            mode: counters["macro_fallbacks"]
            for mode, counters in doc["oversubscription"]["modes"].items()
        },
        "degraded_retention":
            doc["fault_injection"]["retention_degraded_vs_healthy"],
        "gc_retention": doc["gc"]["retention_gc_on_vs_off"],
        "write_amp": {mode: counters["write_amp"]
                      for mode, counters in doc["gc"]["modes"].items()},
        "gc_moves": doc["gc"]["modes"]["gc_on"]["gc_moves"],
        "prefix_flop_ratio": doc["shared_prefix"]["prefill_flop_ratio"],
        "prefix_page_ratio": doc["shared_prefix"]["device_page_ratio"],
        "prefix_cow_moves": doc["shared_prefix"]["cow_moves"],
        "recovery_mttr_s": doc["recovery"]["mttr_s"],
        "recovery_replayed": {
            name: r["replayed_records"]
            for name, r in doc["recovery"]["snapshot_sweep"].items()
        },
    }


def main(argv) -> int:
    if not argv or argv[0].startswith("-"):
        print("usage: check_bench_json.py BENCH_serve.json "
              "[--append-history FILE]", file=sys.stderr)
        return 2
    path = argv[0]
    hist = None
    if "--append-history" in argv:
        hist = argv[argv.index("--append-history") + 1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        print(f"FAIL: {path} missing or unreadable: {e}", file=sys.stderr)
        return 1
    except ValueError as e:
        print(f"FAIL: {path} is not valid JSON: {e}", file=sys.stderr)
        return 1
    try:
        check(doc)
    except SchemaError as e:
        print(f"FAIL: {path} malformed: {e}", file=sys.stderr)
        return 1
    print(f"OK: {path} conforms "
          f"({len(doc['steps_per_sec'])} modes, "
          f"{len(doc['speedups'])} speedups)")
    if hist:
        with open(hist, "a") as f:
            json.dump(history_line(doc), f, separators=(",", ":"))
            f.write("\n")
        print(f"OK: appended trajectory line to {hist}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
