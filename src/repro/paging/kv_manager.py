"""KV page manager: the FMMU integrated as the serving page-table engine.

Logical address: DLPN = slot * max_pages + logical_page (slot = batch
slot of a live sequence). Physical: tier-tagged block id in the KV pool.
The mapping lives in the batched FMMU (core/fmmu/batch): lookups build
the block tables consumed by the paged-attention kernels; updates back
new allocations; CondUpdate arbitrates swap/relocation races exactly as
the paper's GC path does (a relocation only commits if the mapping still
points at the old block).

Every map operation funnels through ONE fused entry point
(``_xlate`` -> ``translate_batch``): a single CMT probe and a single
insert pass per call, mirroring the paper's arbiter that multiplexes
all request sources through one shared pipeline. All jitted closures
donate the FMMU state pytree, so steady-state serving performs zero
state copies.

Data movement between tiers operates on the pool tensors via jitted
gather/scatter (device<->host offload copies on real hardware).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fmmu import batch as fb
from repro.core.fmmu.types import (COND_UPDATE, FMMUGeometry, NIL, UPDATE)
from repro.paging.pool import HOST_BASE, BlockPool, OutOfBlocks


def _move_rows(pool, src, dst, axis: int):
    """pool[..., dst, ...] = pool[..., src, ...] along `axis`."""
    taken = jnp.take(pool, src, axis=axis)
    pm = jnp.moveaxis(pool, axis, 0)
    pm = pm.at[dst].set(jnp.moveaxis(taken, axis, 0))
    return jnp.moveaxis(pm, 0, axis)


def _geometry(n_slots: int, max_pages: int) -> FMMUGeometry:
    n_dlpns = n_slots * max_pages
    ept = max(64, min(4096, max_pages))
    return FMMUGeometry(
        cmt_sets=max(8, min(512, n_dlpns // 64)),
        cmt_ways=4,
        cmt_entries=8,
        ctp_sets=8, ctp_ways=4,
        entries_per_tp=ept,
        n_tvpns=-(-n_dlpns // ept),
        queue_cap=64,
    )


class KVPageManager:
    """Host-driven control plane; device-resident map + pools."""

    def __init__(self, n_slots: int, max_pages: int, n_device_blocks: int,
                 n_host_blocks: int = 0):
        self.n_slots = n_slots
        self.max_pages = max_pages
        self.geom = _geometry(n_slots, max_pages)
        self.fns = fb.make_jitted(self.geom)
        self.state = fb.init_batch_state(self.geom)
        self.pool = BlockPool(n_device_blocks, n_host_blocks)
        self.seq_pages: Dict[int, List[int]] = {}   # slot -> block ids
        self._table_fn = jax.jit(functools.partial(self._tables, self.geom),
                                 static_argnums=(1, 2),
                                 donate_argnums=(0,))

    # ----------------------------------------------------------- helpers
    def _dlpns(self, slot: int, pages: range) -> np.ndarray:
        return np.asarray([slot * self.max_pages + p for p in pages],
                          np.int32)

    def _xlate(self, kind: int, dlpns, dppns, olds=None):
        """Single fused map entry: one translate_batch call (one probe,
        one insert) services the whole op batch; state is donated and
        rebound."""
        dl = jnp.asarray(dlpns, jnp.int32)
        opc = jnp.full(dl.shape, kind, jnp.int32)
        dp = jnp.asarray(dppns, jnp.int32)
        od = (jnp.zeros(dl.shape, jnp.int32) if olds is None
              else jnp.asarray(olds, jnp.int32))
        self.state, out, ok = self.fns["translate"](self.state, opc, dl,
                                                    dp, od)
        return out, ok

    @staticmethod
    def _tables(geom, state, n_slots, max_pages):
        """Translate every (slot, page) through the FMMU -> block table."""
        dl = jnp.arange(n_slots * max_pages, dtype=jnp.int32)
        state, out = fb.lookup_batch(geom, state, dl)
        return state, out.reshape(n_slots, max_pages)

    # ----------------------------------------------------------- API
    def new_seq(self, slot: int, n_pages: int) -> List[int]:
        assert slot not in self.seq_pages, f"slot {slot} busy"
        blocks = self.pool.alloc(n_pages)
        dl = self._dlpns(slot, range(n_pages))
        self._xlate(UPDATE, dl, blocks)
        self.seq_pages[slot] = list(blocks)
        return blocks

    def extend_seq(self, slot: int, n_new: int) -> List[int]:
        cur = self.seq_pages[slot]
        blocks = self.pool.alloc(n_new)
        dl = self._dlpns(slot, range(len(cur), len(cur) + n_new))
        self._xlate(UPDATE, dl, blocks)
        cur.extend(blocks)
        return blocks

    def free_seq(self, slot: int):
        blocks = self.seq_pages.pop(slot)
        dl = self._dlpns(slot, range(len(blocks)))
        self._xlate(UPDATE, dl, np.full(len(blocks), NIL, np.int32))
        self.pool.free(blocks)

    def block_tables(self) -> jnp.ndarray:
        """[n_slots, max_pages] int32; NIL for unmapped; host-tier blocks
        appear tagged (callers must swap in before attention)."""
        self.state, tables = self._table_fn(self.state, self.n_slots,
                                            self.max_pages)
        return tables

    # ----------------------------------------------------------- swapping
    def swap_out(self, slot: int, pools: List[jnp.ndarray],
                 block_axis: int = 0) -> Tuple[List[jnp.ndarray], int]:
        """Relocate all device blocks of `slot` to the host tier.
        pools: list of [NB_dev(+host), ...] tensors (k & v per layer
        group); host region lives at [n_device:]. Returns updated pools
        and the number of relocated blocks. CondUpdate guards each move."""
        blocks = self.seq_pages[slot]
        dev = [b for b in blocks if not BlockPool.is_host(b)]
        if not dev:
            return pools, 0
        host = self.pool.alloc(len(dev), host=True)
        dl = []
        for i, b in enumerate(blocks):
            if not BlockPool.is_host(b):
                dl.append(slot * self.max_pages + i)
        _, ok = self._xlate(COND_UPDATE, dl, host, dev)
        okh = np.asarray(ok)
        assert okh.all(), "swap_out raced with a concurrent relocation"
        # move data: host block h stored at row n_device + (h - HOST_BASE)
        src = jnp.asarray(dev, jnp.int32)
        dst = jnp.asarray([self.pool.n_device + (h - HOST_BASE)
                           for h in host], jnp.int32)
        pools = [_move_rows(p, src, dst, block_axis) for p in pools]
        self.pool.free(dev)
        self.seq_pages[slot] = [
            host[dev.index(b)] if b in dev else b for b in blocks]
        self.pool.stats.swaps_out += len(dev)
        return pools, len(dev)

    def swap_in(self, slot: int, pools: List[jnp.ndarray],
                block_axis: int = 0) -> Tuple[List[jnp.ndarray], int]:
        """Bring a swapped-out sequence back to device blocks."""
        blocks = self.seq_pages[slot]
        hostb = [b for b in blocks if BlockPool.is_host(b)]
        if not hostb:
            return pools, 0
        dev = self.pool.alloc(len(hostb))
        dl = [slot * self.max_pages + i for i, b in enumerate(blocks)
              if BlockPool.is_host(b)]
        _, ok = self._xlate(COND_UPDATE, dl, dev, hostb)
        assert np.asarray(ok).all()
        src = jnp.asarray([self.pool.n_device + (h - HOST_BASE)
                           for h in hostb], jnp.int32)
        dst = jnp.asarray(dev, jnp.int32)
        pools = [_move_rows(p, src, dst, block_axis) for p in pools]
        self.pool.free(hostb)
        self.seq_pages[slot] = [
            dev[hostb.index(b)] if b in hostb else b for b in blocks]
        self.pool.stats.swaps_in += len(hostb)
        return pools, len(hostb)

    def hit_stats(self) -> dict:
        s = np.asarray(self.state.stats)
        return {"hits": int(s[0]), "misses": int(s[1]),
                "fills": int(s[2]), "updates": int(s[3])}
