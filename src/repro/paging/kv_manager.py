"""KV page manager: the FMMU integrated as the serving page-table engine.

Logical address: DLPN = slot * max_pages + logical_page (slot = batch
slot of a live sequence). Physical: tier-tagged block id in the KV pool.
The mapping lives in the batched FMMU (core/fmmu/batch): lookups build
the block tables consumed by the paged-attention kernels; updates back
new allocations; CondUpdate arbitrates swap/relocation races exactly as
the paper's GC path does (a relocation only commits if the mapping still
points at the old block).

Every map operation funnels through ONE fused entry point
(``_xlate`` -> ``translate_serving`` -> ``translate_batch``): a single
CMT probe and a single insert pass per call, mirroring the paper's
arbiter that multiplexes all request sources through one shared
pipeline. All jitted closures donate the FMMU state pytree, so
steady-state serving performs zero state copies.

The block table is a **device-resident member of the state pytree**,
maintained incrementally by the same fused call that commits each map
write (DESIGN.md "Device-resident incremental block table"):
``block_tables()`` is a zero-cost accessor — no translation, no state
mutation — and steady-state decode performs zero full-map
retranslations. The from-scratch path survives as
``retranslate_tables()`` (test oracle / legacy benchmark baseline
only). NOTE: because the state pytree is donated, arrays returned by
``block_tables()`` are invalidated by the next map op — re-fetch
instead of holding them across ``new_seq``/``extend``/``free``/swaps.

Data movement between tiers is ONE donated jitted call per swap
(``_swap``): the CondUpdate map commits ride the single-probe fused
translate, the pool rows move by gather/scatter, and the
``ServingMapState.swap_pending`` residency lane flips — state and both
KV pools are donated, so a swap mutates in place and the host never
blocks on it (the guard-mask readback is opt-in via ``check=True``;
the serving scheduler leaves it off and lets the equivalence tests own
correctness). Swap lane counts are padded to the next power of two so
the jit re-traces O(log max_pages) times, not once per distinct swap
size. DESIGN.md "Non-blocking host-tier swap pipeline".

ISSUE-5 channel sharding: ``channels=N`` partitions the whole map
state by the static hash ``channel(dlpn) = dlpn mod N`` — each channel
holds a complete 1/N-sized ServingMapState shard (CMT, backing, table
slice, the free stacks of the blocks it owns: block ``b`` belongs to
channel ``b mod N``) stacked on a leading [C] pytree axis, and every
fused entry above runs as ONE sharded translate (shard_map over a
'channel' mesh when >= C devices are visible, else a bit-identical
jax.vmap). The pool free lists stripe per channel the same way
(``BlockPool(n_channels=N)``), macro-scan growth is pre-committed at
the boundary (``precommit_growth``) so the scan needs no in-graph
allocator, and ``block_tables()`` interleaves the shards back to
global order (the boundary all-gather). DESIGN.md "Channel-sharded
map pipeline". ``channels=1`` (default) bypasses every sharded branch.

ISSUE-3 allocator mirror: the FMMU serving state carries a
device-resident free-list allocator (decode macro-steps allocate KV
blocks without leaving the jit). The host ``BlockPool`` stays
authoritative at macro-step boundaries: host-side mutations mark the
device stacks dirty (lazily re-pushed by ``sync_allocator``), and
device-side pops are replayed onto the pool by ``reconcile_macro`` —
both sides apply identical deltas in identical order, so steady-state
decode needs zero sync pushes (DESIGN.md "Device-resident block
allocator + K-step fused decode macro-steps").
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as flt
from repro.core import journal as jl
from repro.core.counters import COUNTERS
from repro.core.fmmu import batch as fb
from repro.core.fmmu.types import (COND_UPDATE, FMMUGeometry, LOOKUP,
                                   NIL, SWAP_IN, SWAP_OUT, UPDATE)
from repro.paging.pool import (HOST_BASE, BlockPool, OutOfBlocks,
                               PoolExhausted)

# Host-level call counters (the PROBE_TRACES pattern, at op granularity):
# bumped once per *invocation*, so tests can assert that a steady-state
# decode step performs zero full-map retranslations and at most one
# fused map call — and that a steady-state MACRO step performs zero of
# either plus zero allocator re-syncs. The names alias registry cells
# (core/counters.py): same list objects, also visible to
# COUNTERS.snapshot()/delta().
XLATE_CALLS = COUNTERS.cell("kvm.xlate_calls")
FULL_TABLE_CALLS = COUNTERS.cell("kvm.full_table_calls")
ALLOC_SYNCS = COUNTERS.cell("kvm.alloc_syncs")

def _ji(xs) -> List[int]:
    """Journal payloads are JSON: plain ints, not numpy scalars."""
    return [int(x) for x in xs]


@dataclasses.dataclass
class MapStats:
    """Typed ``KVPageManager.hit_stats()`` result (ISSUE 9): every
    historical dict key is a field, ``__getitem__`` keeps the legacy
    ``stats["hits"]`` call sites working verbatim, and ``as_dict()``
    feeds the bench schema. New GC/CTP axes: ``gc_moves`` (live pages
    relocated by the victim walk), ``victims_ch`` (erase blocks fully
    reclaimed, per channel), ``prefetch_hits``/``prefetch_misses`` (CTP
    probes that found the map segment already cached vs. pulled it —
    a prefetch MISS is the useful case), and the write-amplification
    axis: ``host_writes`` (fresh page programs commanded by the host:
    admission, decode growth, macro pre-commits), ``flash_programs``
    (host writes + swap-ins + GC relocations — every device-tier
    program), ``write_amp`` = flash_programs / host_writes (>= 1.0
    whenever anything was written)."""
    hits: int = 0
    misses: int = 0
    fills: int = 0
    updates: int = 0
    swaps_out: int = 0
    swaps_in: int = 0
    host_resident_slots: int = 0
    retired_blocks: int = 0
    retired_ch: List[int] = dataclasses.field(default_factory=list)
    pool_exhausted: List[int] = dataclasses.field(default_factory=list)
    swap_faults: int = 0
    program_faults: int = 0
    alloc_faults: int = 0
    gc_moves: int = 0
    victims_ch: List[int] = dataclasses.field(default_factory=list)
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    host_writes: int = 0
    flash_programs: int = 0
    write_amp: float = 1.0
    shared_maps: int = 0
    cow_moves: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __getitem__(self, key: str):
        if not any(f.name == key for f in dataclasses.fields(self)):
            raise KeyError(key)
        return getattr(self, key)

    def __contains__(self, key: str) -> bool:
        return any(f.name == key for f in dataclasses.fields(self))


# bad-block re-drive bound: a retirement chain retires at most this
# many consecutive schedule-failed replacement candidates before the
# last candidate is kept regardless (bounded recovery — no infinite
# retirement cascade can stall a boundary)
_MAX_REDRIVE = 4


def _move_rows(pool, src, dst, axis: int):
    """pool[..., dst, ...] = pool[..., src, ...] along `axis`."""
    taken = jnp.take(pool, src, axis=axis)
    pm = jnp.moveaxis(pool, axis, 0)
    pm = pm.at[dst].set(jnp.moveaxis(taken, axis, 0))
    return jnp.moveaxis(pm, 0, axis)


def _geometry(n_slots: int, max_pages: int,
              channels: int = 1) -> FMMUGeometry:
    """Map geometry sized for one channel's shard: with C channels each
    shard owns ceil(n_dlpns / C) logical pages, so its CMT and backing
    table are 1/C-sized — the paper's per-channel FMMU partitioning
    (translate work per channel scales as 1/N)."""
    n_dlpns = -(-n_slots * max_pages // channels)
    ept = max(64, min(4096, max_pages))
    return FMMUGeometry(
        cmt_sets=max(8, min(512, n_dlpns // 64)),
        cmt_ways=4,
        cmt_entries=8,
        ctp_sets=8, ctp_ways=4,
        entries_per_tp=ept,
        n_tvpns=-(-n_dlpns // ept),
        queue_cap=64,
    )


class KVPageManager:
    """Host-driven control plane; device-resident map + pools."""

    def __init__(self, n_slots: int, max_pages: int, n_device_blocks: int,
                 n_host_blocks: int = 0, channels: int = 1,
                 use_mesh: Optional[bool] = None,
                 faults: Optional["flt.FaultPlane"] = None,
                 track_live: bool = False,
                 track_refs: bool = False):
        self.n_slots = n_slots
        self.max_pages = max_pages
        self._n_dev = n_device_blocks
        self._n_host = n_host_blocks
        self.channels = C = int(channels)
        # GC live-page tracking (ISSUE 9): when enabled the map state
        # carries the optional ``live`` lane (maintained inside every
        # fused commit — core/fmmu/batch.translate_serving). Off by
        # default: the lane is a None pytree leaf and every traced
        # graph stays jaxpr-identical to the pre-GC path.
        self.track_live = bool(track_live)
        # Prefix-sharing refcount tracking (ISSUE 10): same optional-
        # leaf discipline as the live lane — off by default, and when
        # armed the ``refcnt`` lane rides the identical fused commits.
        # With C > 1, sharing requires max_pages % C == 0 so that the
        # SAME page index of different slots stripes to the same
        # channel (dlpn = slot*max_pages + page, channel = dlpn mod C):
        # a shared block and every dlpn mapping it then live in one
        # channel, preserving the pool/alloc channel invariant.
        self.track_refs = bool(track_refs)
        if self.track_refs and C > 1:
            assert max_pages % C == 0, \
                (f"prefix sharing with {C} channels needs "
                 f"max_pages % channels == 0 (got {max_pages})")
        self.geom = _geometry(n_slots, max_pages, C)
        self.fns = fb.make_jitted(self.geom)
        # fault-injection plane (ISSUE 6, core/faults.py): consulted at
        # host commit points only — swap dispatch (_swap), pool
        # allocation (_alloc_blocks), and fresh-block program commits
        # (new_seq / extend_seqs / precommit_growth). None (default)
        # costs nothing and, because the plane never enters a traced
        # graph, attaching one cannot change any jaxpr either.
        self.faults = faults
        # crash-consistency journal (ISSUE 7, core/journal.py): when
        # attached (ServeEngine.attach_journal), every host commit
        # point above appends a sequence-numbered record AFTER its op
        # succeeds — the same ``is not None`` host-only discipline as
        # the fault plane, so journaling-disabled stays jaxpr-identical
        self.journal: Optional["jl.Journal"] = None
        # ISSUE-5 channel sharding: with channels > 1 the map state is C
        # per-channel ServingMapState shards stacked on a leading axis
        # (each shard: 1/C-sized CMT + backing + table slice + the free
        # stacks of the blocks its channel owns). Requests route by the
        # static hash owner(dlpn) = dlpn mod C; every fused map call
        # goes through ONE sharded translate (each channel keeps the
        # single-probe/single-sort contract locally). The lowering is
        # shard_map over a 'channel' mesh axis when the process has >= C
        # devices (use_mesh=None auto-detects; CI's tier1-sharded lane
        # forces 8 host devices), else jax.vmap — both bit-identical.
        self.mesh = None
        if C > 1:
            if use_mesh is None:
                use_mesh = len(jax.devices()) >= C
            if use_mesh:
                from jax.sharding import PartitionSpec as P

                from repro.parallel.sharding import channel_mesh, shard_map
                self.mesh = channel_mesh(C)
                self._xlate_graph = shard_map(
                    fb.make_sharded_shard_body(self.geom, C),
                    mesh=self.mesh,
                    in_specs=(P("channel"), P(), P(), P(), P()),
                    out_specs=(P("channel"), P(), P()))
            else:
                self._xlate_graph = functools.partial(
                    fb.translate_sharded, self.geom, C)
            self._serve_sharded = jax.jit(self._xlate_graph,
                                          donate_argnums=(0,))
            # per-channel routed-lane counters: the 1/N-translate-work
            # claim is asserted from these, not inferred from timings
            self.channel_lanes = np.zeros(C, np.int64)
        else:
            self.channel_lanes = np.zeros(1, np.int64)
        self.state = self._fresh_state()
        self.pool = BlockPool(n_device_blocks, n_host_blocks,
                              n_channels=C)
        self.seq_pages: Dict[int, List[int]] = {}   # slot -> block ids
        # host-tier page count per slot, maintained by the swap ops so
        # the per-step residency predicate is O(1), not a page-list scan
        self._host_pages: Dict[int, int] = {}
        # device-allocator mirror protocol: the host BlockPool is
        # authoritative at macro-step boundaries; any host-side pool
        # mutation (admission alloc, free, swap) marks the device
        # stacks stale and sync_allocator() re-pushes them before the
        # next macro-step. Macro-step pops are reconciled the other way
        # (reconcile_macro replays them onto the pool) WITHOUT dirtying
        # — both sides applied the same delta, so the mirror holds and
        # steady-state decode needs zero sync pushes.
        self._alloc_dirty = False
        if C > 1:
            self._retrans_fn = jax.jit(
                functools.partial(self._retranslate_sharded, self.geom,
                                  C, n_slots, max_pages),
                donate_argnums=(0,))
            self._set_alloc = jax.jit(fb.set_allocator_sharded,
                                      donate_argnums=(0,))
        else:
            self._retrans_fn = jax.jit(
                functools.partial(self._retranslate, self.geom),
                static_argnums=(1, 2), donate_argnums=(0,))
            self._set_alloc = jax.jit(fb.set_allocator,
                                      donate_argnums=(0,))
        # fused swap jits, cached per (padded lane count, block axis,
        # pool count): state + pools donated, re-traced O(log) times.
        # swap_pad (optional) pins a fixed lane count instead of the
        # next-pow2 policy: every swap then shares ONE compiled fn per
        # direction (pad moves are idempotent row copies), trading a
        # little extra gather/scatter width for zero mid-run
        # recompiles — latency-sensitive runs and benchmarks pin it
        self._swap_jits: Dict[Tuple[int, int, int], object] = {}
        self.swap_pad: Optional[int] = None
        # GC / CTP / write-amplification accounting (ISSUE 9): plain
        # host counters surfaced through hit_stats() as MapStats.
        self.gc_moves = 0
        self.victims_ch = [0] * C
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self._pf_seen: set = set()
        self.host_writes = 0
        # Prefix sharing (ISSUE 10): host-side radix-path index +
        # authoritative refcounts, mirroring the device refcnt lane the
        # way BlockPool mirrors the device allocator.
        #   _nodes   (depth, rolling-hash) -> (block, exact prefix) —
        #            the radix tree in path-key form: node at depth i
        #            holds the device block carrying page i-1's KV
        #            computed under that exact token prefix. Insertion
        #            order doubles as the pruning order (LRU-touched on
        #            match via move_to_end).
        #   _pinned  block -> node key: blocks the tree holds a
        #            reference on (a pin is NOT a mapping ref — the
        #            device lane counts dlpn->block mappings only).
        #   _ref     block -> number of dlpns mapping it; present for
        #            exactly the share-managed blocks (registered in
        #            the tree at some point and not yet reclaimed).
        #            Free rule everywhere: a share-managed block
        #            returns to the pool only at zero mapping refs AND
        #            no pin.
        #   _shared  slot -> {page -> block}: this slot's pages mapped
        #            at blocks it must not write in place — the COW
        #            trigger set read by cow_writes().
        self._nodes: "collections.OrderedDict[Tuple[int, int], Tuple[int, tuple]]" \
            = collections.OrderedDict()
        self._pinned: Dict[int, Tuple[int, int]] = {}
        self._ref: Dict[int, int] = {}
        self._shared: Dict[int, Dict[int, int]] = {}
        self.prefix_max_nodes = 4096
        self.shared_maps = 0
        self.cow_moves = 0

    # ----------------------------------------------------------- helpers
    def _fresh_state(self):
        """Build (or rebuild) the device-resident map state pytree —
        the ONE home of the init-and-shard logic, shared by __init__
        and ``reset``."""
        if self.channels > 1:
            st = fb.init_sharded_state(
                self.geom, self.channels, self._n_dev, self._n_host,
                n_lanes=self.n_slots, track_live=self.track_live,
                track_refs=self.track_refs)
            if self.mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P
                st = jax.device_put(
                    st, NamedSharding(self.mesh, P("channel")))
            return st
        return fb.init_serving_state(self.geom, self._n_dev,
                                     self._n_host, n_lanes=self.n_slots,
                                     track_live=self.track_live,
                                     track_refs=self.track_refs)

    def reset(self, faults: Optional["flt.FaultPlane"] = None):
        """Reinitialize map state, pool and bookkeeping while KEEPING
        every compiled closure (_swap_jits, the serve/retranslate/
        set-alloc jits): jitted bound methods trace per *instance*, so
        a fresh manager would recompile the world — the chaos harness
        (tests/chaos/) replays hundreds of fault schedules against ONE
        manager via this. Optionally installs a new fault plane."""
        self.state = self._fresh_state()
        self.pool = BlockPool(self._n_dev, self._n_host,
                              n_channels=self.channels)
        self.seq_pages = {}
        self._host_pages = {}
        self._alloc_dirty = False
        self.channel_lanes[:] = 0
        self.faults = faults
        self.journal = None    # the engine re-attaches after recovery
        self.gc_moves = 0
        self.victims_ch = [0] * self.channels
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self._pf_seen = set()
        self.host_writes = 0
        self._nodes = collections.OrderedDict()
        self._pinned = {}
        self._ref = {}
        self._shared = {}
        self.shared_maps = 0
        self.cow_moves = 0

    def _dlpns(self, slot: int, pages: range) -> np.ndarray:
        return np.asarray([slot * self.max_pages + p for p in pages],
                          np.int32)

    def _xlate(self, kind: int, dlpns, dppns, olds=None):
        """Single fused map entry: one translate call (one probe, one
        insert, incremental table scatter — PER CHANNEL when sharded)
        services the whole op batch; state is donated and rebound."""
        XLATE_CALLS[0] += 1
        # numpy in, jit transfers: cheaper than explicit device_puts
        dl = np.asarray(dlpns, np.int32)
        opc = np.full(dl.shape, kind, np.int32)
        dp = np.asarray(dppns, np.int32)
        od = (np.zeros(dl.shape, np.int32) if olds is None
              else np.asarray(olds, np.int32))
        if self.channels > 1:
            self.channel_lanes += np.bincount(
                dl[dl >= 0] % self.channels, minlength=self.channels)
            self.state, out, ok = self._serve_sharded(self.state, opc,
                                                      dl, dp, od)
        else:
            self.channel_lanes[0] += int((dl >= 0).sum())
            self.state, out, ok = self.fns["serve"](self.state, opc, dl,
                                                    dp, od)
        return out, ok

    def _alloc_blocks(self, dlpns, *, host: bool = False):
        """Pool allocation for a batch of dlpns: channel-agnostic pops
        at channels=1 (the legacy path, bit-identical), per-owner-
        channel pops otherwise — page and backing block always share a
        channel, so each channel's device stack mirror stays exact."""
        if self.faults is not None and len(dlpns) \
                and self.faults.alloc_fails():
            # injected transient exhaustion: raised BEFORE any pop, so
            # the caller's retry sees an untouched pool. transient=True
            # tells the engine's livelock guard this is not terminal.
            c = int(dlpns[0]) % self.channels
            self.pool.note_exhausted(c)
            raise PoolExhausted(
                f"injected transient {'host' if host else 'device'} "
                f"allocator exhaustion ({len(dlpns)} blocks)",
                channel=c, transient=True)
        if self.channels == 1:
            return self.pool.alloc(len(dlpns), host=host)
        return self.pool.alloc_for(
            [int(d) % self.channels for d in dlpns], host=host)

    @staticmethod
    def _retranslate(geom, fmmu, n_slots, max_pages):
        """Translate every (slot, page) through the FMMU -> block table."""
        dl = jnp.arange(n_slots * max_pages, dtype=jnp.int32)
        fmmu, out = fb.lookup_batch(geom, fmmu, dl)
        return fmmu, out.reshape(n_slots, max_pages)

    @staticmethod
    def _retranslate_sharded(geom, C, n_slots, max_pages, fmmu):
        """Sharded retranslation oracle: every channel looks up all of
        its local dlpns, and the per-channel results interleave back to
        the global order (global dlpn d = local l * C + channel c)."""

        def body(fm):
            L = geom.n_tvpns * geom.entries_per_tp
            return fb.lookup_batch(geom, fm,
                                   jnp.arange(L, dtype=jnp.int32))

        fmmu, outs = jax.vmap(body)(fmmu)
        flat = fb.interleave_table(outs, n_slots * max_pages)
        return fmmu, flat.reshape(n_slots, max_pages)

    # ----------------------------------------------------------- API
    def new_seq(self, slot: int, n_pages: int,
                shared: Optional[Sequence[int]] = None) -> List[int]:
        """Admit a sequence into `slot` with `n_pages` logical pages.

        ``shared`` (ISSUE 10) maps the LEADING len(shared) pages at the
        given already-resident blocks instead of allocating: the fused
        UPDATE commits those dlpns at the shared dppns (bumping the
        device refcnt lane), the host refcounts advance in mirror, and
        only the remaining pages allocate + program fresh blocks —
        shared pages cost zero flash programs and zero prefill. Callers
        obtain `shared` from ``match_prefix`` and MUST NOT write shared
        pages in place (``cow_writes`` relocates first). With shared
        empty/None this is byte-for-byte the historical admission path
        (same journal record, same pool order)."""
        assert slot not in self.seq_pages, f"slot {slot} busy"
        shared = list(shared or [])
        k = len(shared)
        assert k <= n_pages, (k, n_pages)
        assert k == 0 or self.track_refs, \
            "shared admission needs track_refs=True (the refcnt lane)"
        dl = self._dlpns(slot, range(n_pages))
        fresh = list(self._alloc_blocks(dl[k:])) if n_pages > k else []
        blocks = shared + fresh
        self._alloc_dirty = True
        self.host_writes += len(fresh)   # shared pages program nothing
        self._xlate(UPDATE, dl, blocks)
        self.seq_pages[slot] = list(blocks)
        if k:
            for b in shared:
                self._ref[b] = self._ref.get(b, 0) + 1
            self._shared[slot] = {i: b for i, b in enumerate(shared)}
            self.shared_maps += k
        if self.journal is not None:
            if k:
                # SHARE admission: the leading blocks are references to
                # blocks some other slot (or the tree) already owns —
                # replay re-takes only the fresh tail from the free
                # lists and counts the shared refs (core/journal._apply).
                # The OOB frame carries ALL lanes' owner pairs — the
                # shared ones as metadata-only entries (they program no
                # data) — so a torn record stays SPOR-recoverable: the
                # reverse-map scan would otherwise see a page hole
                # below the first fresh page.
                self.journal.append(
                    jl.SHARE, {"slot": int(slot), "dl": _ji(dl),
                               "blocks": _ji(blocks), "n_shared": k,
                               "lanes": len(dl)},
                    programmed=zip(dl, blocks))
            else:
                self.journal.append(
                    jl.NEW_SEQ, {"slot": int(slot), "dl": _ji(dl),
                                 "blocks": _ji(blocks)},
                    programmed=zip(dl, blocks))
        # program-fault check AFTER the map commit, BEFORE any data is
        # written (prefill follows admission): a bad block here needs
        # only the CondUpdate re-drive, no row copy. Shared pages hold
        # long-since-verified data — only fresh programs consult the
        # plane.
        self._maybe_retire_programs(dl[k:], fresh)
        return list(self.seq_pages[slot])

    def extend_seq(self, slot: int, n_new: int) -> List[int]:
        return self.extend_seqs({slot: n_new}).get(slot, [])

    def extend_seqs(self, wants: Dict[int, int]) -> Dict[int, List[int]]:
        """Grow several sequences at once: ONE pool allocation and ONE
        fused map call for the whole step (the decode hot path). Raises
        OutOfBlocks before any state changes if the pool can't cover
        the full batch."""
        wants = {s: n for s, n in wants.items() if n > 0}
        if not wants:
            return {}
        dl: List[int] = []
        for slot, n in wants.items():           # validate BEFORE alloc:
            have = len(self.seq_pages[slot])    # KeyError leaks nothing
            dl.extend(slot * self.max_pages + p
                      for p in range(have, have + n))
        blocks = self._alloc_blocks(dl)
        self._alloc_dirty = True
        self.host_writes += len(blocks)
        got: Dict[int, List[int]] = {}
        i = 0
        for slot, n in wants.items():
            got[slot] = blocks[i:i + n]
            i += n
            self.seq_pages[slot].extend(got[slot])
        self._xlate(UPDATE, dl, blocks)
        if self.journal is not None:
            self.journal.append(
                jl.EXTEND, {"dl": _ji(dl), "blocks": _ji(blocks)},
                programmed=zip(dl, blocks))
        # growth blocks are programmed by the decode step that follows;
        # a schedule-failed program re-drives map-only (no data yet)
        if self._maybe_retire_programs(dl, blocks):
            got = {s: self.seq_pages[s][-n:] for s, n in wants.items()}
        return got

    def free_seq(self, slot: int):
        blocks = self.seq_pages.pop(slot)
        self._host_pages.pop(slot, None)
        self._shared.pop(slot, None)
        dl = self._dlpns(slot, range(len(blocks)))
        self._xlate(UPDATE, dl, np.full(len(blocks), NIL, np.int32))
        if self._ref:
            # refcount gate (ISSUE 10): share-managed blocks return to
            # the pool only at zero mapping refs and no tree pin —
            # per-block in lane order, so the free-list order matches
            # the unshared bulk free (and journal replay) exactly
            for b in blocks:
                self._unref(b)
        else:
            self.pool.free(blocks)
        # The CTP frontier filter assumes growth dlpns advance
        # monotonically — true within one sequence's life, false across
        # slot reuse: the next occupant re-grows through the SAME dlpn
        # range, and a key left in _pf_seen would silently skip its
        # segment fetches forever. Drop the freed slot's keys so a
        # reused slot re-prefetches. (When max_pages is not a multiple
        # of cmt_entries a segment can straddle two slots, so this may
        # also drop a neighbour's still-warm key — harmless: the set is
        # a hint, and the re-probe lands as a redundant hit.)
        ent = self.geom.cmt_entries
        C = self.channels
        for d in dl.tolist():
            self._pf_seen.discard((d % C, (d // C) // ent) if C > 1
                                  else (0, d // ent))
        self._alloc_dirty = True
        if self.journal is not None:
            # no OOB frame: a free programs nothing — a torn tail just
            # drops it cleanly (pages stay mapped until re-freed)
            self.journal.append(jl.FREE,
                                {"slot": int(slot), "blocks": _ji(blocks),
                                 "lanes": len(blocks)})

    def is_resident(self, slot: int) -> bool:
        """True when no page of `slot` lives in the host tier. One
        source of truth for the tier predicate: BlockPool.is_host
        (counted into _host_pages by the swap ops; alloc paths only
        ever add device blocks)."""
        return self._host_pages.get(slot, 0) == 0

    def n_device_pages(self, slot: int) -> int:
        """Device-tier pages held by `slot` (preemption victims must
        have at least one, or swapping them out moves nothing)."""
        return (len(self.seq_pages.get(slot, ()))
                - self._host_pages.get(slot, 0))

    def n_host_pages(self, slot: int) -> int:
        """Host-tier pages held by `slot`, O(1) (swap-maintained
        count). The serving scheduler's cost term is the per-channel
        ``host_pages_vec``; this total remains for host-side
        bookkeeping and diagnostics."""
        return self._host_pages.get(slot, 0)

    def block_tables(self) -> jnp.ndarray:
        """[n_slots, max_pages] int32 device view of the incremental
        table — zero-cost: no translation, no state mutation. NIL for
        unmapped; host-tier blocks appear tagged (callers must swap in
        before attention). With channels > 1 the per-channel shards
        interleave back to the global order (the boundary all-gather;
        a relayout, still no translation). The view is invalidated by
        the next map op (donated state); re-fetch, don't hold."""
        n = self.n_slots * self.max_pages    # table is geometry-padded
        return fb.dense_table(self.state, self.channels, n).reshape(
            self.n_slots, self.max_pages)

    def retranslate_tables(self) -> jnp.ndarray:
        """From-scratch full-map retranslation (the pre-incremental
        path): every DLPN through ``lookup_batch``. Kept ONLY as the
        churn-equivalence test oracle and the legacy serving-bench
        baseline; the serving hot path must use ``block_tables()``."""
        FULL_TABLE_CALLS[0] += 1
        if self.channels > 1:
            fmmu, tables = self._retrans_fn(self.state.fmmu)
        else:
            fmmu, tables = self._retrans_fn(self.state.fmmu,
                                            self.n_slots, self.max_pages)
        self.state = self.state._replace(fmmu=fmmu)
        return tables

    # ------------------------------------------- device allocator mirror
    def sync_allocator(self):
        """Re-push the host free lists into the device allocator stacks
        (and clear the OutOfBlocks flag). No-op unless a host-side pool
        mutation happened since the last sync — steady-state macro-step
        decode performs ZERO of these (ALLOC_SYNCS-counted)."""
        if not self._alloc_dirty:
            return
        ALLOC_SYNCS[0] += 1
        if self.channels > 1:
            # the re-push clears the sticky per-channel oob flag lane;
            # fold any set flags into the typed exhaustion counts FIRST
            # — the C>1 engine otherwise never reads the lane (the
            # ISSUE-6 "silent case"; the C=1 macro boundary passes its
            # already-synced flag to observe_exhaustion instead)
            self.observe_exhaustion()
        # refresh the residency lane in the same call: host-side frees
        # of swapped-out slots leave swap_pending stale until here, and
        # every such free also dirtied the pool
        resid = np.zeros(self.n_slots, bool)
        for s, c in self._host_pages.items():
            resid[s] = c > 0
        if self.channels > 1:
            C = self.channels
            dev = np.full(self.state.free_stack.shape, NIL, np.int32)
            host = np.full(self.state.host_stack.shape, NIL, np.int32)
            for c in range(C):
                dev[c, :self.pool.free_device_ch(c)] = \
                    self.pool._free_dev_ch[c]
                host[c, :self.pool.free_host_ch(c)] = \
                    self.pool._free_host_ch[c]
            self.state = self._set_alloc(
                self.state, dev,
                np.asarray([self.pool.free_device_ch(c)
                            for c in range(C)], np.int32),
                host,
                np.asarray([self.pool.free_host_ch(c)
                            for c in range(C)], np.int32), resid)
        else:
            dev = np.full(self.pool.n_device, NIL, np.int32)
            dev[:len(self.pool._free_dev)] = self.pool._free_dev
            host = np.full(self.pool.n_host, NIL, np.int32)
            host[:len(self.pool._free_host)] = self.pool._free_host
            self.state = self._set_alloc(
                self.state, dev, np.int32(len(self.pool._free_dev)),
                host, np.int32(len(self.pool._free_host)), resid)
        self._alloc_dirty = False

    def reconcile_macro(self, grow_seq: List[int]) -> Dict[int, List[int]]:
        """Replay a macro-step's device-side allocations onto the host
        pool and page lists. grow_seq is the slot sequence that popped
        blocks, in device pop order (step-major, slot-ascending within
        a step). Because the host stack is an exact mirror, popping the
        host free list in the same order yields the identical block
        ids — the device never has to ship an allocation log. The pool
        is NOT marked dirty: both sides applied the same delta, so the
        mirror still holds. Returns {slot: [new blocks]} in page
        order."""
        # the channel-sharded macro path never runs this replay: its
        # scans pop nothing device-side (growth is pre-committed by
        # precommit_growth), so replaying here would shrink the host
        # lists while the device stacks stand still — mirror broken
        assert self.channels == 1, \
            "reconcile_macro is the channels=1 replay; sharded macro " \
            "steps pre-commit growth via precommit_growth instead"
        got: Dict[int, List[int]] = {}
        if not grow_seq:
            return got
        blocks = self.pool.alloc(len(grow_seq))
        self.host_writes += len(blocks)
        dl: List[int] = []
        for slot, b in zip(grow_seq, blocks):
            self.seq_pages[slot].append(b)
            dl.append(slot * self.max_pages
                      + len(self.seq_pages[slot]) - 1)
            got.setdefault(slot, []).append(b)
        if self.journal is not None:
            # the scan already committed these lanes in-graph; this
            # record is their durability point (the macro boundary is
            # the commit point the crash axis can land on)
            self.journal.append(
                jl.RECONCILE, {"grow_seq": _ji(grow_seq), "dl": _ji(dl),
                               "blocks": _ji(blocks)},
                programmed=zip(dl, blocks))
        return got

    def _grow_dlpns(self, grow_seq: List[int]) -> List[int]:
        """Growth dlpns for a pop sequence: each entry is the slot's
        next unmapped page at that point in the sequence."""
        pages = {s: len(self.seq_pages[s]) for s in set(grow_seq)}
        dl = []
        for s in grow_seq:
            dl.append(s * self.max_pages + pages[s])
            pages[s] += 1
        return dl

    def precommit_growth(self, grow_seq: List[int],
                         dlpns: Optional[List[int]] = None
                         ) -> Dict[int, List[int]]:
        """Channel-sharded macro-step growth: commit a whole K-step
        growth schedule AHEAD of the scan — one channel-aware pool
        allocation in the scan's pop order (step-major, slot-ascending,
        identical to what K single steps would pop) plus ONE fused
        sharded map dispatch. The scan then decodes against the
        materialized post-growth table and needs no in-graph allocator
        at all: the cross-channel traffic stays at the macro boundary
        (DESIGN.md "Channel-sharded map pipeline").

        ``dlpns`` (aligned with grow_seq) is the dl schedule the
        caller's growth walk already produced — pass it so there is
        ONE derivation of which page each pop maps (the engine's
        ``_growth_walk``); when omitted, the schedule is re-derived
        from the page lists (test drivers)."""
        got: Dict[int, List[int]] = {}
        if not grow_seq:
            return got
        dl = (list(dlpns) if dlpns is not None
              else self._grow_dlpns(grow_seq))
        assert len(dl) == len(grow_seq)
        blocks = self._alloc_blocks(dl)
        self._alloc_dirty = True
        self.host_writes += len(blocks)
        counts: Dict[int, int] = {}
        for slot, b in zip(grow_seq, blocks):
            self.seq_pages[slot].append(b)
            got.setdefault(slot, []).append(b)
            counts[slot] = counts.get(slot, 0) + 1
        self._xlate(UPDATE, dl, blocks)
        if self.journal is not None:
            self.journal.append(
                jl.PRECOMMIT, {"grow_seq": _ji(grow_seq), "dl": _ji(dl),
                               "blocks": _ji(blocks)},
                programmed=zip(dl, blocks))
        # pre-committed growth blocks are programmed by the scan that
        # follows this boundary, so (like extend_seqs) a bad block here
        # re-drives map-only — the scan then writes the replacement
        if self._maybe_retire_programs(dl, blocks):
            got = {s: self.seq_pages[s][-n:] for s, n in counts.items()}
        return got

    # -------------------------------------- bad-block retirement (ISSUE 6)
    def _maybe_retire_programs(self, dl, blocks) -> int:
        """Consult the fault plane once per freshly programmed device
        block (allocation order); retire + re-drive any that failed.
        Map-only recovery — callers invoke this before the block's
        data is written. Returns the number of blocks retired."""
        f = self.faults
        if f is None:
            return 0
        bad = [(int(d), int(b)) for d, b in zip(dl, blocks)
               if not BlockPool.is_host(int(b)) and f.program_fails()]
        if not bad:
            return 0
        _, n = self.retire_bad_blocks(bad)
        return n

    def retire_bad_blocks(self, bad: List[Tuple[int, int]], pools=None,
                          block_axis: int = 0):
        """Bad-block retirement: for each (dlpn, block) whose program
        failed, pop a replacement from the SAME channel, commit
        dlpn -> replacement through the fused CondUpdate single-probe
        path (failure-is-just-another-relocation: the paper's GC
        discipline already arbitrates racing relocations, so a program
        failure needs no new invariants), and permanently retire the
        bad block from the pool. With ``pools`` the relocation also
        copies the KV rows old -> new inside the same donated jit (for
        blocks whose data was already programmed, e.g. in-scan macro
        growth reconciled at the boundary); without, only the map
        commits — detection preceded the data write. Replacement
        programs re-consult the plane: a bounded re-drive chain
        (_MAX_REDRIVE) retires runs of bad blocks. A dry channel
        defers retirement — the original block stays in service and
        data stays intact either way. Returns (pools, n_retired)."""
        f = self.faults
        done: List[Tuple[int, int, int]] = []    # (dlpn, old, new)
        popped: List[int] = []      # every replacement candidate popped
        retired: List[int] = []     # every block permanently retired
        for dlpn, old in bad:
            assert not BlockPool.is_host(old), \
                "program faults model device-tier block programs"
            c = self.pool.channel_of(old)
            chain = [old]
            new = None
            for i in range(_MAX_REDRIVE):
                try:
                    cand = self.pool.alloc_for([c])[0]
                except OutOfBlocks:
                    break
                popped.append(cand)
                chain.append(cand)
                if f is None or i == _MAX_REDRIVE - 1 \
                        or not f.program_fails():
                    new = cand
                    break
            if new is None:
                # dry channel: old block serves on, un-retired — but any
                # candidates we DID pop failed their programs and must
                # still be retired, or they leak out of all accounting
                # (not free, not mapped, not retired)
                dead = chain[1:]
                if dead:
                    self.pool.retire(dead)
                    retired.extend(dead)
                continue
            dead = [b for b in chain if b != new]
            self.pool.retire(dead)
            retired.extend(dead)
            done.append((dlpn, old, new))
        if popped:
            self._alloc_dirty = True    # pops/retires moved the pool
        if done:
            dl = [d for d, _, _ in done]
            olds = [o for _, o, _ in done]
            news = [n for _, _, n in done]
            if pools is None:
                self._xlate(COND_UPDATE, dl, news, olds)
            else:
                pools, _ = self._retire_move(dl, news, olds, pools,
                                             block_axis)
            for d, o, n in done:
                pages = self.seq_pages[d // self.max_pages]
                pages[pages.index(o)] = n
        if self.journal is not None and (done or popped):
            touched = sorted({d // self.max_pages for d, _, _ in done})
            self.journal.append(
                jl.RETIRE,
                {"done": [[int(d), int(o), int(n)] for d, o, n in done],
                 "popped": _ji(popped), "retired": _ji(retired),
                 "pages": {int(s): _ji(self.seq_pages[s])
                           for s in touched},
                 "lanes": len(done)},
                programmed=[(d, n) for d, _, n in done],
                retired=retired)
        return pools, len(done)

    def _retire_fn(self, cap: int, block_axis: int, n_pools: int):
        """Fused retirement-relocation jit (cached beside the swap
        jits): CondUpdate map commit + device-row copy old -> new in
        ONE donated call — the swap pipeline's shape minus the
        residency-lane flip (retirement never changes tier)."""
        key = ("retire", cap, block_axis, n_pools)
        fn = self._swap_jits.get(key)
        if fn is None:
            g = self.geom
            sharded = self.channels > 1

            def f(ms, pools, dl, newb, oldb, src, dst):
                opc = jnp.full((cap,), COND_UPDATE, jnp.int32)
                if sharded:
                    ms, _, ok = self._xlate_graph(ms, opc, dl, newb,
                                                  oldb)
                else:
                    ms, _, ok = fb.translate_serving(g, ms, opc, dl,
                                                     newb, oldb)
                pools = [_move_rows(p, src, dst, block_axis)
                         for p in pools]
                return ms, pools, ok

            fn = jax.jit(f, donate_argnums=(0, 1))
            self._swap_jits[key] = fn
        return fn

    def _retire_move(self, dl, news, olds, pools, block_axis):
        """Dispatch one fused CondUpdate relocation (lanes padded to
        the next power of two, exactly like ``_swap``). Device-tier
        rows are the block ids themselves. Shared by bad-block
        retirement and the GC victim walk (both are "just another
        relocation"). Returns (pools, ok[:n]) — the guard-mask
        readback, so GC can skip lanes whose mapping went stale
        mid-walk (the page died; its relocation must not apply)."""
        n = len(dl)
        cap = 1 << (n - 1).bit_length()
        pad = cap - n

        def arr(xs, fill):
            return np.asarray(list(xs) + [fill] * pad, np.int32)

        XLATE_CALLS[0] += 1
        if self.channels > 1:
            self.channel_lanes += np.bincount(
                np.asarray(dl) % self.channels,
                minlength=self.channels)
        else:
            self.channel_lanes[0] += n
        fn = self._retire_fn(cap, block_axis, len(pools))
        # pad map lanes are inactive (dl=-1); pad moves repeat lane 0's
        # (src, dst) pair — duplicate writes of an identical value
        self.state, pools, ok = fn(
            self.state, list(pools), arr(dl, -1), arr(news, 0),
            arr(olds, 0), arr(olds, olds[0]), arr(news, news[0]))
        return pools, np.asarray(ok)[:n]

    def observe_exhaustion(self, flags=None) -> np.ndarray:
        """Fold the sticky in-graph OutOfBlocks flag lane into the
        typed per-channel exhaustion counts (``pool.exhausted_ch`` /
        hit_stats "pool_exhausted"). ``flags`` (host values) avoids a
        device readback when the caller already synced them — the C=1
        macro boundary passes the scan's returned flag; ``None`` reads
        ``state.oob``. Detection latency: an in-graph allocation
        failure at scan step j only becomes observable here, at the
        next boundary/sync — up to K tokens after the fact (documented
        + asserted in tests/test_faults.py). Any set flag marks the
        allocator dirty so the next ``sync_allocator`` re-push clears
        the lane."""
        if flags is None:
            flags = jax.device_get(self.state.oob)
        flags = np.atleast_1d(np.asarray(flags))
        for c, hit in enumerate(flags):
            if hit:
                self.pool.note_exhausted(c % self.channels)
                self._alloc_dirty = True
        return flags

    # ------------------------------------------------- GC walk (ISSUE 9)
    def live_counts(self) -> np.ndarray:
        """Host view of the device-maintained per-block live-page
        counts ([n_device] int; channel shards summed). ONE readback
        per GC walk — the counts are maintained by the fused commits
        themselves, so the walk never probes or scans the map."""
        assert self.track_live and self.state.live is not None, \
            "GC needs track_live=True (the optional live lane)"
        return np.asarray(jax.device_get(fb.live_vec(self.state)))

    def _pick_victim(self, c: int, lv: np.ndarray,
                     block_pages: int) -> Optional[List[int]]:
        """The channel's GC victim: among its full erase blocks
        (pool.erase_blocks grouping), the FRAGMENTED one — some live
        pages, some dead — with the fewest live pages (ties to the
        lowest id). Blocks touching retirement never recycle; fully
        dead blocks are already reclaimed frame-by-frame; fully live
        blocks have nothing to gain. Returns the victim's frames or
        None."""
        best = None
        for frames in self.pool.erase_blocks(c, block_pages):
            if any(self.pool.is_retired(f) for f in frames):
                continue
            # share-managed frames are immovable (ISSUE 10): a shared
            # block is mapped by SEVERAL dlpns (and possibly pinned by
            # the radix tree), and the walk's one-CondUpdate-per-frame
            # relocation can only re-point one of them — freeing the
            # old frame would tear every other mapper. The erase block
            # re-qualifies once the refcount gate drains it.
            if self._ref and any(f in self._ref for f in frames):
                continue
            nlive = int(sum(int(lv[f]) for f in frames))
            if nlive == 0 or nlive >= len(frames):
                continue
            if best is None or nlive < best[0]:
                best = (nlive, frames)
        return None if best is None else best[1]

    def gc_collect(self, pools=None, block_axis: int = 0, *,
                   block_pages: int, budget: int
                   ) -> Tuple[Optional[List[jnp.ndarray]], int, int]:
        """One budgeted GC victim-eviction walk (the paper's GCM):
        per channel, pick the fragmented erase block with the fewest
        live pages (from the fused-commit-maintained counts — no map
        probe, no sort), relocate its live pages as ONE batched
        CondUpdate through the single-probe fused path (+ KV row moves
        when ``pools`` is given), and free the old frames — the whole
        victim erase block then sits on the channel's free stack.

        ``budget`` caps pages moved across the whole call (the
        boundary budget: GC never blocks decode for more than a
        bounded relocation batch); a victim that does not fit finishes
        on later walks. Destinations come from the channel's own free
        list, EXCLUDING the victim's frames (pool.alloc_gc) — net free
        count is unchanged (the modeled erase granularity lives in the
        grouping, not in the free list; DESIGN.md), but live data
        defragments into whole-block holes.

        Relocate-if-still-mapped: a lane whose CondUpdate guard fails
        means the page died mid-walk — it is skipped and its unused
        destination returns to the free list (``returned``). Applied
        moves are journaled as a GC host commit (crash mid-walk
        replays or drops them atomically). Returns
        (pools, pages_moved, victims_reclaimed)."""
        assert self.track_live, \
            "GC needs track_live=True (the optional live lane)"
        if budget <= 0:
            return pools, 0, 0
        lv = self.live_counts()
        mp = self.max_pages
        rev: Dict[int, int] = {}
        for s, pages in self.seq_pages.items():
            for i, b in enumerate(pages):
                if not BlockPool.is_host(b):
                    rev[b] = s * mp + i
        plan = []   # (channel, n_live_in_victim, take frames, news)
        left = int(budget)
        for c in range(self.channels):
            if left <= 0:
                break
            frames = self._pick_victim(c, lv, block_pages)
            if frames is None:
                continue
            live_frames = [f for f in frames if int(lv[f]) > 0]
            missing = [f for f in live_frames if f not in rev]
            assert not missing, \
                f"live counts name unmapped blocks {missing}"
            take = live_frames[:left]
            news = self.pool.alloc_gc(c, len(take), avoid=frames)
            take = take[:len(news)]    # opportunistic: fewer is fine
            if not take:
                continue
            left -= len(take)
            plan.append((c, len(live_frames), take, news))
        if not plan:
            return pools, 0, 0
        self._alloc_dirty = True
        dl = [rev[f] for _, _, take, _ in plan for f in take]
        olds = [f for _, _, take, _ in plan for f in take]
        news = [b for _, _, _, ns in plan for b in ns]
        if pools is None:
            # map-only walk (test drivers): pad like every fused dispatch
            n = len(dl)
            cap = 1 << (n - 1).bit_length()
            _, ok = self._xlate(COND_UPDATE, dl + [-1] * (cap - n),
                                news + [0] * (cap - n),
                                olds + [0] * (cap - n))
            okh = np.asarray(ok)[:n]
        else:
            pools, okh = self._retire_move(dl, news, olds, pools,
                                           block_axis)
        moves: List[Tuple[int, int, int]] = []
        returned: List[int] = []
        reclaimed = 0
        i = 0
        for c, n_live, take, ns in plan:
            whole = len(take) == n_live
            for f, nb in zip(take, ns):
                if bool(okh[i]):
                    d = rev[f]
                    self.seq_pages[d // mp][d % mp] = nb
                    moves.append((d, f, nb))
                else:
                    returned.append(nb)    # page died mid-walk: skip
                    whole = False
                i += 1
            if whole:
                self.victims_ch[c] += 1
                reclaimed += 1
        # free applied olds then skipped news, in lane order — journal
        # replay (core/journal._apply GC branch) mirrors this exactly
        self.pool.free([o for _, o, _ in moves] + returned)
        self.gc_moves += len(moves)
        if self.journal is not None:
            self.journal.append(
                jl.GC,
                {"moves": [[int(d), int(o), int(n)]
                           for d, o, n in moves],
                 "returned": _ji(returned), "lanes": len(moves)},
                programmed=[(d, n) for d, _, n in moves])
        return pools, len(moves), reclaimed

    # ----------------------------------- prefix sharing (ISSUE 10)
    def refcounts(self) -> np.ndarray:
        """Host view of the device-maintained per-block mapping
        reference counts ([n_device] int; channel shards summed) — the
        refcnt lane's ``live_counts`` twin, read back once per check.
        The host ``_ref`` dict stays authoritative for share-managed
        blocks; the lane exists so tests can assert the two mirrors
        never diverge (and the GC/COW paths never pay a readback)."""
        assert self.track_refs and self.state.refcnt is not None, \
            "prefix sharing needs track_refs=True (the refcnt lane)"
        return np.asarray(jax.device_get(fb.refcount_vec(self.state)))

    @staticmethod
    def page_groups(tokens, page_size: int) -> List[tuple]:
        """Split a prompt into page-granular token groups — the radix
        path alphabet. The last group may be partial (a prompt tail
        that only part-fills its page); it is still shareable, because
        two requests whose prompts agree through the partial page have
        bit-identical KV for it, and the first divergent WRITE into it
        relocates copy-on-write."""
        toks = [int(t) for t in tokens]
        return [tuple(toks[i:i + page_size])
                for i in range(0, len(toks), page_size)]

    @staticmethod
    def _path_keys(groups) -> List[Tuple[int, int]]:
        """Rolling-hash node keys for every prefix of the page-group
        path: key_i = (depth i+1, crc32 chained over groups[:i+1]).
        The chain makes the key a function of the WHOLE prefix, so one
        flat dict keyed by (depth, hash) IS the radix tree — matching
        a prompt is a walk down increasing depths. Nodes store the
        exact prefix too: a crc collision degrades to a miss, never to
        sharing the wrong KV."""
        keys = []
        h = 0
        for i, g in enumerate(groups):
            h = zlib.crc32(np.asarray(g, np.int64).tobytes(), h)
            keys.append((i + 1, h))
        return keys

    def match_prefix(self, groups) -> List[int]:
        """Walk the radix path for a prompt's page groups; return the
        blocks backing the LONGEST already-cached prefix (possibly
        empty). Every returned block carries this exact prefix's KV,
        already resident in the device tier — admission maps the new
        slot's leading dlpns at them (``new_seq(shared=...)``) and
        prefill skips those pages entirely. Matched nodes are
        LRU-touched so hot prefixes survive pruning."""
        if not self.track_refs:
            return []
        out: List[int] = []
        pref: List[tuple] = []
        for g, key in zip(groups, self._path_keys(groups)):
            node = self._nodes.get(key)
            if node is None:
                break
            block, exact = node
            pref.append(tuple(g))
            if exact != tuple(pref) or self.pool.is_retired(block) \
                    or BlockPool.is_host(block):
                break               # collision / retired: miss, never lie
            self._nodes.move_to_end(key)
            out.append(block)
        return out

    def register_prefix(self, slot: int, groups) -> int:
        """Pin the slot's (fully prefilled) prompt pages into the radix
        tree so later admissions can share them. A pin is a TREE
        reference: the block now outlives its owner slot and returns to
        the pool only when the tree lets go AND no slot maps it. The
        owner's pinned pages also join its COW trigger set — the tree's
        copy must never be written in place, not even by the slot that
        computed it. Returns the number of newly pinned pages."""
        if not self.track_refs or not groups:
            return 0
        pages = self.seq_pages.get(slot)
        if pages is None:
            return 0
        mine = self._shared.setdefault(slot, {})
        pinned: List[Tuple[int, int]] = []     # (page, block)
        for i, key in enumerate(self._path_keys(groups)):
            if i >= len(pages):
                break
            if key in self._nodes:             # cached already (first
                continue                       # writer wins)
            b = pages[i]
            if BlockPool.is_host(b) or self.pool.is_retired(b) \
                    or b in self._pinned:
                continue
            self._nodes[key] = (b, tuple(tuple(g) for g in groups[:i + 1]))
            self._pinned[b] = key
            if b not in self._ref:
                self._ref[b] = 1               # the owner's mapping
            mine[i] = b
            pinned.append((i, b))
        if not mine:
            self._shared.pop(slot, None)
        if pinned and self.journal is not None:
            # a pin moves no map state and programs nothing — pure
            # refcount bookkeeping, replayed for the free-gate
            self.journal.append(
                jl.SHARE, {"op": "pin", "slot": int(slot),
                           "pages": [int(p) for p, _ in pinned],
                           "blocks": [int(b) for _, b in pinned],
                           "lanes": 0})
        self._prune_nodes()
        return len(pinned)

    def _prune_nodes(self):
        """Bound the tree at ``prefix_max_nodes``: evict least-recently
        -matched nodes (OrderedDict order). Unpinning releases the tree
        reference; the block is reclaimed immediately if no slot still
        maps it, else it lingers as an ordinary shared block until its
        mappers drain through the refcount gate."""
        dropped: List[int] = []
        while len(self._nodes) > self.prefix_max_nodes:
            _, (b, _) = self._nodes.popitem(last=False)
            self._pinned.pop(b, None)
            if self._ref.get(b, 0) <= 0:
                self._ref.pop(b, None)
                self.pool.free([b])
                self._alloc_dirty = True
            dropped.append(b)
        if dropped and self.journal is not None:
            self.journal.append(
                jl.SHARE, {"op": "unpin", "blocks": _ji(dropped),
                           "lanes": 0})

    def _unref(self, b: int):
        """Drop one mapping reference. Share-managed blocks (in
        ``_ref``) hit the pool only at zero refs with no pin; everything
        else frees as before."""
        n = self._ref.get(b)
        if n is None:
            self.pool.free([b])
            return
        self._ref[b] = n - 1
        if n - 1 <= 0 and b not in self._pinned:
            del self._ref[b]
            self.pool.free([b])

    def has_shared(self, slot: Optional[int] = None) -> bool:
        """Any (or this slot's) pages mapped at blocks that must not be
        written in place — the cheap guard the engine checks before
        paying the per-step COW frontier scan."""
        if slot is None:
            return bool(self._shared)
        return bool(self._shared.get(slot))

    def cow_writes(self, fronts: Dict[int, int], pools=None,
                   block_axis: int = 0):
        """Copy-on-write relocation (ISSUE 10): for each slot, every
        shared page AT OR AFTER its write frontier (the page index its
        next token lands in) is about to diverge from the cached
        prefix, so relocate it BEFORE the write commits: allocate a
        private block in the page's own channel, CondUpdate the dlpn
        old -> new through the batched relocation path (+ KV row copy
        when ``pools`` is given — the same fused jit GC and retirement
        ride), and drop the mapping ref on the shared block. A lane
        whose guard fails means the page died mid-copy (freed or moved
        by a racing commit) — it is skipped and its destination
        returns, exactly the GC walk's stale-lane discipline. Raises
        OutOfBlocks before any state changes if the pool cannot cover
        the batch. Returns (pools, n_relocated)."""
        work: List[Tuple[int, int, int]] = []    # (slot, page, old)
        for slot, wpage in fronts.items():
            m = self._shared.get(slot)
            if not m:
                continue
            for p in sorted(k for k in m if k >= wpage):
                old = m[p]
                if self.seq_pages[slot][p] != old:
                    m.pop(p)     # already diverged elsewhere (GC/retire)
                    continue
                work.append((slot, p, old))
        if not work:
            return pools, 0
        dl = [s * self.max_pages + p for s, p, _ in work]
        news = list(self._alloc_blocks(dl))
        olds = [o for _, _, o in work]
        self._alloc_dirty = True
        if pools is None:
            n = len(dl)
            cap = 1 << (n - 1).bit_length()
            _, ok = self._xlate(COND_UPDATE, dl + [-1] * (cap - n),
                                news + [0] * (cap - n),
                                olds + [0] * (cap - n))
            okh = np.asarray(ok)[:n]
        else:
            pools, okh = self._retire_move(dl, news, olds, pools,
                                           block_axis)
        moves: List[Tuple[int, int, int, int]] = []
        returned: List[int] = []
        for (slot, page, old), nb, okl in zip(work, news, okh):
            if bool(okl):
                self.seq_pages[slot][page] = nb
                self._shared[slot].pop(page, None)
                if not self._shared[slot]:
                    del self._shared[slot]
                self._unref(old)
                moves.append((slot, page, old, nb))
            else:
                returned.append(nb)
        self.pool.free(returned)
        self.cow_moves += len(moves)
        if self.journal is not None and (moves or returned):
            self.journal.append(
                jl.COW,
                {"moves": [[int(s), int(p), int(o), int(nw)]
                           for s, p, o, nw in moves],
                 "returned": _ji(returned), "lanes": len(moves)},
                programmed=[(s * self.max_pages + p, nw)
                            for s, p, _, nw in moves])
        return pools, len(moves)

    # ------------------------------------------ CTP prefetch (ISSUE 9)
    def prefetch_segments(self, dlpns) -> int:
        """The paper's CTP, from pre-commit knowledge: the macro
        boundary already knows exactly which dlpns the next K-step
        growth will touch, so pull the backing-table segments (CMT
        cache blocks) they live in into the CMT AHEAD of the scan —
        one fused LOOKUP over one representative dlpn per distinct
        (channel, segment), padded like every dispatch. A LOOKUP of a
        still-unmapped dlpn is exactly a segment fetch: the insert
        pass caches the whole backing block, so the scan's UPDATE
        commits hit instead of missing. Accounting: a prefetch MISS
        did useful work (the segment was cold); a prefetch HIT was
        redundant. Returns the number of segments probed.

        The prefetcher tracks the scan FRONTIER: a segment is fetched
        the first time growth crosses into it and never re-probed
        (``_pf_seen``) — growth dlpns advance monotonically, so
        without the filter every boundary would re-dispatch a LOOKUP
        over the same already-cached segments, and that per-boundary
        dispatch tax is what the >= 0.9x GC-retention acceptance
        forbids. The set is a hint, not a guarantee: a CMT eviction
        can re-cool a seen segment, which the scan then pays as an
        ordinary miss."""
        dl = np.unique(np.asarray(dlpns, np.int32))
        dl = dl[dl >= 0]
        if dl.size == 0:
            return 0
        ent = self.geom.cmt_entries
        C = self.channels
        reps: List[int] = []
        for d in dl.tolist():
            key = ((d % C, (d // C) // ent) if C > 1
                   else (0, d // ent))
            if key not in self._pf_seen:
                self._pf_seen.add(key)
                reps.append(int(d))
        n = len(reps)
        if n == 0:
            return 0
        cap = 1 << (n - 1).bit_length()
        before = self._cmt_hit_miss()
        self._xlate(LOOKUP, reps + [-1] * (cap - n),
                    np.zeros(cap, np.int32))
        after = self._cmt_hit_miss()
        self.prefetch_hits += int(after[0] - before[0])
        self.prefetch_misses += int(after[1] - before[1])
        return n

    def _cmt_hit_miss(self) -> Tuple[int, int]:
        s = np.asarray(jax.device_get(self.state.fmmu.stats))
        if self.channels > 1:
            s = s.sum(axis=0)
        return int(s[0]), int(s[1])

    # ----------------------------------------------------------- swapping
    def _swap_fn(self, cap: int, block_axis: int, n_pools: int):
        """Build (or fetch) the fused swap jit for a padded lane count.
        ONE donated call per swap: CondUpdate commits through the
        single-probe fused translate, pool rows gather/scatter, and the
        swap_pending residency lane flips — no host roundtrip between
        the map write and the data it guards."""
        key = (cap, block_axis, n_pools)
        fn = self._swap_jits.get(key)
        if fn is None:
            g = self.geom
            sharded = self.channels > 1

            def f(ms, pools, dl, newb, oldb, src, dst, lane, pending):
                opc = jnp.full((cap,), COND_UPDATE, jnp.int32)
                if sharded:
                    # same fused shape, channel-sharded commit: each
                    # channel CondUpdates the swap lanes it owns (the
                    # shard_map/vmap graph composes under this jit)
                    ms, _, ok = self._xlate_graph(ms, opc, dl, newb,
                                                  oldb)
                    ms = fb.mark_swap_sharded(ms, lane, pending)
                else:
                    ms, _, ok = fb.translate_serving(g, ms, opc, dl,
                                                     newb, oldb)
                    ms = fb.mark_swap(ms, lane, pending)
                pools = [_move_rows(p, src, dst, block_axis)
                         for p in pools]
                return ms, pools, ok

            fn = jax.jit(f, donate_argnums=(0, 1))
            self._swap_jits[key] = fn
        return fn

    def _swap(self, direction: int, slot: int, pools, block_axis: int,
              check: bool) -> Tuple[List[jnp.ndarray], int]:
        """Shared body of swap_out/swap_in: host bookkeeping + one
        fused donated jit. Lane arrays are padded to the next power of
        two (pad lanes are inactive map ops and idempotent row moves),
        bounding re-traces at O(log max_pages) per (axis, pool-count)."""
        blocks = self.seq_pages[slot]
        out = direction == SWAP_OUT
        # share-managed blocks never change tier (ISSUE 10): other
        # slots (or the radix tree) still read them in the device
        # tier, so a swap-out moves only this slot's PRIVATE pages and
        # leaves the shared prefix resident — the slot comes back with
        # its shared mappings untouched. (Swap-in never sees shared
        # blocks: only device-tier blocks are ever shared.)
        moving = [b for b in blocks
                  if BlockPool.is_host(b) != out and b not in self._ref]
        if not moving:
            return pools, 0
        if self.faults is not None and self.faults.swap_fails():
            # injected BEFORE any mutation (allocs, map, pools, page
            # lists): the caller may retry the identical swap later —
            # the engine backs off exponentially and quarantines a
            # slot whose swap keeps failing
            raise flt.SwapFault(slot, direction, len(moving))
        dl = [slot * self.max_pages + i for i, b in enumerate(blocks)
              if BlockPool.is_host(b) != out and b not in self._ref]
        fresh = self._alloc_blocks(dl, host=out)
        self._alloc_dirty = True
        row = self.pool.host_row
        src = [row(b) if not out else b for b in moving]
        dst = [b if not out else row(b) for b in fresh]
        n = len(moving)
        cap = 1 << (n - 1).bit_length()
        if self.swap_pad:
            cap = max(cap, self.swap_pad)   # pinned: one fn per direction
        pad = cap - n

        def arr(xs, fill):
            return np.asarray(list(xs) + [fill] * pad, np.int32)

        XLATE_CALLS[0] += 1
        if self.channels > 1:
            self.channel_lanes += np.bincount(
                np.asarray(dl) % self.channels,
                minlength=self.channels)
        else:
            self.channel_lanes[0] += n
        fn = self._swap_fn(cap, block_axis, len(pools))
        # pad map lanes are inactive (dl=-1); pad moves repeat lane 0's
        # (src, dst) pair — duplicate writes of an identical value
        self.state, pools, ok = fn(
            self.state, list(pools), arr(dl, -1), arr(fresh, 0),
            arr(moving, 0), arr(src, src[0]), arr(dst, dst[0]),
            np.int32(slot), out)
        if check:
            assert np.asarray(ok)[:n].all(), \
                "swap raced with a concurrent relocation"
        self.pool.free(moving)
        self.seq_pages[slot] = [
            fresh[moving.index(b)] if b in moving else b for b in blocks]
        self._host_pages[slot] = sum(
            BlockPool.is_host(b) for b in self.seq_pages[slot])
        if out:
            self.pool.stats.swaps_out += n
        else:
            self.pool.stats.swaps_in += n
        if self.journal is not None:
            # the swap's commit point: a crash on this append is the
            # ISSUE-7 "mid-swap" case — the OOB frame (dl -> fresh)
            # either survives whole (reverse-map scan re-applies the
            # move, freeing the displaced blocks) or tears (the move
            # never reached flash; pre-swap state is the truth)
            self.journal.append(
                jl.SWAP,
                {"slot": int(slot), "out": bool(out), "moving": _ji(moving),
                 "fresh": _ji(fresh), "pages": _ji(self.seq_pages[slot]),
                 "hp": int(self._host_pages[slot])},
                programmed=zip(dl, fresh))
        return pools, n

    def swap_out(self, slot: int, pools: List[jnp.ndarray],
                 block_axis: int = 0, check: bool = True
                 ) -> Tuple[List[jnp.ndarray], int]:
        """Relocate all device blocks of `slot` to the host tier in ONE
        donated jitted call (CondUpdate-guarded map commit + pool-row
        gather/scatter + swap_pending lane set). pools: list of
        [NB_dev(+host), ...] tensors (k & v per layer group); the host
        region lives at rows [n_device:]. Returns (pools, n moved).
        ``check=False`` skips the guard-mask readback so the caller
        never blocks on the swap (the serving scheduler's mode)."""
        return self._swap(SWAP_OUT, slot, pools, block_axis, check)

    def swap_in(self, slot: int, pools: List[jnp.ndarray],
                block_axis: int = 0, check: bool = True
                ) -> Tuple[List[jnp.ndarray], int]:
        """Bring a swapped-out sequence back to device blocks (same
        fused non-blocking pipeline as swap_out; clears the lane)."""
        return self._swap(SWAP_IN, slot, pools, block_axis, check)

    def free_device_vec(self) -> np.ndarray:
        """Free device blocks per channel ([total] at channels=1): the
        engine's growth-reserve checks compare per channel, because a
        dry channel is real pool pressure even while others have
        blocks."""
        return np.asarray([self.pool.free_device_ch(c)
                           for c in range(self.channels)], np.int64)

    def host_pages_vec(self, slot: int) -> np.ndarray:
        """Host-tier pages of `slot` per owner channel — the per-
        channel device blocks its swap-in would consume."""
        out = np.zeros(self.channels, np.int64)
        for b in self.seq_pages.get(slot, ()):
            if BlockPool.is_host(b):
                out[self.pool.channel_of(b)] += 1
        return out

    # -------------------------------------- crash consistency (ISSUE 7)
    def journal_cfg(self) -> dict:
        """Geometry stamped into every snapshot: recovery refuses to
        restore into a differently-shaped manager."""
        return {"channels": self.channels, "n_device": self._n_dev,
                "n_host": self._n_host, "max_pages": self.max_pages,
                "n_slots": self.n_slots}

    def snapshot_state(self) -> dict:
        """The manager's share of a journal snapshot: page lists, the
        swap-maintained host-page counts, and the full pool allocator
        state (free-list ORDER included — the device-mirror contract
        makes order part of the state). All host data: the device map
        is a pure function of this (``restore_mapping`` re-derives it),
        so snapshots never serialize device arrays or KV pools."""
        d = {"cfg": self.journal_cfg(),
             "seq_pages": {int(s): _ji(p)
                           for s, p in self.seq_pages.items()},
             "host_pages": {int(s): int(n)
                            for s, n in self._host_pages.items()}}
        if self._ref or self._pinned:
            # prefix sharing (ISSUE 10): mapping refcounts and tree
            # pins are host truth the free-gate depends on. The tree's
            # CONTENT (token hashes) is deliberately not persisted —
            # the prefix cache is volatile; recovery releases pins and
            # rebuilds sharing from new traffic (restore_mapping).
            d["ref"] = {str(int(b)): int(n)
                        for b, n in self._ref.items()}
            d["pinned"] = sorted(int(b) for b in self._pinned)
        d.update(self.pool.state_dict())
        return d

    def restore_mapping(self, rec: "jl.Recovered") -> int:
        """Rebuild this manager from recovered host truth (call on a
        freshly ``reset`` manager): restore the pool + page lists, then
        re-derive the whole device map with ONE fused batched UPDATE
        (lanes padded to the next power of two — the usual re-trace
        bound) and one allocator re-push. The CMT refills warm, which
        SPOR always pays; dense_table / free stacks / residency lanes
        come back bit-identical to the pre-crash state because they are
        pure functions of what the journal persisted. Returns the
        number of mapped pages re-committed."""
        cfg = self.journal_cfg()
        assert rec.cfg == cfg, f"snapshot geometry {rec.cfg} != {cfg}"
        self.pool.load_state({
            "free_dev_ch": rec.free_dev_ch,
            "free_host_ch": rec.free_host_ch,
            "rr": rec.rr, "retired": sorted(rec.retired),
            "retired_ch": rec.retired_ch,
            "exhausted_ch": rec.exhausted_ch, "stats": rec.stats})
        self.seq_pages = {int(s): _ji(p)
                          for s, p in rec.seq_pages.items()}
        self._host_pages = {int(s): int(n)
                            for s, n in rec.host_pages.items()}
        # prefix sharing (ISSUE 10): mapping refcounts are durable
        # truth; the radix tree is a volatile cache. Restore the
        # refcounts, then RELEASE every recovered pin — a pinned block
        # no slot maps goes straight back to the pool (in sorted block
        # order, so recovery is deterministic), and sharing rebuilds
        # from post-recovery traffic.
        self._ref = {int(b): int(n) for b, n in rec.ref.items()}
        for b in sorted(int(x) for x in rec.pinned):
            if self._ref.get(b, 0) <= 0:
                self._ref.pop(b, None)
                self.pool.free([b])
        dl: List[int] = []
        blocks: List[int] = []
        for s in sorted(self.seq_pages):
            for i, b in enumerate(self.seq_pages[s]):
                dl.append(s * self.max_pages + i)
                blocks.append(b)
        n = len(dl)
        if n:
            cap = 1 << (n - 1).bit_length()
            dl += [-1] * (cap - n)
            blocks += [0] * (cap - n)
            self._xlate(UPDATE, dl, blocks)
        self._alloc_dirty = True
        self.sync_allocator()    # stacks + residency lanes in one push
        return n

    def hit_stats(self) -> "MapStats":
        s = np.asarray(self.state.fmmu.stats)
        if self.channels > 1:
            s = s.sum(axis=0)
        fired = self.faults.counts() if self.faults is not None else {}
        # write-amplification axis (ISSUE 9): every flash program is a
        # host-commanded write, a swap-in re-program, a GC relocation,
        # or a copy-on-write divergence copy (ISSUE 10). Retirement
        # re-drives are deliberately excluded — they are fault
        # recovery, not amplification policy.
        flash = (self.host_writes + self.pool.stats.swaps_in
                 + self.gc_moves + self.cow_moves)
        return MapStats(
            hits=int(s[0]), misses=int(s[1]),
            fills=int(s[2]), updates=int(s[3]),
            # swap/tier activity (ISSUE-4): the zero-fallback claim
            # is asserted from counters, not inferred from timings
            swaps_out=self.pool.stats.swaps_out,
            swaps_in=self.pool.stats.swaps_in,
            host_resident_slots=sum(
                1 for c in self._host_pages.values() if c > 0),
            # fault/recovery plane (ISSUE 6): retirement + typed
            # per-channel exhaustion attribution + fired-fault
            # counts (all zero without a plane)
            retired_blocks=self.pool.stats.retired,
            retired_ch=list(self.pool.retired_ch),
            pool_exhausted=list(self.pool.exhausted_ch),
            swap_faults=fired.get("swap", 0),
            program_faults=fired.get("program", 0),
            alloc_faults=fired.get("alloc", 0),
            # GC/CTP plane (ISSUE 9)
            gc_moves=self.gc_moves,
            victims_ch=list(self.victims_ch),
            prefetch_hits=self.prefetch_hits,
            prefetch_misses=self.prefetch_misses,
            host_writes=self.host_writes,
            flash_programs=flash,
            write_amp=(flash / self.host_writes
                       if self.host_writes else 1.0),
            # prefix-sharing plane (ISSUE 10)
            shared_maps=self.shared_maps,
            cow_moves=self.cow_moves)
