"""Physical KV block pool allocator (the serving BM analogue).

Two tiers: device (HBM) blocks consumed by attention kernels, and a host
("flash"-analogue) overflow tier used for swapped-out sequences. Block
ids are tier-tagged: device blocks are [0, n_device); host blocks are
[HOST_BASE, HOST_BASE + n_host). The allocator is host-side (scheduler
thread), like the BM in the paper; the FMMU map holds the tier-tagged
physical ids and CondUpdate arbitrates relocation races.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.fmmu.types import HOST_BASE


class OutOfBlocks(RuntimeError):
    pass


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    swaps_out: int = 0
    swaps_in: int = 0
    peak_used: int = 0


class BlockPool:
    def __init__(self, n_device: int, n_host: int = 0):
        self.n_device = n_device
        self.n_host = n_host
        self._free_dev: List[int] = list(range(n_device))[::-1]
        self._free_host: List[int] = [HOST_BASE + i
                                      for i in range(n_host)][::-1]
        self.stats = PoolStats()

    @staticmethod
    def is_host(block: int) -> bool:
        return block >= HOST_BASE

    def host_row(self, block: int) -> int:
        """Pool-tensor row backing a host-tier block id: the host
        region lives at rows [n_device, n_device + n_host). One home
        for the formula — the swap gather/scatter (kv_manager) and the
        data-integrity tests must agree on it."""
        assert block >= HOST_BASE, block
        return self.n_device + (block - HOST_BASE)

    @property
    def free_device(self) -> int:
        return len(self._free_dev)

    @property
    def free_host(self) -> int:
        return len(self._free_host)

    def alloc(self, n: int, *, host: bool = False) -> List[int]:
        pool = self._free_host if host else self._free_dev
        if len(pool) < n:
            raise OutOfBlocks(
                f"need {n} {'host' if host else 'device'} blocks, "
                f"have {len(pool)}")
        out = [pool.pop() for _ in range(n)]
        self.stats.allocs += n
        used = self.n_device - len(self._free_dev)
        self.stats.peak_used = max(self.stats.peak_used, used)
        return out

    def free(self, blocks: List[int]):
        for b in blocks:
            (self._free_host if self.is_host(b) else self._free_dev).append(b)
        self.stats.frees += len(blocks)
