"""Physical KV block pool allocator (the serving BM analogue).

Two tiers: device (HBM) blocks consumed by attention kernels, and a host
("flash"-analogue) overflow tier used for swapped-out sequences. Block
ids are tier-tagged: device blocks are [0, n_device); host blocks are
[HOST_BASE, HOST_BASE + n_host). The allocator is host-side (scheduler
thread), like the BM in the paper; the FMMU map holds the tier-tagged
physical ids and CondUpdate arbitrates relocation races.

Channel-sharded serving (ISSUE 5) stripes both tiers across N channels:
block b belongs to channel b mod C (host blocks by their tier-local
index), mirroring the dlpn -> channel hash, so a page and the block
backing it always live in the same channel and each channel's
device-resident free stack (core/fmmu/batch.init_sharded_state) mirrors
exactly one per-channel free list here. ``n_channels=1`` keeps the
single flat free list bit-identical to the pre-sharding pool (the
channel-0 list IS the old list object).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set

from repro.core.fmmu.types import HOST_BASE


class OutOfBlocks(RuntimeError):
    pass


class PoolExhausted(OutOfBlocks):
    """Typed pool-pressure error (ISSUE 6): carries the channel the
    shortage was attributed to and whether it was a *transient*
    injected exhaustion (fault plane) rather than genuine dry-pool
    pressure. Subclasses ``OutOfBlocks`` so every existing handler
    keeps working; new code should match on this type and consult
    ``transient`` — the engine's livelock guard must NOT treat an
    injected transient shortage as terminal."""

    def __init__(self, msg: str, *, channel: Optional[int] = None,
                 transient: bool = False):
        super().__init__(msg)
        self.channel = channel
        self.transient = transient


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    swaps_out: int = 0
    swaps_in: int = 0
    peak_used: int = 0
    retired: int = 0          # bad blocks permanently removed (ISSUE 6)


class BlockPool:
    def __init__(self, n_device: int, n_host: int = 0,
                 n_channels: int = 1):
        self.n_device = n_device
        self.n_host = n_host
        self.n_channels = n_channels
        # per-channel striped free lists; first pop of channel c yields
        # block c (tier-local), matching init_sharded_state's stacks.
        # For n_channels=1 the channel-0 list is the legacy flat list.
        self._free_dev_ch: List[List[int]] = [
            [b for b in range(n_device) if b % n_channels == c][::-1]
            for c in range(n_channels)]
        self._free_host_ch: List[List[int]] = [
            [HOST_BASE + i for i in range(n_host)
             if i % n_channels == c][::-1]
            for c in range(n_channels)]
        self._free_dev = self._free_dev_ch[0]
        self._free_host = self._free_host_ch[0]
        self._rr = 0        # channel-agnostic alloc's round-robin cursor
        self.stats = PoolStats()
        # bad-block retirement (ISSUE 6): retired blocks never re-enter
        # a free list — free() drops them — and capacity shrinks
        # permanently, like marking a NAND block bad in the BBT
        self._retired: Set[int] = set()
        self.retired_ch = [0] * n_channels
        # per-channel PoolExhausted attribution counts (typed error
        # path; also bumped by KVPageManager.observe_exhaustion when
        # the device-side sticky oob flag lane is read at a boundary)
        self.exhausted_ch = [0] * n_channels

    @staticmethod
    def is_host(block: int) -> bool:
        return block >= HOST_BASE

    def channel_of(self, block: int) -> int:
        """Owner channel of a block id (tier-local index mod C)."""
        b = block - HOST_BASE if block >= HOST_BASE else block
        return b % self.n_channels

    def host_row(self, block: int) -> int:
        """Pool-tensor row backing a host-tier block id: the host
        region lives at rows [n_device, n_device + n_host). One home
        for the formula — the swap gather/scatter (kv_manager) and the
        data-integrity tests must agree on it."""
        assert block >= HOST_BASE, block
        return self.n_device + (block - HOST_BASE)

    @property
    def free_device(self) -> int:
        return sum(len(ch) for ch in self._free_dev_ch)

    @property
    def free_host(self) -> int:
        return sum(len(ch) for ch in self._free_host_ch)

    def free_device_ch(self, c: int) -> int:
        return len(self._free_dev_ch[c])

    def free_host_ch(self, c: int) -> int:
        return len(self._free_host_ch[c])

    def _bump_alloc(self, n: int):
        self.stats.allocs += n
        used = self.n_device - self.free_device
        self.stats.peak_used = max(self.stats.peak_used, used)

    def alloc(self, n: int, *, host: bool = False) -> List[int]:
        """Channel-agnostic allocation (the n_channels=1 fast path;
        with channels the caller should route by dlpn owner via
        ``alloc_for``). Pops round-robin across channels so unchanneled
        callers cannot silently drain one channel."""
        lists = self._free_host_ch if host else self._free_dev_ch
        if sum(len(ch) for ch in lists) < n:
            # aggregate shortage: attribute it to the emptiest channel
            # (the binding constraint) for the per-channel counts
            c = min(range(self.n_channels), key=lambda i: len(lists[i]))
            self.note_exhausted(c)
            raise PoolExhausted(
                f"need {n} {'host' if host else 'device'} blocks, "
                f"have {sum(len(ch) for ch in lists)}", channel=c)
        if self.n_channels == 1:
            pool = lists[0]
            out = [pool.pop() for _ in range(n)]
        else:
            # cursor persists across calls: repeated alloc(1) visits
            # every channel instead of draining channel 0 first
            out = []
            while len(out) < n:
                if lists[self._rr % self.n_channels]:
                    out.append(lists[self._rr % self.n_channels].pop())
                self._rr += 1
        self._bump_alloc(n)
        return out

    def alloc_for(self, channels: Sequence[int], *,
                  host: bool = False) -> List[int]:
        """Pop one block per requested owner channel, in order; the
        channel-sharded allocation path (block i backs a page owned by
        channels[i]). Raises BEFORE any pop when any channel's list is
        short — per-channel pool pressure is a real OutOfBlocks even
        while other channels still hold blocks."""
        lists = self._free_host_ch if host else self._free_dev_ch
        need = [0] * self.n_channels
        for c in channels:
            need[c] += 1
        for c, k in enumerate(need):
            if k > len(lists[c]):
                self.note_exhausted(c)
                raise PoolExhausted(
                    f"need {k} {'host' if host else 'device'} blocks "
                    f"in channel {c}, have {len(lists[c])}", channel=c)
        out = [lists[c].pop() for c in channels]
        self._bump_alloc(len(out))
        return out

    # ------------------------------------------------------ GC (ISSUE 9)
    def erase_blocks(self, channel: int, block_pages: int) -> List[List[int]]:
        """Enumerate the channel's full erase blocks as lists of global
        device block ids (frames). The flash erase granularity is
        modeled ON TOP of the page-granular pool: erase block e of
        channel c groups the channel's tier-local frames [e*P, (e+1)*P)
        — global ids {c + C*(e*P + j) : j < P} under the striping
        (block b -> channel b mod C). A trailing partial group (when
        the channel's frame count is not a multiple of P) is never a
        GC candidate. One home for the grouping: the victim walk
        (kv_manager) and the oracle tests must agree on it."""
        C = self.n_channels
        P = block_pages
        assert P > 0
        n_local = (self.n_device - channel + C - 1) // C
        return [[channel + C * (e * P + j) for j in range(P)]
                for e in range(n_local // P)]

    def alloc_gc(self, channel: int, n: int, avoid=()) -> List[int]:
        """Pop up to ``n`` relocation destinations from a channel's
        device free list, skipping ``avoid`` (the victim erase block's
        own free frames — relocating INTO the victim would leave it
        unreclaimed). Scans from the list tail (top of stack, the same
        end ``alloc`` pops) and removes the exact ids picked: removal
        is by value, so journal replay's remove-by-id reproduces the
        identical list content AND order. Returns fewer than ``n``
        (possibly none) when the channel lacks eligible blocks — GC is
        opportunistic and must never raise pool pressure."""
        avoid = set(avoid)
        ch = self._free_dev_ch[channel]
        picked = [b for b in reversed(ch) if b not in avoid][:n]
        for b in picked:
            ch.remove(b)
        if picked:
            self._bump_alloc(len(picked))
        return picked

    def free(self, blocks: List[int]):
        n = 0
        for b in blocks:
            if b in self._retired:
                continue        # retired blocks never re-enter service
            lists = (self._free_host_ch if self.is_host(b)
                     else self._free_dev_ch)
            lists[self.channel_of(b)].append(b)
            n += 1
        self.stats.frees += n

    # ------------------------------------------------ faults (ISSUE 6)
    def retire(self, blocks: Sequence[int]):
        """Permanently remove blocks from service (bad-block
        retirement): they are dropped from any future ``free`` and
        counted per channel. Callers retire blocks they currently own
        (allocated, not on a free list) after relocating their mapping
        to a replacement — failure-is-just-another-relocation."""
        for b in blocks:
            assert b not in self._retired, f"block {b} retired twice"
            self._retired.add(b)
            self.retired_ch[self.channel_of(b)] += 1
        self.stats.retired += len(blocks)

    def is_retired(self, block: int) -> bool:
        return block in self._retired

    # ------------------------------------------- checkpointing (ISSUE 7)
    def state_dict(self) -> dict:
        """Full allocator state as plain JSON-serializable host data —
        free lists in exact order (the device-mirror contract makes
        order part of the state, not an implementation detail), the
        round-robin cursor, retirement, and counters. Consumed by the
        journal snapshot (core/journal.py) and test_checkpoint.py."""
        return {"free_dev_ch": [list(ch) for ch in self._free_dev_ch],
                "free_host_ch": [list(ch) for ch in self._free_host_ch],
                "rr": self._rr,
                "retired": sorted(self._retired),
                "retired_ch": list(self.retired_ch),
                "exhausted_ch": list(self.exhausted_ch),
                "stats": dataclasses.asdict(self.stats)}

    def load_state(self, d: dict):
        """Restore ``state_dict`` output bit-exactly. Mutates the
        existing per-channel lists IN PLACE: at n_channels=1 the legacy
        ``_free_dev``/``_free_host`` views alias channel 0's list, and
        restoring must preserve that aliasing."""
        assert len(d["free_dev_ch"]) == self.n_channels
        for c in range(self.n_channels):
            self._free_dev_ch[c][:] = [int(b) for b in d["free_dev_ch"][c]]
            self._free_host_ch[c][:] = [int(b)
                                        for b in d["free_host_ch"][c]]
        self._rr = int(d["rr"])
        self._retired = set(int(b) for b in d["retired"])
        self.retired_ch = [int(n) for n in d["retired_ch"]]
        self.exhausted_ch = [int(n) for n in d["exhausted_ch"]]
        self.stats = PoolStats(**d["stats"])

    def note_exhausted(self, channel: int, n: int = 1):
        """Attribute one (or n) pool-exhaustion events to a channel:
        the typed-raise paths call this directly; the device-side
        sticky oob flag lane folds in via
        ``KVPageManager.observe_exhaustion`` at macro boundaries (the
        in-graph failure is observed up to K tokens after it
        happened — the documented detection latency)."""
        self.exhausted_ch[channel] += n
