"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \\
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Full-config launches on real hardware use the same entry point without
--smoke; the mesh is chosen from the visible device count (TP fixed per
arch, data axis absorbs the rest; multi-pod adds the 'pod' axis)."""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_arch, smoke_config
from repro.data.pipeline import DataConfig, data_iter
from repro.models import Runtime, build_model
from repro.parallel.sharding import trivial_ctx
from repro.training import optimizer as opt
from repro.training.elastic import make_ctx
from repro.training.train_loop import TrainerConfig, train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CPU runs")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--tp", type=int, default=0,
                    help="model-parallel size (0 = single device)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--fp32", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    ctx = (make_ctx(len(jax.devices()), model_parallel=args.tp)
           if args.tp else trivial_ctx())
    rt = Runtime(
        compute_dtype=jnp.float32 if args.fp32 else jnp.bfloat16,
        param_dtype=jnp.float32, remat=args.remat)
    model = build_model(cfg, rt, ctx)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    it = data_iter(dcfg)
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                           decay_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps, log_every=args.log_every,
                         ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                         grad_accum=args.grad_accum)

    def on_step(step, metrics):
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f}", flush=True)

    state, summary = train(model, it, ocfg, tcfg, on_step=on_step)
    if hasattr(it, "close"):
        it.close()
    print(json.dumps({
        "final_loss": summary["history"][-1][1],
        "first_loss": summary["history"][0][1],
        "mean_step_s": round(summary["mean_step_s"], 4),
        "stragglers": len(summary["stragglers"]),
    }))
    return state, summary


if __name__ == "__main__":
    main()
