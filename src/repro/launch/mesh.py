"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (jax locks the device count on first init)."""
from __future__ import annotations

import jax

from repro.parallel.sharding import ParallelCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def production_ctx(*, multi_pod: bool = False) -> ParallelCtx:
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = ("pod", "data") if multi_pod else ("data",)
    return ParallelCtx(mesh=mesh, dp=dp)
