"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (jax locks the device count on first init)."""
from __future__ import annotations

from repro.parallel.sharding import ParallelCtx, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # version-portable wrapper: jax.sharding.AxisType only exists on
    # newer wheels than the pinned 0.4.37
    return make_mesh(shape, axes)


def production_ctx(*, multi_pod: bool = False) -> ParallelCtx:
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = ("pod", "data") if multi_pod else ("data",)
    return ParallelCtx(mesh=mesh, dp=dp)
