"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the sweep's
JSON records.

  PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def load(dir_):
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        if p.endswith("summary.json"):
            continue
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def dryrun_table(recs) -> str:
    lines = ["| arch | shape | mesh | status | compile | arg/dev | temp/dev | collectives |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("applicable", True):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skip ({r['skip_reason'][:40]}…) | - | - | - | - |")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"**FAIL** | - | - | - | - |")
            continue
        mem = r.get("memory", {})
        coll = r.get("collectives", {}).get("counts", {})
        cstr = " ".join(f"{k.split('-')[1] if '-' in k else k}:{v}"
                        for k, v in sorted(coll.items())) or "none"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('compile_s', '-')}s | "
            f"{fmt_bytes(mem.get('argument_bytes'))} | "
            f"{fmt_bytes(mem.get('temp_bytes'))} | {cstr} |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful | bound-by note |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != "16x16" or not r.get("ok"):
            continue
        ro = r.get("roofline")
        if not ro:
            continue
        dom = ro["dominant"]
        note = {
            "compute": "MXU-bound: raise arithmetic intensity or accept",
            "memory": "HBM-bound: fuse/recompute less, shrink dtypes, "
                      "bigger tiles",
            "collective": "ICI-bound: reshard, overlap, or compress",
        }[dom]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"**{dom}** | {ro['useful_ratio']:.2f} | {note} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    recs = load(args.dir)
    pod = [r for r in recs if r.get("mesh") == "16x16"]
    mp = [r for r in recs if r.get("mesh") == "2x16x16"]
    ok = sum(1 for r in recs if r.get("ok"))
    skip = sum(1 for r in recs if not r.get("applicable", True))
    fail = len(recs) - ok - skip
    out = []
    out.append(f"### Dry-run status: {ok} compiled ok, {skip} skipped "
               f"(by design), {fail} failed\n")
    out.append("#### Single-pod (16x16 = 256 chips)\n")
    out.append(dryrun_table(pod))
    out.append("\n#### Multi-pod (2x16x16 = 512 chips)\n")
    out.append(dryrun_table(mp))
    out.append("\n### Roofline (single-pod)\n")
    out.append(roofline_table(recs))
    text = "\n".join(out)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
