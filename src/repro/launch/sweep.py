"""Dry-run sweep driver: every (arch x shape) cell on both production
meshes, one subprocess per cell (fresh XLA state), JSON per cell +
rollup summary.

  PYTHONPATH=src python -m repro.launch.sweep --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.sweep --only llama3.2-1b
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ARCHS, SHAPES, shape_applicable


def cell_id(arch, shape, mesh):
    return f"{arch}_{shape}_{mesh}".replace(".", "_")


def run_cell(arch, shape, mesh, out_dir, *, extrapolate=True, fsdp=False,
             timeout=3600):
    path = os.path.join(out_dir, cell_id(arch, shape, mesh) + ".json")
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok") or not rec.get("applicable", True):
            return rec, True  # cached
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--mesh", mesh,
           "--out", path]
    if not extrapolate:
        cmd.append("--no-extrapolate")
    if fsdp:
        cmd.append("--fsdp")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f), False
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "ok": False,
               "error": proc.stderr[-1500:], "wall_s": time.time() - t0}
    except subprocess.TimeoutExpired:
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "ok": False,
               "error": f"timeout after {timeout}s"}
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec, False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--only", default=None, help="arch filter substring")
    ap.add_argument("--shapes", default=None, help="comma-separated")
    ap.add_argument("--meshes", default="pod,multipod")
    ap.add_argument("--timeout", type=int, default=3600)
    # FSDP for models whose fp32 state exceeds HBM on pure TP
    ap.add_argument("--fsdp-archs",
                    default="jamba-1.5-large-398b,arctic-480b,dbrx-132b,"
                            "qwen2-72b")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    fsdp_archs = set(args.fsdp_archs.split(","))
    shapes = (args.shapes.split(",") if args.shapes else list(SHAPES))
    meshes = args.meshes.split(",")
    results = []
    t0 = time.time()
    for arch in ARCHS:
        if args.only and args.only not in arch:
            continue
        for shape in shapes:
            ok, why = shape_applicable(ARCHS[arch], SHAPES[shape])
            for mesh in meshes:
                if not ok:
                    path = os.path.join(args.out,
                                        cell_id(arch, shape, mesh) + ".json")
                    rec = {"arch": arch, "shape": shape, "mesh": mesh,
                           "applicable": False, "skip_reason": why}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=2)
                    results.append(rec)
                    print(f"SKIP {arch:24s} {shape:12s} {mesh}: {why}",
                          flush=True)
                    continue
                t1 = time.time()
                rec, cached = run_cell(
                    arch, shape, mesh, args.out,
                    extrapolate=(mesh == "pod"),
                    fsdp=(arch in fsdp_archs and shape == "train_4k"),
                    timeout=args.timeout)
                results.append(rec)
                status = "ok" if rec.get("ok") else "FAIL"
                extra = ""
                if rec.get("roofline"):
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" c={r['compute_s']:.3f}s"
                             f" m={r['memory_s']:.3f}s"
                             f" n={r['collective_s']:.3f}s"
                             f" useful={r['useful_ratio']:.2f}")
                print(f"{status:4s} {arch:24s} {shape:12s} {mesh:8s} "
                      f"[{time.time() - t1:5.0f}s{' cached' if cached else ''}]"
                      f"{extra}", flush=True)
    n_ok = sum(1 for r in results if r.get("ok"))
    n_skip = sum(1 for r in results if not r.get("applicable", True))
    n_fail = len(results) - n_ok - n_skip
    summary = {"ok": n_ok, "skipped": n_skip, "failed": n_fail,
               "wall_s": round(time.time() - t0)}
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=2)
    print(json.dumps(summary), flush=True)


if __name__ == "__main__":
    main()
