"""Roofline-term extraction from a lowered/compiled dry-run artifact.

compute term    = HLO_FLOPs_per_device / peak_FLOPs
memory term     = HLO_bytes_per_device / HBM_bw
collective term = collective_bytes_per_device / link_bw

cost_analysis() reports the per-partition (per-chip) SPMD module, so the
per-chip terms above equal the spec's global/(chips x rate) form.
Collective bytes are not in cost_analysis: we parse the compiled HLO and
sum operand bytes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. Result-shape bytes are used as operand
proxy (exact for all-reduce/all-to-all/permute); all-gather operand =
result/group, reduce-scatter operand = result (input side), both
corrected with the parsed replica-group size.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, Optional

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\(?[\w\[\],\s{}:]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device bytes by collective kind (operand-side accounting)."""
    out: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # start/done pairs: count the start only
        shapes, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shapes)
        g = _GROUPS_RE.search(line)
        group = len(g.group(1).split(",")) if g else 1
        if kind == "all-gather" and group > 0:
            nbytes = nbytes // max(group, 1)   # operand = result / group
        out[kind] = out.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": out, "counts": counts,
            "total_bytes": sum(out.values())}


def extrapolate(c1: dict, c2: dict, n_periods: int) -> dict:
    """Exact linear-in-depth cost reconstruction from 1-period and
    2-period unrolled compiles: total(n) = c1 + (n-1) * (c2 - c1).
    Works for flops / bytes / collective bytes (layer costs are additive;
    embedding+head appear in both and cancel in the delta)."""
    out = {}
    for k in set(c1) | set(c2):
        a = float(c1.get(k, 0.0) or 0.0)
        b = float(c2.get(k, 0.0) or 0.0)
        out[k] = a + (n_periods - 1) * (b - a)
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_global: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    dominant: str
    note: str = ""

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(cost: Dict[str, float], coll: Dict[str, Any], *,
            n_devices: int, model_flops_global: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.get("total_bytes", 0.0))
    terms = {
        "compute": flops / PEAK_FLOPS_BF16,
        "memory": nbytes / HBM_BW,
        "collective": cbytes / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    hlo_global = flops * n_devices
    ratio = model_flops_global / hlo_global if hlo_global else 0.0
    return Roofline(
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], flops_per_device=flops,
        bytes_per_device=nbytes, coll_bytes_per_device=cbytes,
        model_flops_global=model_flops_global, useful_ratio=ratio,
        dominant=dominant)


def model_flops(cfg, shape) -> float:
    """6·N·D for training, 2·N·D for inference forward passes (N =
    active params; D = tokens processed in the step). Decode attention's
    KV-scan flops are additionally counted (2·ctx·kvdim per layer·token)."""
    total, active = cfg.count_params()
    if shape.kind == "train":
        return 6.0 * active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * active * shape.seq_len * shape.global_batch
    # decode: one token per sequence + attention over the KV history
    d = 2.0 * active * shape.global_batch
    attn_kv = (2 * 2 * cfg.n_attn_layers * cfg.n_kv_heads * cfg.head_dim
               * (cfg.n_heads // max(cfg.n_kv_heads, 1)))
    d += attn_kv * shape.seq_len * shape.global_batch
    return d
