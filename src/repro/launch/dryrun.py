import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes (16x16 single-pod; 2x16x16 multi-pod) with
ShapeDtypeStruct stand-ins (no allocation), print memory_analysis /
cost_analysis, parse the collective schedule, and emit a JSON record
for EXPERIMENTS.md §Dry-run / §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh pod --out results.json
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_arch, get_shape, shape_applicable  # noqa: E402
from repro.launch import roofline as rl                          # noqa: E402
from repro.launch.mesh import production_ctx                     # noqa: E402
from repro.models import Runtime, build_model                    # noqa: E402
from repro.models import transformer                             # noqa: E402
from repro.training import optimizer as opt                      # noqa: E402
from repro.training.train_loop import TrainState, make_train_step  # noqa: E402


import dataclasses  # noqa: E402


def _sds_with_sharding(model, tree_shapes, specs):
    shardings = model.ctx.tree_shardings(specs, tree_shapes,
                                         fsdp=model.ctx.fsdp_params)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_shapes, shardings)


def build_step(model, shape, *, grad_accum: int = 1):
    """Returns (fn, example_args) ready for jax.jit(fn).lower(*args)."""
    cfg, rt, ctx = model.cfg, model.rt, model.ctx
    pspecs = model.specs()
    pshapes = model.param_shapes()
    params_sds = _sds_with_sharding(model, pshapes, pspecs)
    ins = model.input_specs(shape)

    def in_sds(name):
        s, spec = ins[name]
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=ctx.sharding(spec, s.shape))

    if shape.kind == "train":
        step_fn, _, _ = make_train_step(
            model, opt.AdamWConfig(), grad_accum=grad_accum)
        mu = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                           sharding=s.sharding), params_sds)
        state = TrainState(
            params=params_sds,
            opt_state=opt.OptState(
                step=jax.ShapeDtypeStruct((), jnp.int32), mu=mu,
                nu=jax.tree.map(lambda s: s, mu)))
        batch = {k: in_sds(k) for k in ins if k != "segment_ids"}
        return step_fn, (state, batch)

    if shape.kind == "prefill":
        batch = {k: in_sds(k) for k in ins}

        def prefill_step(params, batch):
            return model.prefill(params, batch)

        return prefill_step, (params_sds, batch)

    # decode
    tokens = in_sds("tokens")
    ctx_lens = in_sds("ctx_lens")
    table = in_sds("block_table")
    caches = {}
    for k in ins:
        if k.startswith("cache/"):
            s, spec = ins[k]
            caches[k.split("/", 1)[1]] = jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=ctx.sharding(spec, s.shape))
    src_valid = in_sds("src_valid") if "src_valid" in ins else None

    def serve_step(params, tokens, caches, ctx_lens, table, src_valid=None):
        return model.decode_step(params, tokens, caches, ctx_lens=ctx_lens,
                                 block_table=table, src_valid=src_valid)

    args = (params_sds, tokens, caches, ctx_lens, table)
    if src_valid is not None:
        args = args + (src_valid,)
    return serve_step, args


def _measure(cfg, shape, ctx, rt_kw, grad_accum):
    """Lower+compile, return (record, compiled artifacts)."""
    model = build_model(cfg, Runtime(**rt_kw), ctx)
    t0 = time.time()
    fn, args = build_step(model, shape, grad_accum=grad_accum)
    with ctx.mesh:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = rl.collective_bytes(compiled.as_text())
    return {
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed",
                                          "transcendentals") if k in cost},
        "collectives": coll,
    }


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             *, grad_accum: int = 1, rt_overrides=None,
             fsdp: bool = False, dim_fallback: bool = False,
             extrapolate: bool = True) -> dict:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch_name, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "applicable": ok}
    if not ok:
        rec["skip_reason"] = why
        return rec
    ctx = production_ctx(multi_pod=multi_pod)
    if fsdp:
        ctx = dataclasses.replace(ctx, fsdp_params=True)
    if dim_fallback:
        ctx = dataclasses.replace(ctx, spec_dim_fallback=True)
    rt_kw = dict(compute_dtype=jnp.bfloat16, param_dtype=jnp.float32,
                 remat="dots", scan_layers=True)
    rt_kw.update(rt_overrides or {})
    # main compile: full depth, scanned layers (compact HLO, real memory)
    main = _measure(cfg, shape, ctx, rt_kw, grad_accum)
    rec.update(main)
    rec["ok"] = True
    rec["n_devices"] = ctx.n_devices
    cost, coll_total = dict(main["cost"]), main["collectives"]["total_bytes"]
    if extrapolate:
        # XLA's cost analysis counts a while-loop body ONCE; reconstruct
        # true depth costs from 1-period and 2-period unrolled compiles.
        period = cfg.period
        n_periods = cfg.n_layers // period
        if n_periods > 1:
            def depth_cfg(k):
                kw = {"n_layers": k * period}
                if cfg.n_enc_layers:
                    kw["n_enc_layers"] = max(1, cfg.n_enc_layers
                                             * k * period // cfg.n_layers)
                return dataclasses.replace(cfg, **kw)

            rt1 = dict(rt_kw, scan_layers=False)
            m1 = _measure(depth_cfg(1), shape, ctx, rt1, grad_accum)
            m2 = _measure(depth_cfg(2), shape, ctx, rt1, grad_accum)
            cost = rl.extrapolate(m1["cost"], m2["cost"], n_periods)
            cb1 = {"total": m1["collectives"]["total_bytes"]}
            cb2 = {"total": m2["collectives"]["total_bytes"]}
            coll_total = rl.extrapolate(cb1, cb2, n_periods)["total"]
            rec["cost_extrapolated"] = cost
            rec["collective_bytes_extrapolated"] = coll_total
            rec["depth_probe"] = {"p1": m1["cost"], "p2": m2["cost"],
                                  "p1_coll": cb1["total"],
                                  "p2_coll": cb2["total"]}
    mf = rl.model_flops(cfg, shape)
    roof = rl.analyze(cost, {"total_bytes": coll_total},
                      n_devices=ctx.n_devices, model_flops_global=mf)
    rec["roofline"] = roof.as_dict()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--kv-chunk", type=int, default=None)
    ap.add_argument("--page-size", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--shard-kv-pages", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--compute-dtype", default=None)
    ap.add_argument("--capacity", type=float, default=None)
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--dim-fallback", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rt_overrides = {}
    if args.q_chunk:
        rt_overrides["q_chunk"] = args.q_chunk
    if args.kv_chunk:
        rt_overrides["kv_chunk"] = args.kv_chunk
    if args.page_size:
        rt_overrides["page_size"] = args.page_size
    if args.remat:
        rt_overrides["remat"] = args.remat
    if args.shard_kv_pages:
        rt_overrides["shard_kv_pool_pages"] = True
    if args.seq_shard:
        rt_overrides["seq_shard_acts"] = True
    if args.compute_dtype:
        rt_overrides["compute_dtype"] = getattr(jnp, args.compute_dtype)
    if args.capacity:
        rt_overrides["capacity_factor"] = args.capacity
    if args.param_dtype:
        rt_overrides["param_dtype"] = getattr(jnp, args.param_dtype)
    try:
        rec = run_cell(args.arch, args.shape, args.mesh == "multipod",
                       grad_accum=args.grad_accum,
                       rt_overrides=rt_overrides, fsdp=args.fsdp,
                       dim_fallback=args.dim_fallback,
                       extrapolate=not args.no_extrapolate)
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    print(json.dumps(rec, indent=2, default=str))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2, default=str)
    if not rec.get("ok", rec.get("applicable", False) is False):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
