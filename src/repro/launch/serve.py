"""Serving launcher: FMMU-paged continuous-batching demo.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.models import Runtime, build_model
from repro.serving.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-ctx", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--host-blocks", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    rt = Runtime(compute_dtype=jnp.float32, param_dtype=jnp.float32,
                 remat="none", page_size=args.page_size,
                 capacity_factor=100.0)
    model = build_model(cfg, rt)
    params = model.init(jax.random.key(args.seed))
    eng = ServeEngine(model, params, n_slots=args.slots,
                      max_ctx=args.max_ctx, n_host_blocks=args.host_blocks)
    rng = np.random.default_rng(args.seed)
    rids = []
    for i in range(args.requests):
        plen = int(rng.integers(8, 48))
        toks = rng.integers(2, cfg.vocab_size, plen).tolist()
        kw = {}
        if cfg.prefix_len:
            kw["prefix_emb"] = 0.02 * jax.random.normal(
                jax.random.key(i), (min(cfg.prefix_len, 8), cfg.d_model))
        if cfg.n_enc_layers:
            kw["src_emb"] = 0.02 * jax.random.normal(
                jax.random.key(100 + i), (32, cfg.d_model))
        rids.append(eng.submit(toks, max_new=args.max_new, **kw))
    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0
    stats = eng.kvm.hit_stats()
    out = {
        "completed": len(done),
        "generated_tokens": eng.metrics["generated"],
        "decode_steps": eng.metrics["decode_steps"],
        "preemptions": eng.metrics["preemptions"],
        "tok_per_s": round(eng.metrics["generated"] / max(wall, 1e-9), 1),
        "fmmu_map": stats,
        "pool_peak_blocks": eng.kvm.pool.stats.peak_used,
    }
    print(json.dumps(out, indent=2))
    for rid in rids[:3]:
        print(f"req {rid}: {done.get(rid, [])[:12]}")
    return done


if __name__ == "__main__":
    main()
