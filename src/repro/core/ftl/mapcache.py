"""Simulation-level map-cache schemes: DFTL [8], CDFTL [24], FMMU.

These model the *cache behaviour* (hit/miss/victim/flush decisions over
real structures) and the *execution cost* (micro-op counts x costmodel)
of each scheme, for the discrete-event SSD simulator. Architectural
correctness of FMMU itself is proven separately (oracle/engine lockstep);
here FMMU's decision logic is a direct reuse of the same CMT/CTP/DTL
policies with hardware pipeline costs.

Interface (driven by core/sim/ssd.py per page-sized sub-request):
  access(dlpn, write) -> AccessPlan(cycles, tp_read, fill_cycles,
                                    flush=FlushWork|None)
The sim owns flash timing; tp_read is the TVPN to fetch when the scheme
misses, fill_cycles the exec charged on arrival. FlushWork carries TP
read-modify-writes (reads skipped when the page is CTP-resident) and
programs to schedule.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Tuple

from repro.core.ftl.costmodel import HW, SW, us


@dataclasses.dataclass
class FlushWork:
    cycles: float
    tp_reads: List[int]
    tp_programs: List[int]


@dataclasses.dataclass
class AccessPlan:
    cycles: float
    tp_read: Optional[int] = None
    fill_cycles: float = 0.0
    flush: Optional[FlushWork] = None


class _SetCache:
    """Set-associative cache of fixed-size blocks with second chance.
    ``dirty_ix`` (group key -> {(s,w)}) is a host-side index so the
    *simulator* can find same-TVPN dirty blocks in O(1); the *simulated*
    software still pays the full scan in cycles (that asymmetry is the
    paper's point)."""

    def __init__(self, n_sets: int, n_ways: int, group_of=None):
        self.n_sets = n_sets
        self.n_ways = n_ways
        self.tag = [[-1] * n_ways for _ in range(n_sets)]
        self.valid = [[False] * n_ways for _ in range(n_sets)]
        self.dirty = [[False] * n_ways for _ in range(n_sets)]
        self.ref = [[False] * n_ways for _ in range(n_sets)]
        self.clock = [0] * n_sets
        self.n_dirty = 0
        self.group_of = group_of or (lambda tag: 0)
        self.dirty_ix = {}

    def _ix_add(self, s, w):
        self.dirty_ix.setdefault(self.group_of(self.tag[s][w]), set()).add((s, w))

    def _ix_del(self, s, w):
        grp = self.group_of(self.tag[s][w])
        members = self.dirty_ix.get(grp)
        if members:
            members.discard((s, w))
            if not members:
                self.dirty_ix.pop(grp, None)

    def probe(self, tag: int) -> Tuple[int, Optional[int]]:
        s = tag % self.n_sets
        for w in range(self.n_ways):
            if self.valid[s][w] and self.tag[s][w] == tag:
                return s, w
        return s, None

    def second_chance(self, s: int) -> Tuple[Optional[int], int]:
        """Returns (way or None, ways_scanned) among non-dirty blocks."""
        scanned = 0
        for i in range(2 * self.n_ways):
            w = (self.clock[s] + i) % self.n_ways
            scanned += 1
            if self.dirty[s][w]:
                continue
            if self.ref[s][w]:
                self.ref[s][w] = False
                continue
            self.clock[s] = (w + 1) % self.n_ways
            return w, scanned
        return None, scanned

    def any_victim(self, s: int) -> Tuple[int, int]:
        """Victim allowing dirty blocks (clean preferred: FMMU fallback)."""
        w, scanned = self.second_chance(s)
        if w is not None:
            return w, scanned
        # all dirty: plain clock over dirty blocks
        w = self.clock[s]
        self.clock[s] = (w + 1) % self.n_ways
        return w, scanned + 1

    def clock_victim(self, s: int) -> Tuple[int, int]:
        """Classic second chance over ALL blocks, dirty or not — the
        DFTL/CDFTL policy (the paper's FMMU §4.4 twist is precisely that
        it restricts victims to non-dirty blocks; baselines do not)."""
        scanned = 0
        for i in range(2 * self.n_ways):
            w = (self.clock[s] + i) % self.n_ways
            scanned += 1
            if self.ref[s][w]:
                self.ref[s][w] = False
                continue
            self.clock[s] = (w + 1) % self.n_ways
            return w, scanned
        w = self.clock[s]
        self.clock[s] = (w + 1) % self.n_ways
        return w, scanned

    def install(self, s: int, w: int, tag: int, dirty: bool):
        if self.dirty[s][w]:
            self.n_dirty -= 1
            self._ix_del(s, w)
        self.tag[s][w] = tag
        self.valid[s][w] = True
        self.ref[s][w] = True
        self.dirty[s][w] = dirty
        if dirty:
            self.n_dirty += 1
            self._ix_add(s, w)

    def set_dirty(self, s: int, w: int):
        if not self.dirty[s][w]:
            self.dirty[s][w] = True
            self.n_dirty += 1
            self._ix_add(s, w)

    def clean(self, s: int, w: int):
        if self.dirty[s][w]:
            self._ix_del(s, w)
            self.dirty[s][w] = False
            self.n_dirty -= 1

    @property
    def blocks(self) -> int:
        return self.n_sets * self.n_ways


class BaseMapCache:
    name = "base"

    def __init__(self, cfg):
        self.cfg = cfg
        self.ec = cfg.cmt_block_entries
        self.ept = cfg.entries_per_tp
        self.stats = {"hit": 0, "miss": 0, "flushes": 0, "tp_reads": 0,
                      "tp_programs": 0, "exec_cycles": 0.0}

    def _done(self, plan: AccessPlan) -> AccessPlan:
        self.stats["exec_cycles"] += plan.cycles + plan.fill_cycles
        if plan.tp_read is not None:
            self.stats["tp_reads"] += 1
        if plan.flush:
            self.stats["tp_programs"] += len(plan.flush.tp_programs)
            self.stats["tp_reads"] += len(plan.flush.tp_reads)
            self.stats["exec_cycles"] += plan.flush.cycles
        return plan


# ======================================================================
class DFTLCache(BaseMapCache):
    """Single-level CMT over all map RAM; batch flush scans the WHOLE
    cache for same-TVPN dirty blocks (no index — the paper's complaint)."""
    name = "dftl"

    def __init__(self, cfg):
        super().__init__(cfg)
        blocks = cfg.map_ram_bytes // (self.ec * cfg.map_entry_bytes)
        bpt = self.ept // self.ec
        self.cmt = _SetCache(blocks // cfg.assoc, cfg.assoc,
                             group_of=lambda t: t // bpt)

    def access(self, dlpn: int, write: bool) -> AccessPlan:
        tag = dlpn // self.ec
        s, w = self.cmt.probe(tag)
        if w is not None:
            self.stats["hit"] += 1
            self.cmt.ref[s][w] = True
            if write:
                self.cmt.set_dirty(s, w)
            cycles = (SW.dispatch + SW.probe_way * self.cmt.n_ways
                      + SW.entry_rw + SW.lru)
            return self._done(AccessPlan(cycles))
        # miss
        self.stats["miss"] += 1
        vic, scanned = self.cmt.clock_victim(s)
        cycles = (SW.dispatch + SW.probe_way * self.cmt.n_ways
                  + SW.sc_pass * scanned + SW.miss_book + SW.issue)
        flush = None
        if self.cmt.dirty[s][vic]:
            flush = self._flush_tvpn(self.cmt.tag[s][vic] * self.ec
                                     // self.ept)
        self.cmt.install(s, vic, tag, dirty=write)
        fill = SW.fill_entry * self.ec + SW.fill_book + SW.lru
        return self._done(AccessPlan(cycles, tp_read=dlpn // self.ept,
                                     fill_cycles=fill, flush=flush))

    def _flush_tvpn(self, tvpn: int) -> FlushWork:
        """Batch update: scan every cache block for dirty blocks of this
        TVPN (cost O(total blocks)), then RMW the translation page."""
        self.stats["flushes"] += 1
        members = list(self.cmt.dirty_ix.get(tvpn, ()))
        for (s, w) in members:
            self.cmt.clean(s, w)
        # software has no index: charge the full O(cache) scan
        cycles = (SW.flush_scan_blk * self.cmt.blocks
                  + SW.flush_blk * len(members) + SW.tp_rmw + SW.issue)
        return FlushWork(cycles, tp_reads=[tvpn], tp_programs=[tvpn])


# ======================================================================
class CDFTLCache(BaseMapCache):
    """Two-level: small CMT + translation-page-sized CTP [24]."""
    name = "cdftl"

    def __init__(self, cfg):
        super().__init__(cfg)
        cmt_blocks = cfg.cmt_ram_bytes // (self.ec * cfg.map_entry_bytes)
        ctp_pages = cfg.ctp_ram_bytes // (self.ept * cfg.map_entry_bytes)
        bpt = self.ept // self.ec
        self.cmt = _SetCache(cmt_blocks // cfg.assoc, cfg.assoc,
                             group_of=lambda t: t // bpt)
        self.ctp = _SetCache(max(1, ctp_pages // cfg.assoc), cfg.assoc)

    def access(self, dlpn: int, write: bool) -> AccessPlan:
        tag = dlpn // self.ec
        s, w = self.cmt.probe(tag)
        if w is not None:
            self.stats["hit"] += 1
            self.cmt.ref[s][w] = True
            if write:
                self.cmt.set_dirty(s, w)
            cycles = (SW.dispatch + SW.probe_way * self.cmt.n_ways
                      + SW.entry_rw + SW.lru)
            return self._done(AccessPlan(cycles))
        self.stats["miss"] += 1
        cycles = SW.dispatch + SW.probe_way * self.cmt.n_ways + SW.l2_book
        flush = None
        vic, scanned = self.cmt.clock_victim(s)
        if self.cmt.dirty[s][vic]:
            flush = self._flush_cmt(self.cmt.tag[s][vic] * self.ec
                                    // self.ept)
        cycles += SW.sc_pass * scanned + SW.lru
        # second level
        tvpn = dlpn // self.ept
        ts, tw = self.ctp.probe(tvpn)
        if tw is not None:
            # CTP hit: copy entries up into CMT
            self.ctp.ref[ts][tw] = True
            self.cmt.install(s, vic, tag, dirty=write)
            cycles += (SW.probe_way * self.ctp.n_ways
                       + SW.fill_entry * self.ec + SW.fill_book)
            return self._done(AccessPlan(cycles))
        # CTP miss: evict a CTP page (program if dirty), read TP from flash
        tvic, tsc = self.ctp.any_victim(ts)
        cycles += (SW.probe_way * self.ctp.n_ways + SW.sc_pass * tsc
                   + SW.miss_book + SW.issue)
        if flush is None and self.ctp.dirty[ts][tvic]:
            self.stats["flushes"] += 1
            flush = FlushWork(SW.tp_rmw + SW.issue, tp_reads=[],
                              tp_programs=[self.ctp.tag[ts][tvic]])
        self.ctp.install(ts, tvic, tvpn, dirty=False)
        self.cmt.install(s, vic, tag, dirty=write)
        fill = SW.fill_entry * self.ec + SW.fill_book
        return self._done(AccessPlan(cycles, tp_read=tvpn, fill_cycles=fill,
                                     flush=flush))

    def _flush_cmt(self, tvpn: int) -> FlushWork:
        """Scan whole CMT for dirty blocks of tvpn; merge into CTP page
        (present or loaded); program later on CTP eviction."""
        self.stats["flushes"] += 1
        members = list(self.cmt.dirty_ix.get(tvpn, ()))
        n = len(members)
        for (s, w) in members:
            self.cmt.clean(s, w)
        reads = []
        ts, tw = self.ctp.probe(tvpn)
        if tw is None:
            tvic, _ = self.ctp.any_victim(ts)
            progs = ([self.ctp.tag[ts][tvic]]
                     if self.ctp.dirty[ts][tvic] else [])
            self.ctp.install(ts, tvic, tvpn, dirty=True)
            reads = [tvpn]
        else:
            progs = []
            self.ctp.set_dirty(ts, tw)
        cycles = (SW.flush_scan_blk * self.cmt.blocks + SW.flush_blk * n
                  + SW.tp_rmw)
        return FlushWork(cycles, tp_reads=reads, tp_programs=progs)


# ======================================================================
class FMMUCache(BaseMapCache):
    """FMMU decision logic (CMT+CTP+DTL, watermark flush, next-links)
    with hardware pipeline costs. Non-blocking behaviour (MSHR merging)
    is realized by the simulator's shared in-flight TP reads; merged
    requesters are charged HW.mshr_log only."""
    name = "fmmu"

    def __init__(self, cfg):
        super().__init__(cfg)
        cmt_blocks = cfg.cmt_ram_bytes // (self.ec * cfg.map_entry_bytes)
        ctp_pages = cfg.ctp_ram_bytes // (self.ept * cfg.map_entry_bytes)
        self.cmt = _SetCache(cmt_blocks // cfg.assoc, cfg.assoc)
        self.ctp = _SetCache(max(1, ctp_pages // cfg.assoc), cfg.assoc)
        # DTL: tvpn -> set of (s,w) dirty blocks (the next-link chains)
        self.dtl: "OrderedDict[int, set]" = OrderedDict()
        self.low = max(1, int(cfg.flush_low_watermark * self.cmt.blocks))
        self.high = max(self.low + 1,
                        int(cfg.flush_high_watermark * self.cmt.blocks))

    def access(self, dlpn: int, write: bool) -> AccessPlan:
        tag = dlpn // self.ec
        s, w = self.cmt.probe(tag)
        flush = self._maybe_flush()
        if w is not None:
            self.stats["hit"] += 1
            self.cmt.ref[s][w] = True
            if write and not self.cmt.dirty[s][w]:
                self.cmt.set_dirty(s, w)
                self._dtl_add(dlpn // self.ept, s, w)
            return self._done(AccessPlan(HW.cmt_packet, flush=flush))
        self.stats["miss"] += 1
        vic, _ = self.cmt.second_chance(s)
        if vic is None:
            fw = self._flush_tvpn_of_set(s)
            if flush is None:
                flush = fw
            elif fw:
                flush.cycles += fw.cycles
                flush.tp_reads += fw.tp_reads
                flush.tp_programs += fw.tp_programs
            vic, _ = self.cmt.second_chance(s)
            if vic is None:
                vic, _ = self.cmt.any_victim(s)
        tvpn = dlpn // self.ept
        ts, tw = self.ctp.probe(tvpn)
        if tw is not None:
            self.ctp.ref[ts][tw] = True
            self.cmt.install(s, vic, tag, dirty=write)
            if write:
                self._dtl_add(tvpn, s, vic)
            return self._done(AccessPlan(HW.cmt_packet + HW.ctp_packet,
                                         flush=flush))
        tvic, _ = self.ctp.any_victim(ts)
        progs = []
        if self.ctp.dirty[ts][tvic]:
            progs = [self.ctp.tag[ts][tvic]]
            self.stats["flushes"] += 1
        self.ctp.install(ts, tvic, tvpn, dirty=False)
        self.cmt.install(s, vic, tag, dirty=write)
        if write:
            self._dtl_add(tvpn, s, vic)
        if progs:
            pf = FlushWork(HW.fc_issue, [], progs)
            if flush is None:
                flush = pf
            else:
                flush.cycles += pf.cycles
                flush.tp_programs += progs
        return self._done(AccessPlan(
            HW.cmt_packet + HW.ctp_packet + HW.fc_issue,
            tp_read=tvpn, fill_cycles=HW.ctp_packet + HW.cmt_packet,
            flush=flush))

    def merged_cycles(self) -> float:
        """Cost charged to a request that merges into an in-flight miss."""
        return HW.cmt_packet + HW.mshr_log

    # ----------------------------------------------------------------
    def _dtl_add(self, tvpn: int, s: int, w: int):
        self.dtl.setdefault(tvpn, set()).add((s, w))

    def _maybe_flush(self) -> Optional[FlushWork]:
        nondirty = self.cmt.blocks - self.cmt.n_dirty
        if nondirty >= self.low or not self.dtl:
            return None
        work = FlushWork(0.0, [], [])
        while (self.cmt.blocks - self.cmt.n_dirty) < self.high and self.dtl:
            tvpn = max(self.dtl, key=lambda t: len(self.dtl[t]))  # greedy
            w2 = self._flush_chain(tvpn)
            work.cycles += w2.cycles
            work.tp_reads += w2.tp_reads
            work.tp_programs += w2.tp_programs
        self.stats["flushes"] += 1
        return work

    def _flush_tvpn_of_set(self, s: int) -> Optional[FlushWork]:
        for w in range(self.cmt.n_ways):
            if self.cmt.dirty[s][w]:
                tvpn = self.cmt.tag[s][w] * self.ec // self.ept
                if tvpn in self.dtl:
                    return self._flush_chain(tvpn)
        return None

    def _flush_chain(self, tvpn: int) -> FlushWork:
        """Walk next-links: O(dirty blocks of tvpn), not O(cache)."""
        chain = self.dtl.pop(tvpn, set())
        n = 0
        for (s, w) in chain:
            if self.cmt.dirty[s][w]:
                self.cmt.clean(s, w)
                n += 1
        cycles = HW.flush_base + HW.flush_blk * n
        # merge into CTP (load if absent — hardware RMW), mark dirty;
        # the program happens on CTP eviction or watermark
        ts, tw = self.ctp.probe(tvpn)
        reads: List[int] = []
        progs: List[int] = []
        if tw is None:
            tvic, _ = self.ctp.any_victim(ts)
            if self.ctp.dirty[ts][tvic]:
                progs = [self.ctp.tag[ts][tvic]]
            self.ctp.install(ts, tvic, tvpn, dirty=True)
            reads = [tvpn]
        else:
            self.ctp.set_dirty(ts, tw)
        return FlushWork(cycles, reads, progs)


SCHEMES = {"dftl": DFTLCache, "cdftl": CDFTLCache, "fmmu": FMMUCache}
