"""Micro-op cost model for map-cache execution time (Fig. 10 reproduction).

The paper measures DFTL/CDFTL on a 400MHz Cortex-R4 in gem5 and FMMU via
HLS at the same clock. Offline we cannot run gem5/HLS, so each scheme
counts its primitive operations and multiplies by the per-op cycle costs
below. The constants were calibrated ONCE against the paper's reported
anchors (DFTL hit 1.5us/1-core, CDFTL CMT-miss-CTP-hit 4us/1-core, FMMU
0.16us, T_FTL_cmd 0.2us, DFTL miss ~3x hit, FMMU flush <=10us) and are
held fixed for every other experiment; benchmarks/fig10 reports the
achieved match (all anchors within ~12%).
"""
from __future__ import annotations

import dataclasses

CLOCK_MHZ = 400.0


@dataclasses.dataclass(frozen=True)
class SwCosts:
    """Software FTL (per-op cycles on the embedded core)."""
    dispatch: int = 150        # request dequeue, decode, function dispatch
    probe_way: int = 25        # tag load + compare per way
    entry_rw: int = 8          # read/write one mapping entry
    lru: int = 342             # LRU/second-chance list maintenance per hit
    sc_pass: int = 60          # second-chance scan per way pass
    fill_entry: int = 5        # copy one entry on fill
    fill_book: int = 60        # fill bookkeeping
    miss_book: int = 900       # pend/blocked-request management on miss
    l2_book: int = 750         # CDFTL two-level list bookkeeping on CMT miss
    issue: int = 80            # NAND command generation (T_FTL_cmd ~= 0.2us)
    flush_scan_blk: float = 3.5  # per cache block scanned looking for
    #                              same-TVPN dirty blocks (DFTL/CDFTL)
    flush_blk: int = 40        # per dirty block merged into the TP
    tp_rmw: int = 200          # read-modify-write assembly of a TP


@dataclasses.dataclass(frozen=True)
class HwCosts:
    """FMMU hardware pipeline (cycles at the same 400MHz clock)."""
    cmt_packet: int = 64       # full CMT pipeline pass (probe+apply+resp)
    ctp_packet: int = 40       # CTP pipeline pass
    fc_issue: int = 24         # flash command generation
    mshr_log: int = 8          # in-cache MSHR append
    flush_base: int = 64       # DTL victim selection
    flush_blk: int = 24        # per chained dirty block (next-link walk)
    pipeline_ii: int = 16      # initiation interval: the FMMU pipeline
    #                            accepts a new packet every II cycles;
    #                            plan.cycles is end-to-end latency


SW = SwCosts()
HW = HwCosts()


def us(cycles: float) -> float:
    return cycles / CLOCK_MHZ
