"""Batched ("superscalar") FMMU translation engine — the TPU adaptation.

The paper's FMMU processes one packet per pipeline slot; a TPU is a wide
vector machine, so the serving integration translates a whole request
batch per step:

  * all CMT probes in parallel (kernels/fmmu_translate Pallas kernel);
  * MSHR semantics become sort-based *miss dedup*: all misses to the
    same cache block are served by ONE backing-store gather (exactly the
    paper's "one flash read serves many merged requests");
  * per-set insertion honours associativity: at most W distinct new
    blocks enter a set per batch step, surplus misses are served
    uncached (no-allocate overflow) — a deterministic, vectorized
    stand-in for the sequential second-chance walk;
  * the batch path is WRITE-THROUGH (backing is HBM/host RAM, where a
    scatter is cheap), unlike the flash-faithful write-back+DTL FSM in
    engine.py. Recorded as a hardware-adaptation decision in DESIGN.md
    ("Fused translate pipeline").

Fused translate pipeline (DESIGN.md)
------------------------------------
``translate_batch`` is the single entry point: it services a *mixed*
batch of LOOKUP / UPDATE / COND_UPDATE ops — the paper's arbiter
multiplexes all request sources through one shared pipeline — with the
**single-probe invariant**: exactly ONE CMT probe (one
``ops.fmmu_translate`` call: probe + backing fallback + ref-bit touch in
one kernel) and ONE insert pass (one stable lexicographic segment-sort)
per batch, regardless of the op mix. The pre-fusion path re-probed up to
three times per GC relocation (CondUpdate = lookup-probe + update-probe
+ insert x2) and paid two full sorts per insert; it is preserved below
as ``*_unfused`` for equivalence tests and benchmarking.

``lookup_batch`` / ``update_batch`` / ``cond_update_batch`` remain as
thin wrappers over ``translate_batch`` so existing callers and the
lockstep tests keep passing.

Mixed-batch semantics: all lanes *read* the pre-batch mapping; all
writes (UPDATE lanes, and COND_UPDATE lanes whose old_dppn check
passes) apply together afterwards. Duplicate *write* dlpns within one
batch remain a caller contract violation (the paging layer allocates
uniquely); duplicate cache *blocks* in one batch are fine and are
MSHR-merged into a single fill.

State is a small pytree usable inside jit/shard_map; the backing table
plays the role of flash-resident translation pages + GTD.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.counters import COUNTERS
from repro.core.fmmu.types import (COND_UPDATE, FMMUGeometry, HOST_BASE,
                                   LOOKUP, NIL, UPDATE)
from repro.kernels import ops

I = jnp.int32
BIG = jnp.iinfo(jnp.int32).max

# Trace-time instrumentation: bumped once per CMT probe / insert pass
# *traced* into a graph (not per execution). tests/test_fmmu_batch.py
# asserts the fused path traces exactly one of each per batch. The
# names alias registry cells (same list objects), so both the legacy
# `PROBE_TRACES[0]` idiom and `COUNTERS.snapshot()` observe them.
PROBE_TRACES = COUNTERS.cell("fmmu.probe_traces")
INSERT_TRACES = COUNTERS.cell("fmmu.insert_traces")


class BatchFMMUState(NamedTuple):
    tags: jnp.ndarray      # [S,W] block id or NIL
    valid: jnp.ndarray     # [S,W] bool
    ref: jnp.ndarray       # [S,W] bool (second-chance approximation)
    clock: jnp.ndarray     # [S]
    data: jnp.ndarray      # [S,W,E]
    backing: jnp.ndarray   # [n_tvpns * entries_per_tp] full map table
    stats: jnp.ndarray     # [4] hits, misses, unique_fills, updates


def init_batch_state(g: FMMUGeometry) -> BatchFMMUState:
    return BatchFMMUState(
        tags=jnp.full((g.cmt_sets, g.cmt_ways), NIL, I),
        valid=jnp.zeros((g.cmt_sets, g.cmt_ways), bool),
        ref=jnp.zeros((g.cmt_sets, g.cmt_ways), bool),
        clock=jnp.zeros((g.cmt_sets,), I),
        data=jnp.full((g.cmt_sets, g.cmt_ways, g.cmt_entries), NIL, I),
        backing=jnp.full((g.n_tvpns * g.entries_per_tp,), NIL, I),
        stats=jnp.zeros((4,), jnp.int64 if jax.config.jax_enable_x64 else I),
    )


def _n_blocks(g: FMMUGeometry) -> int:
    return g.n_tvpns * g.entries_per_tp // g.cmt_entries


def _insert_blocks(g: FMMUGeometry, st: BatchFMMUState, miss_bids, prio):
    """Insert up to W distinct missing blocks per set (vectorized).

    miss_bids [Bq] block ids (BIG = no miss); prio [Bq] insert-order
    class = the legacy pass index (LOOKUP=0, UPDATE=1, COND_UPDATE=2) so
    a fused mixed batch fills ways in exactly the order the unfused
    three-call sequence would.

    One segment-sort on the packed lexicographic key (set, prio,
    block id) replaces the old two full sort passes. Since the block id
    determines its set (set = bid mod S) and duplicate block ids first
    collapse to one priority class via a scatter-min over the block-id
    space (MSHR merge), the three key components pack into a single
    int32 — key = (set*4 + prio) * ceil(NB/S) + bid//S — so the sort is
    a cheap single-operand sort (XLA's variadic comparator sorts are an
    order of magnitude slower on CPU) and set, priority, and block id
    are all recovered arithmetically from the sorted keys. Equal keys
    are exactly the duplicate block ids, giving the dedup mask by
    adjacency; set segments give the per-set insertion rank.
    """
    INSERT_TRACES[0] += 1
    s_cnt, w_cnt = g.cmt_sets, g.cmt_ways
    q_cap = -(-_n_blocks(g) // s_cnt)
    assert 4 * q_cap * (s_cnt + 1) < BIG, "packed insert key overflows"
    is_miss = miss_bids != BIG
    safe_bid = jnp.where(is_miss, miss_bids, 0)
    # collapse priority per block id (scatter-min): duplicates of one
    # block always carry the same key and therefore sort adjacently
    pbuf = jnp.full((_n_blocks(g),), 3, I).at[safe_bid].min(
        jnp.where(is_miss, prio, 3).astype(I), mode="drop")
    prio_eff = pbuf[safe_bid]
    key = ((jnp.mod(safe_bid, s_cnt) * 4 + prio_eff) * q_cap
           + safe_bid // s_cnt)
    gkey = jnp.sort(jnp.where(is_miss, key, BIG))
    gsets = jnp.where(gkey != BIG, gkey // (4 * q_cap), s_cnt).astype(I)
    gbids = jnp.where(gkey != BIG,
                      jnp.mod(gkey, q_cap) * s_cnt + gsets, BIG)
    first = jnp.concatenate([jnp.array([True]), gkey[1:] != gkey[:-1]])
    kept = first & (gsets < s_cnt)
    # rank within the set segment, counting kept (unique) entries only
    cf = jnp.cumsum(kept.astype(I)) - kept          # exclusive prefix
    counts = jnp.bincount(gsets, length=s_cnt + 1)
    offs = jnp.cumsum(counts) - counts              # segment starts
    seg_start = jnp.clip(offs[jnp.clip(gsets, 0, s_cnt)], 0,
                         gsets.shape[0] - 1)
    rank = cf - cf[seg_start]
    keep = kept & (rank < w_cnt)
    way = jnp.mod(st.clock[jnp.clip(gsets, 0, s_cnt - 1)] + rank, w_cnt)
    # gather fresh block contents from backing
    base = jnp.where(keep, gbids, 0) * g.cmt_entries
    idx = base[:, None] + jnp.arange(g.cmt_entries)[None, :]
    fresh = st.backing[jnp.clip(idx, 0, st.backing.shape[0] - 1)]
    flat = jnp.where(keep, gsets * w_cnt + way, s_cnt * w_cnt)  # OOB -> drop
    tags = st.tags.reshape(-1).at[flat].set(
        jnp.where(keep, gbids, 0).astype(I), mode="drop").reshape(s_cnt, w_cnt)
    valid = st.valid.reshape(-1).at[flat].set(True, mode="drop").reshape(
        s_cnt, w_cnt)
    ref = st.ref.reshape(-1).at[flat].set(True, mode="drop").reshape(
        s_cnt, w_cnt)
    data = st.data.reshape(-1, g.cmt_entries).at[flat].set(
        fresh.astype(I), mode="drop").reshape(s_cnt, w_cnt, g.cmt_entries)
    ins_per_set = jnp.bincount(jnp.where(keep, gsets, s_cnt),
                               length=s_cnt + 1)[:s_cnt]
    clock = jnp.mod(st.clock + ins_per_set, w_cnt)
    n_fill = keep.sum()
    return st._replace(tags=tags, valid=valid, ref=ref, data=data,
                       clock=clock,
                       stats=st.stats.at[2].add(n_fill)), n_fill


def translate_batch(g: FMMUGeometry, st: BatchFMMUState, opcodes, dlpns,
                    dppns, old_dppns, impl=None
                    ) -> Tuple[BatchFMMUState, jnp.ndarray, jnp.ndarray]:
    """Fused mixed-op translate: ONE CMT probe, ONE insert pass.
    Thin wrapper over _translate_core (drops the commit mask).

    opcodes [Bq] in {LOOKUP, UPDATE, COND_UPDATE}; dlpns [Bq]
    (-1 = inactive lane); dppns [Bq] new mapping for write lanes;
    old_dppns [Bq] compare value for COND_UPDATE lanes.

    Returns (state, out [Bq], ok [Bq] bool):
      * out: the pre-batch mapping of dlpn (NIL when unmapped/inactive)
        — for LOOKUP lanes this is the translation result;
      * ok:  for COND_UPDATE lanes, whether the guarded write applied
        (mapping still equalled old_dppn); `active` for other lanes.
    """
    st, out, ok, _ = _translate_core(g, st, opcodes, dlpns, dppns,
                                     old_dppns, impl=impl)
    return st, out, ok


def _translate_core(g: FMMUGeometry, st: BatchFMMUState, opcodes, dlpns,
                    dppns, old_dppns, impl=None):
    """translate_batch body; additionally returns the commit mask
    `write` (lanes whose dppn actually entered the map) so wrappers
    like translate_serving share ONE definition of what committed."""
    PROBE_TRACES[0] += 1
    active = dlpns >= 0
    is_l = opcodes == LOOKUP
    is_u = opcodes == UPDATE
    is_c = opcodes == COND_UPDATE
    # probed lanes (LOOKUP + COND) are the ones that count hit/miss
    # stats AND touch the ref bit on a hit — one binding, used for both
    probed = active & (is_l | is_c)
    # one fused kernel: probe + backing fallback + ref-bit touch
    hit, cur, set_idx, way, refbits = ops.fmmu_translate(
        st.tags, st.valid, st.ref, st.data, st.backing, dlpns, probed,
        entries_per_block=g.cmt_entries, impl=impl)
    ok = jnp.where(is_c, active & (cur == old_dppns), active)
    write = (is_u & active) | (is_c & ok)
    # write-through to the backing table
    safe = jnp.where(write, dlpns, st.backing.shape[0])
    backing = st.backing.at[safe].set(dppns.astype(I), mode="drop")
    # update cached copies where the block is resident
    off = jnp.mod(jnp.where(active, dlpns, 0), g.cmt_entries)
    flat = (set_idx * g.cmt_ways + way) * g.cmt_entries + off
    flat = jnp.where(write & hit, flat, st.data.size)
    data = st.data.reshape(-1).at[flat].set(
        dppns.astype(I), mode="drop").reshape(st.data.shape)
    stats = (st.stats.at[0].add((probed & hit).sum())
             .at[1].add((probed & ~hit).sum())
             .at[3].add(write.sum()))
    st = st._replace(backing=backing, data=data, ref=refbits, stats=stats)
    # single insert pass for every miss, MSHR-merged; write-allocate for
    # UPDATE/COND lanes pulls post-write backing contents
    miss_bids = jnp.where(active & ~hit, dlpns // g.cmt_entries, BIG)
    prio = jnp.where(is_l, 0, jnp.where(is_u, 1, 2)).astype(I)
    st, _ = _insert_blocks(g, st, miss_bids, prio)
    return st, jnp.where(active, cur, NIL), ok, write


# ------------------------------------------------------ serving wrapper
class ServingMapState(NamedTuple):
    """FMMU state + the device-resident serving block table + allocator.

    ``table`` [n_tvpns * entries_per_tp] holds the *current* dlpn->dppn
    mapping (NIL when unmapped) and is maintained incrementally by
    ``translate_serving`` inside the same fused jitted call that
    commits each map write — coherent with the map by construction, so
    serving-layer readers never trigger a full-map retranslation
    (DESIGN.md "Device-resident incremental block table").

    The free-list allocator (DESIGN.md "Device-resident block
    allocator") is a pair of tier stacks + head counts, a member of the
    same pytree so decode macro-steps can allocate KV blocks and commit
    their mappings without leaving the jit. ``free_stack[:free_n]`` are
    the free device-tier block ids, top of stack at ``free_n - 1``;
    ``host_stack``/``host_n`` mirror the host tier. Stack order mirrors
    the host ``BlockPool`` free list exactly (list index i == stack
    index i), so host-side reconciliation replays device pops
    bit-for-bit. ``oob`` is the sticky OutOfBlocks *flag lane*: a
    failed in-graph alloc sets it instead of raising, and the host
    falls back to single-step mode when it reads the flag.

    Detection latency (ISSUE 6): the flag is written in-graph but only
    *observable* at a host sync — a K-step macro scan that runs a
    channel dry at scan step j surfaces the failure at the boundary,
    up to K tokens after the fact. Stickiness is what makes the
    deferred read lossless: the flag cannot un-set until the host
    acknowledges it (``set_allocator`` clears it during the resync).
    Hosts fold observed flags into the typed per-channel exhaustion
    counts via ``KVPageManager.observe_exhaustion`` (read through
    ``oob_vec`` — per-channel at C>1, where each shard raises its own
    flag and a silent wedge would otherwise hide real pool pressure).

    ``swap_pending`` [n_lanes] is the host-tier residency lane
    (DESIGN.md "Non-blocking host-tier swap pipeline"): True while a
    serving slot's KV pages live in the host tier (swapped out, or a
    swap still in flight). It is flipped by the same fused jitted call
    that commits a swap's CondUpdate map writes and moves the pool
    rows (``mark_swap`` riding KVPageManager's swap op), so the decode
    macro-scan can mask swap-pending slots as paused lanes from its
    own state — swaps overlap decode instead of dropping the engine
    out of the fused path.

    ``commit_seq`` is the per-commit sequence lane (ISSUE 7): a
    monotone count of committed map-write LANES, bumped by
    ``translate_serving`` with the same ``write`` mask that scatters
    the table — so every committed (dlpn -> block) write has a unique
    position in the channel's commit order, whichever batching
    (single-step, macro scan, sharded pre-commit) carried it. The host
    journal stamps its records with the same cumulative count; at a
    snapshot boundary the two must agree (the crash-consistency
    integrity check), and the on-disk OOB region's (dlpn, seq) owners
    are ordered by it — the newest mapping of a dlpn is the max-seq
    one, which is what the SPOR reverse-map scan reconstructs when the
    journal tail is torn.

    ``live`` is the OPTIONAL per-device-block live-page count lane (the
    GC walk's input — the paper's GCM reads hardware-maintained
    validity counts instead of scanning the map). ``None`` by default:
    None is an empty pytree node, so a state without live tracking
    traces to the exact pre-GC graph (jaxpr-identical, asserted in
    tests/test_gc.py). When enabled it is a [n_device_blocks] int32
    vector maintained by ``translate_serving`` inside the SAME fused
    commit that scatters the table — two scatter-adds keyed on the
    core's ``write`` mask, no extra probe and no extra sort. Host-tier
    blocks are never counted (only the device tier is the flash
    analogue the GC walks).

    ``refcnt`` is the OPTIONAL per-device-block reference-count lane
    (ISSUE 10 — prefix sharing): how many logical pages (dlpns)
    currently map each device block. Same construction as ``live``:
    None by default (an absent pytree leaf, so sharing-off traces the
    exact pre-sharing graph — jaxpr-identical, asserted in
    tests/test_prefix.py), and when enabled it is maintained by
    ``translate_serving`` inside the SAME fused commit with the same
    ``write`` mask — no extra probe, no extra sort. Without sharing
    every count is 0 or 1 (the map is injective); prefix sharing maps
    B slots' prompt pages at ONE block, driving its count to B, and
    the pool must not reclaim a block until its count returns to 0.
    ``live`` and ``refcnt`` stay separate lanes because they arm
    independently (gc on/off x sharing on/off) even though both ride
    the identical scatter-add skeleton."""
    fmmu: BatchFMMUState
    table: jnp.ndarray
    free_stack: jnp.ndarray   # [n_device] int32 free device block ids
    free_n: jnp.ndarray       # [] int32 live stack depth
    host_stack: jnp.ndarray   # [n_host] int32 free host block ids
    host_n: jnp.ndarray       # [] int32
    oob: jnp.ndarray          # [] bool, sticky OutOfBlocks flag
    swap_pending: jnp.ndarray  # [n_lanes] bool host-tier residency lane
    commit_seq: jnp.ndarray = jnp.asarray(0, I)  # [] int32 commit lanes
    live: Optional[jnp.ndarray] = None  # [n_device] int32 live pages
    refcnt: Optional[jnp.ndarray] = None  # [n_device] int32 mapping refs


def init_serving_state(g: FMMUGeometry, n_device_blocks: int = 0,
                       n_host_blocks: int = 0, n_lanes: int = 0,
                       track_live: bool = False,
                       track_refs: bool = False) -> ServingMapState:
    # stack mirrors BlockPool.__init__: list(range(n))[::-1], so index i
    # holds block n-1-i and the first pop yields block 0
    return ServingMapState(
        fmmu=init_batch_state(g),
        table=jnp.full((g.n_tvpns * g.entries_per_tp,), NIL, I),
        free_stack=jnp.arange(n_device_blocks - 1, -1, -1, dtype=I),
        free_n=jnp.asarray(n_device_blocks, I),
        host_stack=jnp.arange(HOST_BASE + n_host_blocks - 1,
                              HOST_BASE - 1, -1, dtype=I),
        host_n=jnp.asarray(n_host_blocks, I),
        oob=jnp.asarray(False),
        swap_pending=jnp.zeros((n_lanes,), bool),
        commit_seq=jnp.asarray(0, I),
        live=(jnp.zeros((n_device_blocks,), I) if track_live else None),
        refcnt=(jnp.zeros((n_device_blocks,), I) if track_refs
                else None))


def oob_vec(ms: ServingMapState) -> jnp.ndarray:
    """The sticky OutOfBlocks flag lane as a [C] vector ([1] for the
    unsharded state, whose flag is a scalar): the ONE home of the
    flag-read layout, so every boundary observer (engine, tests,
    KVPageManager.observe_exhaustion) indexes channels identically."""
    return jnp.atleast_1d(ms.oob)


def live_vec(ms: ServingMapState) -> jnp.ndarray:
    """Global per-device-block live-page counts as an [n_device] vector
    — the ONE home of the cross-channel combine for the live lane. A
    channel-stacked state carries [C, n_device] per-shard counts over
    GLOBAL block ids (each shard only touches blocks it owns), so the
    global view is the plain sum over the channel axis. Requires live
    tracking (``ms.live is not None``)."""
    assert ms.live is not None, "live tracking is off for this state"
    return ms.live if ms.live.ndim == 1 else ms.live.sum(0)


def refcount_vec(ms: ServingMapState) -> jnp.ndarray:
    """Global per-device-block mapping reference counts as an
    [n_device] vector — the refcnt lane's ``live_vec`` twin. A
    channel-stacked state carries [C, n_device] per-shard counts over
    GLOBAL block ids (a shared block and every dlpn mapping it stripe
    to the same channel, so exactly one shard counts it); the global
    view is the sum over the channel axis. Requires ref tracking
    (``ms.refcnt is not None``)."""
    assert ms.refcnt is not None, "ref tracking is off for this state"
    return ms.refcnt if ms.refcnt.ndim == 1 else ms.refcnt.sum(0)


def commit_seq_vec(ms: ServingMapState) -> jnp.ndarray:
    """The per-commit sequence lane as a [C] vector ([1] unsharded) —
    one read layout for every boundary observer, like ``oob_vec``. The
    journal integrity check compares its SUM against the cumulative
    committed-lane count of the journal records (ISSUE 7)."""
    return jnp.atleast_1d(ms.commit_seq)


# ------------------------------------------------- device allocator ops
def alloc_serving(ms: ServingMapState, want
                  ) -> Tuple[ServingMapState, jnp.ndarray, jnp.ndarray]:
    """Pop one device-tier block per requesting lane (pure transition).

    want [B] bool. Lanes pop in index order: lane with rank r among the
    requesters receives ``free_stack[free_n - 1 - r]`` — exactly the
    order the host ``BlockPool.alloc`` would pop, so the two stay
    mirrors. When the stack runs dry, later-ranked lanes FAIL (ok
    False, block NIL) and the sticky ``oob`` flag is raised — the
    in-graph replacement for the host-side OutOfBlocks raise.

    Returns (state, blocks [B] int32 (NIL on fail), ok [B] bool)."""
    want = want.astype(bool)
    rank = jnp.cumsum(want.astype(I)) - want.astype(I)
    idx = ms.free_n - 1 - rank
    ok = want & (idx >= 0)
    cap = ms.free_stack.shape[0]
    picked = (ms.free_stack[jnp.clip(idx, 0, cap - 1)] if cap
              else jnp.full(want.shape, NIL, I))
    blocks = jnp.where(ok, picked, NIL)
    return ms._replace(
        free_n=ms.free_n - ok.sum().astype(I),
        oob=ms.oob | (want & ~ok).any()), blocks, ok


def free_serving(ms: ServingMapState, blocks) -> ServingMapState:
    """Push blocks back onto their tier stacks (pure transition).

    blocks [B] int32, NIL lanes ignored; tier routed by HOST_BASE.
    Push order is lane-index order, mirroring sequential
    ``BlockPool.free`` appends."""
    valid = blocks >= 0
    is_host = valid & (blocks >= HOST_BASE)
    is_dev = valid & ~is_host
    drank = jnp.cumsum(is_dev.astype(I)) - is_dev.astype(I)
    hrank = jnp.cumsum(is_host.astype(I)) - is_host.astype(I)
    didx = jnp.where(is_dev, ms.free_n + drank, ms.free_stack.shape[0])
    hidx = jnp.where(is_host, ms.host_n + hrank, ms.host_stack.shape[0])
    return ms._replace(
        free_stack=ms.free_stack.at[didx].set(blocks, mode="drop"),
        free_n=ms.free_n + is_dev.sum().astype(I),
        host_stack=ms.host_stack.at[hidx].set(blocks, mode="drop"),
        host_n=ms.host_n + is_host.sum().astype(I))


def set_allocator(ms: ServingMapState, free_stack, free_n, host_stack,
                  host_n, swap_pending=None) -> ServingMapState:
    """Overwrite the allocator tiers from the (authoritative) host pool
    and clear the OutOfBlocks flag — the macro-step-boundary resync.
    ``swap_pending`` (optional) refreshes the residency lane from the
    host's page-tier bookkeeping in the same call (host-side frees of
    swapped-out slots leave the lane stale until the next sync)."""
    return ms._replace(
        free_stack=jnp.asarray(free_stack, I),
        free_n=jnp.asarray(free_n, I),
        host_stack=jnp.asarray(host_stack, I),
        host_n=jnp.asarray(host_n, I),
        oob=jnp.asarray(False),
        swap_pending=(ms.swap_pending if swap_pending is None
                      else jnp.asarray(swap_pending, bool)))


def mark_swap(ms: ServingMapState, lane, pending) -> ServingMapState:
    """Flip one slot's host-tier residency lane (pure transition).
    Rides the fused swap jit in KVPageManager: the lane, the CondUpdate
    map commits, and the pool-row moves all advance in ONE donated
    call, so the macro scan's view of who is swap-pending can never
    race the data movement it masks."""
    return ms._replace(
        swap_pending=ms.swap_pending.at[lane].set(pending))


def serving_grow(g: FMMUGeometry, ms: ServingMapState, grow, dlpns,
                 impl=None
                 ) -> Tuple[ServingMapState, jnp.ndarray, jnp.ndarray]:
    """Device-side page growth: one alloc + one fused map commit.

    grow [B] bool lanes wanting one new block for logical page dlpns[B].
    Pops from the device free stack (``alloc_serving``) and commits the
    new dlpn->block mappings through the single-probe fused translate
    path (``translate_serving``) — allocator, map, table and block
    table all advance coherently inside one jit; lanes that could not
    be served leave every structure untouched and raise the ``oob``
    flag. Returns (state, blocks [B], ok [B])."""
    ms, blocks, ok = alloc_serving(ms, grow)
    dl = jnp.where(ok, dlpns, -1).astype(I)
    opc = jnp.full(dl.shape, UPDATE, I)
    ms, _, _ = translate_serving(g, ms, opc, dl, blocks,
                                 jnp.zeros_like(dl), impl=impl)
    return ms, blocks, ok


def translate_serving(g: FMMUGeometry, ms: ServingMapState, opcodes,
                      dlpns, dppns, old_dppns, impl=None
                      ) -> Tuple[ServingMapState, jnp.ndarray, jnp.ndarray]:
    """``translate_batch`` + incremental block-table maintenance.

    Single-probe invariant preserved (the table scatter adds no probe
    and no sort). Exactly the lanes whose write committed to the map
    (the core's own `write` mask: UPDATE, and COND_UPDATE whose
    old_dppn guard passed) scatter their new dppn into ``ms.table``;
    all other lanes leave it untouched.

    When the optional ``live`` lane is enabled, the SAME `write` mask
    maintains per-device-block live-page counts (the GC walk's input):
    a committed lane decrements the block it unmapped (``out``, the
    pre-batch mapping) and increments the block it mapped (``dppns``),
    each gated to the device tier — host blocks and NIL never count.
    Two scatter-adds, no probe, no sort; live=None traces nothing."""
    st, out, ok, write = _translate_core(g, ms.fmmu, opcodes, dlpns,
                                         dppns, old_dppns, impl=impl)
    safe = jnp.where(write, dlpns, ms.table.shape[0])
    table = ms.table.at[safe].set(dppns.astype(I), mode="drop")
    live = ms.live
    if live is not None:
        nb = live.shape[0]
        dec = write & (out >= 0) & (out < nb)
        inc = write & (dppns >= 0) & (dppns < nb)
        live = (live.at[jnp.where(dec, out, nb)].add(-1, mode="drop")
                    .at[jnp.where(inc, dppns, nb)].add(1, mode="drop"))
    # refcnt lane (ISSUE 10): same skeleton, same `write` mask — a
    # committed lane drops a reference on the block it unmapped and
    # takes one on the block it mapped. Sharing B slots' prompt pages
    # at one block is then just B ordinary UPDATE commits of different
    # dlpns to the same dppn: the lane counts to B with no special
    # casing, and COW/free paths read it back through refcount_vec.
    refcnt = ms.refcnt
    if refcnt is not None:
        nb = refcnt.shape[0]
        dec = write & (out >= 0) & (out < nb)
        inc = write & (dppns >= 0) & (dppns < nb)
        refcnt = (refcnt.at[jnp.where(dec, out, nb)].add(-1, mode="drop")
                        .at[jnp.where(inc, dppns, nb)].add(1, mode="drop"))
    # per-commit sequence lane (ISSUE 7): count committed write LANES,
    # not calls — K single steps, one macro scan, or one sharded
    # pre-commit of the same growth advance the lane identically, so
    # the host journal's cumulative record count can be checked against
    # it at any snapshot boundary regardless of batching
    return ms._replace(fmmu=st, table=table, live=live, refcnt=refcnt,
                       commit_seq=ms.commit_seq + write.sum().astype(I)
                       ), out, ok


# ----------------------------------------------- channel-sharded wrapper
# ISSUE-5: the paper's headline claim is that the FMMU scales to a
# 32-channel / 8-way SSD because translation state is partitioned per
# channel. The serving adaptation stripes the logical page space across
# N channels with a STATIC hash (owner(dlpn) = dlpn mod C — the paper's
# channel-striping) and gives each channel its own complete
# ServingMapState shard: a 1/C-sized CMT, a 1/C-sized backing table, a
# 1/C slice of the incremental block table, and the free stacks of the
# blocks that channel owns (block b belongs to channel b mod C, so a
# page and the physical block backing it always live in the same
# channel). Every per-channel transition is the UNCHANGED single-probe
# fused pipeline above — sharding composes around it, never inside it.
#
# A sharded state is an ordinary ServingMapState whose leaves carry a
# leading [C] channel axis, so the same pytree runs under jax.vmap
# (single device: the portable lowering, bit-identical by construction)
# or under shard_map over a 'channel' mesh axis (one shard per device;
# the cross-channel combine becomes a psum). Lane results merge with
# the +1 trick: exactly one channel owns each active lane, NIL is -1,
# so sum_c(own_c ? out_c + 1 : 0) - 1 reconstructs the owner's answer
# (and NIL for lanes no channel owns). DESIGN.md "Channel-sharded map
# pipeline".


def channel_of(dlpns, n_channels: int):
    """Static dlpn -> channel hash (the paper's channel-striping)."""
    return jnp.mod(dlpns, n_channels)


def local_dlpn(dlpns, n_channels: int):
    """Channel-local logical page id of a global dlpn."""
    return dlpns // n_channels


def channel_stack(n_blocks: int, n_channels: int, c: int, cap: int,
                  base: int = 0):
    """Free-stack init for one channel: the blocks it owns (global id
    mod C == c), in per-channel BlockPool order (list(range)[::-1]
    filtered to the channel: first pop yields block base+c), padded to
    the channel-uniform capacity `cap` with NIL."""
    import numpy as np
    owned = np.asarray([base + b for b in range(n_blocks)
                        if b % n_channels == c][::-1], np.int32)
    out = np.full((cap,), NIL, np.int32)
    out[:owned.shape[0]] = owned
    return out, owned.shape[0]


def init_sharded_state(g: FMMUGeometry, n_channels: int,
                       n_device_blocks: int = 0, n_host_blocks: int = 0,
                       n_lanes: int = 0,
                       track_live: bool = False,
                       track_refs: bool = False) -> ServingMapState:
    """Stack C per-channel ServingMapStates into one pytree with a
    leading channel axis. `g` is the PER-CHANNEL geometry (its dlpn
    space covers ceil(n_dlpns / C) local pages). Device/host blocks are
    striped by block id mod C; stack capacities are channel-uniform
    (ceil(n / C)) so the leaves stack rectangularly.

    ``track_live`` gives every channel a FULL-size [n_device_blocks]
    live lane indexed by GLOBAL block id (dppns stay global even where
    dlpns are channel-local): shard c only ever touches blocks owned by
    channel c, so the global count is the plain sum over the channel
    axis — no reindexing, and the combine stays a sum like everything
    else in the sharded pipeline."""
    import numpy as np
    C = n_channels
    dev_cap = -(-n_device_blocks // C) if n_device_blocks else 0
    host_cap = -(-n_host_blocks // C) if n_host_blocks else 0
    dev_stacks, dev_ns, host_stacks, host_ns = [], [], [], []
    for c in range(C):
        s, n = channel_stack(n_device_blocks, C, c, dev_cap)
        dev_stacks.append(s)
        dev_ns.append(n)
        s, n = channel_stack(n_host_blocks, C, c, host_cap,
                             base=HOST_BASE)
        host_stacks.append(s)
        host_ns.append(n)
    one = init_serving_state(g, 0, 0, n_lanes=n_lanes)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (C,) + x.shape), one)
    return stacked._replace(
        free_stack=jnp.asarray(np.stack(dev_stacks), I),
        free_n=jnp.asarray(dev_ns, I),
        host_stack=jnp.asarray(np.stack(host_stacks), I),
        host_n=jnp.asarray(host_ns, I),
        live=(jnp.zeros((C, n_device_blocks), I) if track_live
              else None),
        refcnt=(jnp.zeros((C, n_device_blocks), I) if track_refs
                else None))


def _sharded_translate_body(g: FMMUGeometry, C: int, c, ms_c, opcodes,
                            dlpns, dppns, old_dppns, impl=None):
    """One channel's slice of a mixed-op batch: mask lanes to the ones
    this channel owns, run the UNCHANGED fused single-probe pipeline on
    channel-local dlpns, and return +1-encoded combine contributions
    (summed across channels by vmap or psum'd under shard_map)."""
    active = dlpns >= 0
    own = active & (channel_of(dlpns, C) == c)
    dl = jnp.where(own, local_dlpn(dlpns, C), -1).astype(I)
    ms_c, out, ok = translate_serving(g, ms_c, opcodes, dl, dppns,
                                      old_dppns, impl=impl)
    return (ms_c, jnp.where(own, out + 1, 0).astype(I),
            jnp.where(own, ok, False))


def translate_sharded(g: FMMUGeometry, C: int, ms: ServingMapState,
                      opcodes, dlpns, dppns, old_dppns, impl=None
                      ) -> Tuple[ServingMapState, jnp.ndarray, jnp.ndarray]:
    """Channel-sharded ``translate_serving`` (portable vmap lowering).

    ms leaves carry a leading [C] axis; each channel services exactly
    the lanes it owns with ONE local probe + ONE local insert pass (the
    per-channel single-probe/single-sort contract) and the per-lane
    results merge by summation — exactly one channel contributes per
    active lane. ``make_sharded_shard_body`` is the same body arranged
    for shard_map over a device mesh; both lowerings are bit-identical
    (the combine is the same sum)."""
    def body(c, ms_c):
        return _sharded_translate_body(g, C, c, ms_c, opcodes, dlpns,
                                       dppns, old_dppns, impl=impl)

    ms, outs, oks = jax.vmap(body)(jnp.arange(C, dtype=I), ms)
    return ms, outs.sum(0) - 1, oks.sum(0) > 0


def make_sharded_shard_body(g: FMMUGeometry, C: int, axis: str = "channel",
                            impl=None):
    """translate_sharded arranged as a shard_map body: the state shard
    arrives with a leading [1] slice of the channel axis, the lane
    arrays are replicated, and the combine is a psum over the mesh
    axis. Wrap with parallel.sharding.shard_map(mesh=..., in_specs=
    (P(axis), P(), P(), P(), P()), out_specs=(P(axis), P(), P()))."""
    def body(ms, opcodes, dlpns, dppns, old_dppns):
        c = jax.lax.axis_index(axis).astype(I)
        ms_c = jax.tree.map(lambda x: x[0], ms)
        ms_c, out_c, ok_c = _sharded_translate_body(
            g, C, c, ms_c, opcodes, dlpns, dppns, old_dppns, impl=impl)
        out = jax.lax.psum(out_c, axis) - 1
        ok = jax.lax.psum(ok_c.astype(I), axis) > 0
        return jax.tree.map(lambda x: x[None], ms_c), out, ok

    return body


def grow_sharded(g: FMMUGeometry, C: int, ms: ServingMapState, grow,
                 dlpns, impl=None
                 ) -> Tuple[ServingMapState, jnp.ndarray, jnp.ndarray]:
    """Channel-sharded ``serving_grow``: each growth lane pops from its
    OWNER channel's free stack (block and page stay in one channel) and
    commits through that channel's fused translate. Combine uses the
    same +1 encoding (blocks are >= 0, NIL on fail)."""
    def body(c, ms_c):
        own = grow & (channel_of(dlpns, C) == c)
        dl = jnp.where(own, local_dlpn(dlpns, C), -1).astype(I)
        ms_c, blocks, ok = serving_grow(g, ms_c, own, dl, impl=impl)
        return (ms_c, jnp.where(own & ok, blocks + 1, 0).astype(I),
                jnp.where(own, ok, False))

    ms, blks, oks = jax.vmap(body)(jnp.arange(C, dtype=I), ms)
    return ms, blks.sum(0) - 1, oks.sum(0) > 0


def set_allocator_sharded(ms: ServingMapState, free_stack, free_n,
                          host_stack, host_n, swap_pending=None
                          ) -> ServingMapState:
    """``set_allocator`` on a channel-stacked state: tier stacks arrive
    as [C, cap] arrays (one row per channel, host pool order), the
    per-channel OutOfBlocks flags clear, and the (replicated) residency
    lane refreshes across every channel's copy."""
    C = ms.oob.shape[0]
    sp = ms.swap_pending
    if swap_pending is not None:
        sp = jnp.broadcast_to(jnp.asarray(swap_pending, bool)[None],
                              ms.swap_pending.shape)
    return ms._replace(
        free_stack=jnp.asarray(free_stack, I),
        free_n=jnp.asarray(free_n, I),
        host_stack=jnp.asarray(host_stack, I),
        host_n=jnp.asarray(host_n, I),
        oob=jnp.zeros((C,), bool),
        swap_pending=sp)


def mark_swap_sharded(ms: ServingMapState, lane, pending
                      ) -> ServingMapState:
    """``mark_swap`` on a channel-stacked state: the residency lane is
    replicated per channel (every shard masks the same slots), so the
    flip lands in all channels' copies."""
    return ms._replace(
        swap_pending=ms.swap_pending.at[:, lane].set(pending))


def interleave_table(table, n: int) -> jnp.ndarray:
    """THE one home of the shard-interleave layout: a [C, L] stack of
    per-channel table shards flattens to global dlpn order (global d
    lives at shard [d mod C, d // C], so the transpose IS the
    cross-channel all-gather under a mesh; on one device it is a cheap
    relayout). A flat [L] table (unstacked, channels=1) passes through
    with a slice. Every consumer of the striping layout — dense_table,
    the serving engine's decode paths, the sharded retranslation
    oracle — must go through here."""
    if table.ndim == 1:
        return table[:n]
    return table.T.reshape(-1)[:n]


def dense_table(ms: ServingMapState, C: int, n: int) -> jnp.ndarray:
    """Materialize the global block table from a (possibly channel-
    stacked) serving state — ``interleave_table`` on ``ms.table``.
    Handles a C=1 *stacked* state ([1, L]) correctly too: the branch is
    on the table's rank, not on C."""
    del C  # layout is carried by the table's rank
    return interleave_table(ms.table, n)


# ------------------------------------------------------------ wrappers
def lookup_batch(g: FMMUGeometry, st: BatchFMMUState, dlpns,
                 impl=None) -> Tuple[BatchFMMUState, jnp.ndarray]:
    """Translate a batch of DLPNs. dlpns [Bq] (-1 = inactive).
    Returns (state, dppns [Bq]). Misses are served from backing in the
    same step and filled into the cache (dedup'd). Thin wrapper over
    translate_batch (single-probe fused path)."""
    z = jnp.zeros(dlpns.shape, I)
    st, out, _ = translate_batch(g, st, jnp.full(dlpns.shape, LOOKUP, I),
                                 dlpns, z, z, impl=impl)
    return st, out


def update_batch(g: FMMUGeometry, st: BatchFMMUState, dlpns, dppns,
                 impl=None) -> BatchFMMUState:
    """Write-through batched Update (thin wrapper over translate_batch).
    Duplicate dlpns in one batch are a caller contract violation (the
    paging layer allocates uniquely)."""
    st, _, _ = translate_batch(g, st, jnp.full(dlpns.shape, UPDATE, I),
                               dlpns, dppns, jnp.zeros(dlpns.shape, I),
                               impl=impl)
    return st


def cond_update_batch(g: FMMUGeometry, st: BatchFMMUState, dlpns, dppns,
                      old_dppns, impl=None):
    """Batched CondUpdate (GC relocation): apply only where the current
    mapping still equals old_dppn. Returns (state, applied mask). Thin
    wrapper over translate_batch — one probe, one insert (the unfused
    path re-probed twice and inserted twice)."""
    st, _, ok = translate_batch(g, st,
                                jnp.full(dlpns.shape, COND_UPDATE, I),
                                dlpns, dppns, old_dppns, impl=impl)
    return st, ok


def make_jitted(g: FMMUGeometry):
    """Convenience jitted closures for the serving layer.

    The state pytree (arg 0) is DONATED: steady-state serving performs
    zero state copies — callers must always rebind the returned state
    and never reuse the argument they passed in (all in-repo callers
    follow `state = fns[...](state, ...)`)."""
    j = functools.partial(jax.jit, donate_argnums=(0,))
    return {
        "lookup": j(functools.partial(lookup_batch, g)),
        "update": j(functools.partial(update_batch, g)),
        "cond_update": j(functools.partial(cond_update_batch, g)),
        "translate": j(functools.partial(translate_batch, g)),
        "serve": j(functools.partial(translate_serving, g)),
    }


# ----------------------------------------------------------------------
# Unfused reference path — the pre-fusion implementation, kept verbatim
# (one probe per op kind, CondUpdate = lookup + update = 2 probes +
# 2 insert passes, each insert paying two full sorts). Used by the
# equivalence tests (fused mixed batch must be bit-identical to the
# unfused three-call split) and as the kernel_bench baseline. Not
# exported via make_jitted; new callers must use translate_batch.
# ----------------------------------------------------------------------
def _probe_unfused(g: FMMUGeometry, st: BatchFMMUState, dlpns, impl=None):
    PROBE_TRACES[0] += 1
    return ops.fmmu_lookup(st.tags, st.valid, st.data, dlpns,
                           entries_per_block=g.cmt_entries, impl=impl)


def _insert_blocks_unfused(g: FMMUGeometry, st: BatchFMMUState, miss_bids):
    """Pre-fusion insert: dedup via full sort + second argsort pass."""
    INSERT_TRACES[0] += 1
    s_cnt, w_cnt = g.cmt_sets, g.cmt_ways
    sorted_b = jnp.sort(miss_bids)
    first = jnp.concatenate([jnp.array([True]),
                             sorted_b[1:] != sorted_b[:-1]])
    uniq = jnp.where(first & (sorted_b != BIG), sorted_b, BIG)
    usets = jnp.where(uniq != BIG, jnp.mod(uniq, s_cnt), s_cnt)
    order = jnp.argsort(usets, stable=True)
    gsets = usets[order]
    gbids = uniq[order]
    counts = jnp.bincount(gsets, length=s_cnt + 1)
    offs = jnp.cumsum(counts) - counts
    rank = jnp.arange(gsets.shape[0]) - offs[gsets]
    keep = (gsets < s_cnt) & (rank < w_cnt)
    way = jnp.mod(st.clock[jnp.clip(gsets, 0, s_cnt - 1)] + rank, w_cnt)
    base = gbids * g.cmt_entries
    idx = base[:, None] + jnp.arange(g.cmt_entries)[None, :]
    fresh = st.backing[jnp.clip(idx, 0, st.backing.shape[0] - 1)]
    sset = jnp.where(keep, gsets, s_cnt - 1)
    sway = jnp.where(keep, way, 0)
    drop = ~keep
    flat = sset * w_cnt + sway
    flat = jnp.where(drop, s_cnt * w_cnt, flat)    # OOB -> dropped
    tags = st.tags.reshape(-1).at[flat].set(
        jnp.where(drop, 0, gbids).astype(I), mode="drop").reshape(s_cnt, w_cnt)
    valid = st.valid.reshape(-1).at[flat].set(True, mode="drop").reshape(
        s_cnt, w_cnt)
    ref = st.ref.reshape(-1).at[flat].set(True, mode="drop").reshape(
        s_cnt, w_cnt)
    data = st.data.reshape(-1, g.cmt_entries).at[flat].set(
        fresh.astype(I), mode="drop").reshape(s_cnt, w_cnt, g.cmt_entries)
    ins_per_set = jnp.bincount(jnp.where(keep, gsets, s_cnt),
                               length=s_cnt + 1)[:s_cnt]
    clock = jnp.mod(st.clock + ins_per_set, w_cnt)
    n_fill = keep.sum()
    return st._replace(tags=tags, valid=valid, ref=ref, data=data,
                       clock=clock,
                       stats=st.stats.at[2].add(n_fill)), n_fill


def lookup_batch_unfused(g: FMMUGeometry, st: BatchFMMUState, dlpns,
                         impl=None) -> Tuple[BatchFMMUState, jnp.ndarray]:
    hit, dppn, set_idx, way = _probe_unfused(g, st, dlpns, impl=impl)
    active = dlpns >= 0
    miss = active & ~hit
    backing_val = st.backing[jnp.clip(dlpns, 0, st.backing.shape[0] - 1)]
    out = jnp.where(hit, dppn, jnp.where(active, backing_val, NIL))
    flat = set_idx * g.cmt_ways + way
    flat = jnp.where(hit, flat, g.cmt_sets * g.cmt_ways)
    ref = st.ref.reshape(-1).at[flat].set(True, mode="drop").reshape(
        st.ref.shape)
    st = st._replace(ref=ref,
                     stats=st.stats.at[0].add(hit.sum()).at[1].add(miss.sum()))
    miss_bids = jnp.where(miss, dlpns // g.cmt_entries, BIG)
    st, _ = _insert_blocks_unfused(g, st, miss_bids)
    return st, out


def update_batch_unfused(g: FMMUGeometry, st: BatchFMMUState, dlpns, dppns,
                         impl=None) -> BatchFMMUState:
    active = dlpns >= 0
    safe = jnp.where(active, dlpns, st.backing.shape[0])
    backing = st.backing.at[safe].set(dppns.astype(I), mode="drop")
    st = st._replace(backing=backing,
                     stats=st.stats.at[3].add(active.sum()))
    hit, _, set_idx, way = _probe_unfused(g, st, dlpns, impl=impl)
    off = jnp.mod(jnp.where(active, dlpns, 0), g.cmt_entries)
    flat = (set_idx * g.cmt_ways + way) * g.cmt_entries + off
    flat = jnp.where(hit, flat, st.data.size)
    data = st.data.reshape(-1).at[flat].set(dppns.astype(I), mode="drop")
    st = st._replace(data=data.reshape(st.data.shape))
    miss = active & ~hit
    miss_bids = jnp.where(miss, dlpns // g.cmt_entries, BIG)
    st, _ = _insert_blocks_unfused(g, st, miss_bids)
    return st


def cond_update_batch_unfused(g: FMMUGeometry, st: BatchFMMUState, dlpns,
                              dppns, old_dppns, impl=None):
    st2, cur = lookup_batch_unfused(g, st, dlpns, impl=impl)
    ok = (cur == old_dppns) & (dlpns >= 0)
    eff = jnp.where(ok, dlpns, -1)
    st3 = update_batch_unfused(g, st2, eff, dppns, impl=impl)
    return st3, ok
