"""Batched ("superscalar") FMMU translation engine — the TPU adaptation.

The paper's FMMU processes one packet per pipeline slot; a TPU is a wide
vector machine, so the serving integration translates a whole request
batch per step:

  * all CMT probes in parallel (kernels/fmmu_lookup Pallas kernel);
  * MSHR semantics become sort-based *miss dedup*: all misses to the
    same cache block are served by ONE backing-store gather (exactly the
    paper's "one flash read serves many merged requests");
  * per-set insertion honours associativity: at most W distinct new
    blocks enter a set per batch step, surplus misses are served
    uncached (no-allocate overflow) — a deterministic, vectorized
    stand-in for the sequential second-chance walk;
  * the batch path is WRITE-THROUGH (backing is HBM/host RAM, where a
    scatter is cheap), unlike the flash-faithful write-back+DTL FSM in
    engine.py. Recorded as a hardware-adaptation decision in DESIGN.md.

State is a small pytree usable inside jit/shard_map; the backing table
plays the role of flash-resident translation pages + GTD.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.fmmu.types import FMMUGeometry, NIL
from repro.kernels import ops

I = jnp.int32
BIG = jnp.iinfo(jnp.int32).max


class BatchFMMUState(NamedTuple):
    tags: jnp.ndarray      # [S,W] block id or NIL
    valid: jnp.ndarray     # [S,W] bool
    ref: jnp.ndarray       # [S,W] bool (second-chance approximation)
    clock: jnp.ndarray     # [S]
    data: jnp.ndarray      # [S,W,E]
    backing: jnp.ndarray   # [n_tvpns * entries_per_tp] full map table
    stats: jnp.ndarray     # [4] hits, misses, unique_fills, updates


def init_batch_state(g: FMMUGeometry) -> BatchFMMUState:
    return BatchFMMUState(
        tags=jnp.full((g.cmt_sets, g.cmt_ways), NIL, I),
        valid=jnp.zeros((g.cmt_sets, g.cmt_ways), bool),
        ref=jnp.zeros((g.cmt_sets, g.cmt_ways), bool),
        clock=jnp.zeros((g.cmt_sets,), I),
        data=jnp.full((g.cmt_sets, g.cmt_ways, g.cmt_entries), NIL, I),
        backing=jnp.full((g.n_tvpns * g.entries_per_tp,), NIL, I),
        stats=jnp.zeros((4,), jnp.int64 if jax.config.jax_enable_x64 else I),
    )


def _probe(g: FMMUGeometry, st: BatchFMMUState, dlpns, impl=None):
    return ops.fmmu_lookup(st.tags, st.valid, st.data, dlpns,
                           entries_per_block=g.cmt_entries, impl=impl)


def _insert_blocks(g: FMMUGeometry, st: BatchFMMUState, miss_bids):
    """Insert up to W distinct missing blocks per set (vectorized).
    miss_bids [Bq] block ids (BIG = no miss)."""
    s_cnt, w_cnt = g.cmt_sets, g.cmt_ways
    # dedup block ids (MSHR merging)
    sorted_b = jnp.sort(miss_bids)
    first = jnp.concatenate([jnp.array([True]),
                             sorted_b[1:] != sorted_b[:-1]])
    uniq = jnp.where(first & (sorted_b != BIG), sorted_b, BIG)
    # group by set, rank within set
    usets = jnp.where(uniq != BIG, jnp.mod(uniq, s_cnt), s_cnt)
    order = jnp.argsort(usets, stable=True)
    gsets = usets[order]
    gbids = uniq[order]
    counts = jnp.bincount(gsets, length=s_cnt + 1)
    offs = jnp.cumsum(counts) - counts
    rank = jnp.arange(gsets.shape[0]) - offs[gsets]
    keep = (gsets < s_cnt) & (rank < w_cnt)
    way = jnp.mod(st.clock[jnp.clip(gsets, 0, s_cnt - 1)] + rank, w_cnt)
    # gather fresh block contents from backing
    base = gbids * g.cmt_entries
    idx = base[:, None] + jnp.arange(g.cmt_entries)[None, :]
    fresh = st.backing[jnp.clip(idx, 0, st.backing.shape[0] - 1)]
    sset = jnp.where(keep, gsets, s_cnt - 1)
    sway = jnp.where(keep, way, 0)
    drop = ~keep
    # scatter (dropped rows target [S-1,0] but with mode guard via where
    # on a one-shot mask: rewrite as scatter with explicit drop index)
    flat = sset * w_cnt + sway
    flat = jnp.where(drop, s_cnt * w_cnt, flat)    # OOB -> dropped
    tags = st.tags.reshape(-1).at[flat].set(
        jnp.where(drop, 0, gbids).astype(I), mode="drop").reshape(s_cnt, w_cnt)
    valid = st.valid.reshape(-1).at[flat].set(True, mode="drop").reshape(
        s_cnt, w_cnt)
    ref = st.ref.reshape(-1).at[flat].set(True, mode="drop").reshape(
        s_cnt, w_cnt)
    data = st.data.reshape(-1, g.cmt_entries).at[flat].set(
        fresh.astype(I), mode="drop").reshape(s_cnt, w_cnt, g.cmt_entries)
    ins_per_set = jnp.bincount(jnp.where(keep, gsets, s_cnt),
                               length=s_cnt + 1)[:s_cnt]
    clock = jnp.mod(st.clock + ins_per_set, w_cnt)
    n_fill = keep.sum()
    return st._replace(tags=tags, valid=valid, ref=ref, data=data,
                       clock=clock,
                       stats=st.stats.at[2].add(n_fill)), n_fill


def lookup_batch(g: FMMUGeometry, st: BatchFMMUState, dlpns,
                 impl=None) -> Tuple[BatchFMMUState, jnp.ndarray]:
    """Translate a batch of DLPNs. dlpns [Bq] (-1 = inactive).
    Returns (state, dppns [Bq]). Misses are served from backing in the
    same step and filled into the cache (dedup'd)."""
    hit, dppn, set_idx, way = _probe(g, st, dlpns, impl=impl)
    active = dlpns >= 0
    miss = active & ~hit
    # serve misses straight from the flat backing table
    backing_val = st.backing[jnp.clip(dlpns, 0, st.backing.shape[0] - 1)]
    out = jnp.where(hit, dppn, jnp.where(active, backing_val, NIL))
    # refbit touch for hits
    flat = set_idx * g.cmt_ways + way
    flat = jnp.where(hit, flat, g.cmt_sets * g.cmt_ways)
    ref = st.ref.reshape(-1).at[flat].set(True, mode="drop").reshape(
        st.ref.shape)
    st = st._replace(ref=ref,
                     stats=st.stats.at[0].add(hit.sum()).at[1].add(miss.sum()))
    miss_bids = jnp.where(miss, dlpns // g.cmt_entries, BIG)
    st, _ = _insert_blocks(g, st, miss_bids)
    return st, out


def update_batch(g: FMMUGeometry, st: BatchFMMUState, dlpns, dppns,
                 impl=None) -> BatchFMMUState:
    """Write-through batched Update. Duplicate dlpns in one batch are a
    caller contract violation (the paging layer allocates uniquely)."""
    active = dlpns >= 0
    safe = jnp.where(active, dlpns, st.backing.shape[0])
    backing = st.backing.at[safe].set(dppns.astype(I), mode="drop")
    st = st._replace(backing=backing,
                     stats=st.stats.at[3].add(active.sum()))
    # update cached copies where present
    hit, _, set_idx, way = _probe(g, st, dlpns, impl=impl)
    off = jnp.mod(jnp.where(active, dlpns, 0), g.cmt_entries)
    flat = (set_idx * g.cmt_ways + way) * g.cmt_entries + off
    flat = jnp.where(hit, flat, st.data.size)
    data = st.data.reshape(-1).at[flat].set(dppns.astype(I), mode="drop")
    st = st._replace(data=data.reshape(st.data.shape))
    # allocate blocks for missing updates too (write-allocate, like FSM)
    miss = active & ~hit
    miss_bids = jnp.where(miss, dlpns // g.cmt_entries, BIG)
    st, _ = _insert_blocks(g, st, miss_bids)
    return st


def cond_update_batch(g: FMMUGeometry, st: BatchFMMUState, dlpns, dppns,
                      old_dppns, impl=None):
    """Batched CondUpdate (GC relocation): apply only where the current
    mapping still equals old_dppn. Returns (state, applied mask)."""
    st2, cur = lookup_batch(g, st, dlpns, impl=impl)
    ok = (cur == old_dppns) & (dlpns >= 0)
    eff = jnp.where(ok, dlpns, -1)
    st3 = update_batch(g, st2, eff, dppns, impl=impl)
    return st3, ok


def make_jitted(g: FMMUGeometry):
    """Convenience jitted closures for the serving layer."""
    return {
        "lookup": jax.jit(functools.partial(lookup_batch, g)),
        "update": jax.jit(functools.partial(update_batch, g)),
        "cond_update": jax.jit(functools.partial(cond_update_batch, g)),
    }
