"""The FMMU as a jittable JAX state machine.

Exact functional mirror of oracle.py (same deterministic policies, same
packet/arbitration semantics) expressed in jax.lax control flow over the
fixed-shape arrays of FMMUState. One ``step`` = one arbitration round =
one packet (or one watermark flush/writeback action), like the hardware
pipeline. ``run`` drives steps until quiescent/blocked via
lax.while_loop. Property tests drive oracle and engine in lockstep.

This is the paper's "hardware automation" rendered TPU-native: the
control FSM is a compiled fixed-function pipeline rather than host
software. The *batched* translate path that serving uses for throughput
lives in batch.py; this engine is the architectural/correctness model
and handles the sequential mutation paths (miss fills, flush, GC).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.fmmu import state as S
from repro.core.fmmu.state import (BLOCKED, F_DIRTY, F_REF, F_TRANS, F_VALID,
                                   IDLE, Q_CTP_REQ, Q_CTP_RESP, Q_FC_RESP,
                                   Q_GCM, Q_HRM, WORKED, FMMUState)
from repro.core.fmmu.types import (COND_UPDATE, FLUSH_BLK, FMMUGeometry,
                                   LOAD, LOAD_RESP, LOOKUP, M_COND, M_FLUSH,
                                   M_LOAD, M_LOOKUP, M_UPDATE, NIL, Request,
                                   Response, ST_OK, ST_STALE, UPDATE)

I = jnp.int32
(ST_HIT, ST_MISS, ST_MERGE, ST_STALL, ST_FTV, ST_FBLK, ST_FC, ST_PROG,
 ST_STEPS, ST_CHIT, ST_CMISS) = range(11)


def _bump(st, idx):
    return st._replace(stats=st.stats.at[idx].add(1))


# ----------------------------------------------------------------------
# queues
# ----------------------------------------------------------------------
def _qlen(st, q):
    return st.qtail[q] - st.qhead[q]


def _qpush(st, q, pkt):
    cap = st.qbuf.shape[1]
    pos = jnp.mod(st.qtail[q], cap)
    return st._replace(qbuf=st.qbuf.at[q, pos].set(pkt),
                       qtail=st.qtail.at[q].add(1))


def _qpush_front(st, q, pkt):
    cap = st.qbuf.shape[1]
    pos = jnp.mod(st.qhead[q] - 1, cap)
    return st._replace(qbuf=st.qbuf.at[q, pos].set(pkt),
                       qhead=st.qhead.at[q].add(-1))


def _qpop(st, q):
    cap = st.qbuf.shape[1]
    pkt = st.qbuf[q, jnp.mod(st.qhead[q], cap)]
    return st._replace(qhead=st.qhead.at[q].add(1)), pkt


def _pkt(g, kind, f1=NIL, f2=NIL, f3=NIL, f4=NIL, data=None):
    head = jnp.stack([jnp.asarray(v, I) for v in (kind, f1, f2, f3, f4)])
    if data is None:
        data = jnp.full((g.cmt_entries,), NIL, I)
    return jnp.concatenate([head, data.astype(I)])


# ----------------------------------------------------------------------
# outputs
# ----------------------------------------------------------------------
def _emit_resp(st, rid, kind, dppn, status):
    cap = st.resp_buf.shape[0]
    row = jnp.stack([rid, jnp.asarray(kind, I), dppn, jnp.asarray(status, I)])
    return st._replace(resp_buf=st.resp_buf.at[jnp.mod(st.resp_n, cap)].set(row),
                       resp_n=st.resp_n + 1)


def _emit_fc(st, tppn, s, w):
    cap = st.fc_buf.shape[0]
    row = jnp.stack([tppn, jnp.asarray(s, I), jnp.asarray(w, I)])
    st = st._replace(fc_buf=st.fc_buf.at[jnp.mod(st.fc_n, cap)].set(row),
                     fc_n=st.fc_n + 1)
    return _bump(st, ST_FC)


def _emit_prog(st, tvpn, tppn):
    cap = st.prog_buf.shape[0]
    st = st._replace(prog_buf=st.prog_buf.at[jnp.mod(st.prog_n, cap)]
                     .set(jnp.stack([tvpn, tppn])),
                     prog_n=st.prog_n + 1)
    return _bump(st, ST_PROG)


def _stall(st, q, pkt, front=False):
    st = _bump(st, ST_STALL)
    st = st._replace(stalls_in_row=st.stalls_in_row + 1)
    return _qpush_front(st, q, pkt) if front else _qpush(st, q, pkt)


# ----------------------------------------------------------------------
# second-chance victim selection (shared CMT/CTP)
# ----------------------------------------------------------------------
def _second_chance(flags_row, clock, n_ways: int):
    """Returns (found, way, new_flags_row, new_clock) mirroring the
    oracle: scan 2W slots from clock, clearing refbits until a clean,
    non-transient, non-referenced block is found."""
    def body(i, carry):
        found, way, fl, done = carry
        w = jnp.mod(clock + i, n_ways)
        f = fl[w]
        busy = (f & (F_DIRTY | F_TRANS)) != 0
        has_ref = (f & F_REF) != 0
        # selection only if not done, not busy, no refbit
        select = (~done) & (~busy) & (~has_ref)
        clear_ref = (~done) & (~busy) & has_ref
        fl = jnp.where(clear_ref, fl.at[w].set(f & ~F_REF), fl)
        found = found | select
        way = jnp.where(select, w, way)
        done = done | select
        return (found, way, fl, done)

    found, way, fl, _ = lax.fori_loop(
        0, 2 * n_ways, body,
        (jnp.asarray(False), jnp.asarray(0, I), flags_row,
         jnp.asarray(False)))
    new_clock = jnp.where(found, jnp.mod(way + 1, n_ways), clock)
    return found, way, fl, new_clock


# ----------------------------------------------------------------------
# DTL
# ----------------------------------------------------------------------
def _dtl_find(st, tvpn):
    match = (st.dtl_tvpn == tvpn)
    return match.any(), jnp.argmax(match).astype(I)


def _dtl_register(g, st, s, w, tvpn):
    """Link CMT block (s,w) into the DTL chain for tvpn."""
    p = (s * g.cmt_ways + w).astype(I)
    found, idx = _dtl_find(st, tvpn)

    def link(st):
        st = st._replace(
            cmt_next=st.cmt_next.at[s, w].set(st.dtl_head[idx]),
            dtl_head=st.dtl_head.at[idx].set(p),
            dtl_ndirty=st.dtl_ndirty.at[idx].add(1),
            dtl_updated=st.dtl_updated.at[idx].set(1))
        return st

    def insert(st):
        free = st.dtl_tvpn == NIL

        def make_room(st):
            # full: flush the oldest entry (min seq), like oracle dtl[0]
            oldest = jnp.argmin(st.dtl_seq).astype(I)
            return _flush_tvpn(g, st, oldest)

        st = lax.cond(free.any(), lambda x: x, make_room, st)
        free = st.dtl_tvpn == NIL
        slot = jnp.argmax(free).astype(I)
        st = st._replace(
            cmt_next=st.cmt_next.at[s, w].set(NIL),
            dtl_tvpn=st.dtl_tvpn.at[slot].set(tvpn),
            dtl_head=st.dtl_head.at[slot].set(p),
            dtl_ndirty=st.dtl_ndirty.at[slot].set(1),
            dtl_updated=st.dtl_updated.at[slot].set(1),
            dtl_seq=st.dtl_seq.at[slot].set(st.dtl_ctr),
            dtl_ctr=st.dtl_ctr + 1)
        return st

    return lax.cond(found, link, insert, st)


def _flush_tvpn(g, st, idx):
    """Walk the next-link chain of DTL entry idx, emitting one FLUSH_BLK
    per dirty block (paper's O(dirty) batch flush)."""
    tvpn = st.dtl_tvpn[idx]
    st = _bump(st, ST_FTV)

    def cond(carry):
        st_, p = carry
        return p != NIL

    def body(carry):
        st_, p = carry
        s = p // g.cmt_ways
        w = jnp.mod(p, g.cmt_ways)
        nxt = st_.cmt_next[s, w]
        dirty = (st_.cmt_flags[s, w] & F_DIRTY) != 0

        def do_flush(st_):
            chunk = jnp.mod(st_.cmt_tag[s, w], g.chunks_per_tp)
            pkt = _pkt(g, FLUSH_BLK, tvpn, chunk, data=st_.cmt_data[s, w])
            st_ = _qpush(st_, Q_CTP_REQ, pkt)
            st_ = st_._replace(
                cmt_flags=st_.cmt_flags.at[s, w].set(
                    st_.cmt_flags[s, w] & ~F_DIRTY),
                cmt_next=st_.cmt_next.at[s, w].set(NIL),
                cmt_dirty=st_.cmt_dirty - 1)
            return _bump(st_, ST_FBLK)

        st_ = lax.cond(dirty, do_flush, lambda x: x, st_)
        return (st_, nxt)

    st, _ = lax.while_loop(cond, body, (st, st.dtl_head[idx]))
    st = st._replace(
        dtl_tvpn=st.dtl_tvpn.at[idx].set(NIL),
        dtl_head=st.dtl_head.at[idx].set(NIL),
        dtl_ndirty=st.dtl_ndirty.at[idx].set(0),
        dtl_updated=st.dtl_updated.at[idx].set(0),
        dtl_seq=st.dtl_seq.at[idx].set(jnp.iinfo(jnp.int32).max))
    return st


def _pick_flush_victim(st):
    """Greedy: max ndirty; tie -> oldest (min seq). Matches Python max()
    over registration order."""
    valid = st.dtl_tvpn != NIL
    nd = jnp.where(valid, st.dtl_ndirty, -1)
    best_nd = nd.max()
    cand = valid & (nd == best_nd)
    seq = jnp.where(cand, st.dtl_seq, jnp.iinfo(jnp.int32).max)
    return jnp.argmin(seq).astype(I)


# ----------------------------------------------------------------------
# CMT
# ----------------------------------------------------------------------
def _cmt_apply(g, st, s, w, kind, off, rid, dppn, old):
    """Hit/replay application of LOOKUP/UPDATE/COND_UPDATE to block (s,w)."""
    cur = st.cmt_data[s, w, off]

    def do_lookup(st):
        return _emit_resp(st, rid, LOOKUP, cur, ST_OK)

    def do_stale(st):
        return _emit_resp(st, rid, COND_UPDATE, cur, ST_STALE)

    def do_write(st):
        st = st._replace(cmt_data=st.cmt_data.at[s, w, off].set(dppn))
        was_dirty = (st.cmt_flags[s, w] & F_DIRTY) != 0

        def mark(st):
            st = st._replace(
                cmt_flags=st.cmt_flags.at[s, w].set(st.cmt_flags[s, w] | F_DIRTY),
                cmt_dirty=st.cmt_dirty + 1)
            tvpn = st.cmt_tag[s, w] // g.chunks_per_tp
            return _dtl_register(g, st, s, w, tvpn)

        st = lax.cond(was_dirty, lambda x: x, mark, st)
        return _emit_resp(st, rid, kind, dppn, ST_OK)

    is_lookup = kind == LOOKUP
    is_stale = (kind == COND_UPDATE) & (cur != old)
    return lax.cond(is_lookup, do_lookup,
                    lambda st: lax.cond(is_stale, do_stale, do_write, st), st)


def _targeted_cmt_flush(g, st, s):
    """Free a way in set s by flushing a TVPN owning a dirty block there."""
    dirty = (st.cmt_flags[s] & F_DIRTY) != 0

    def do(st):
        w = jnp.argmax(dirty).astype(I)
        tvpn = st.cmt_tag[s, w] // g.chunks_per_tp
        found, idx = _dtl_find(st, tvpn)
        return lax.cond(found, lambda st: _flush_tvpn(g, st, idx),
                        lambda st: st, st)

    return lax.cond(dirty.any(), do, lambda st: st, st)


def _cmt_handle(g, st, pkt, qid):
    kind, dlpn, dppn, old, rid = pkt[0], pkt[1], pkt[2], pkt[3], pkt[4]
    block_id = dlpn // g.cmt_entries
    s = jnp.mod(block_id, g.cmt_sets)
    off = jnp.mod(dlpn, g.cmt_entries)
    tags = st.cmt_tag[s]
    flags = st.cmt_flags[s]
    present = (tags == block_id) & ((flags & (F_VALID | F_TRANS)) != 0)
    found = present.any()
    way = jnp.argmax(present).astype(I)
    is_trans = found & ((flags[way] & F_TRANS) != 0)
    mkind = jnp.where(kind == LOOKUP, M_LOOKUP,
                      jnp.where(kind == UPDATE, M_UPDATE, M_COND))
    mshr_row = jnp.stack([mkind, off, rid, dppn, old])

    def on_transient(st):
        full = st.cmt_mshr_n[s, way] >= g.mshr_cap

        def merge(st):
            st = _bump(st, ST_MERGE)
            n = st.cmt_mshr_n[s, way]
            return st._replace(
                cmt_mshr=st.cmt_mshr.at[s, way, n].set(mshr_row),
                cmt_mshr_n=st.cmt_mshr_n.at[s, way].set(n + 1))

        return lax.cond(full, lambda st: _stall(st, qid, pkt), merge, st)

    def on_hit(st):
        st = _bump(st, ST_HIT)
        st = st._replace(cmt_flags=st.cmt_flags.at[s, way].set(
            st.cmt_flags[s, way] | F_REF))
        return _cmt_apply(g, st, s, way, kind, off, rid, dppn, old)

    def on_miss(st):
        st = _bump(st, ST_MISS)
        ok, vic, new_flags_row, new_clock = _second_chance(
            st.cmt_flags[s], st.cmt_clock[s], g.cmt_ways)

        def alloc(st):
            st = st._replace(
                cmt_flags=st.cmt_flags.at[s].set(new_flags_row),
                cmt_clock=st.cmt_clock.at[s].set(new_clock))
            fl = (F_TRANS | F_REF)
            st = st._replace(
                cmt_tag=st.cmt_tag.at[s, vic].set(block_id),
                cmt_flags=st.cmt_flags.at[s, vic].set(fl),
                cmt_next=st.cmt_next.at[s, vic].set(NIL),
                cmt_mshr=st.cmt_mshr.at[s, vic, 0].set(mshr_row),
                cmt_mshr_n=st.cmt_mshr_n.at[s, vic].set(1))
            tvpn = dlpn // g.entries_per_tp
            chunk = jnp.mod(dlpn, g.entries_per_tp) // g.cmt_entries
            dest = s * g.cmt_ways + vic
            return _qpush(st, Q_CTP_REQ, _pkt(g, LOAD, tvpn, chunk, dest))

        def no_victim(st):
            st = _targeted_cmt_flush(g, st, s)
            return _stall(st, qid, pkt)

        return lax.cond(ok, alloc, no_victim, st)

    return lax.cond(is_trans, on_transient,
                    lambda st: lax.cond(found, on_hit, on_miss, st), st)


def _cmt_fill(g, st, pkt):
    """LOAD_RESP from CTP: fill block, replay in-cache MSHRs in order."""
    dest = pkt[3]
    s = dest // g.cmt_ways
    w = jnp.mod(dest, g.cmt_ways)
    data = pkt[5:5 + g.cmt_entries]
    st = st._replace(
        cmt_data=st.cmt_data.at[s, w].set(data),
        cmt_flags=st.cmt_flags.at[s, w].set(
            (st.cmt_flags[s, w] & ~F_TRANS) | F_VALID))
    n = st.cmt_mshr_n[s, w]
    st = st._replace(cmt_mshr_n=st.cmt_mshr_n.at[s, w].set(0))

    def body(i, st):
        def replay(st):
            row = st.cmt_mshr[s, w, i]
            mk, off, rid, dppn, old = row[0], row[1], row[2], row[3], row[4]
            kind = jnp.where(mk == M_LOOKUP, LOOKUP,
                             jnp.where(mk == M_UPDATE, UPDATE, COND_UPDATE))
            return _cmt_apply(g, st, s, w, kind, off, rid, dppn, old)

        return lax.cond(i < n, replay, lambda x: x, st)

    return lax.fori_loop(0, g.mshr_cap, body, st)


def _cmt_flush_needed(g, st):
    return ((g.cmt_blocks - st.cmt_dirty) < g.cmt_low()) & \
        (st.dtl_tvpn != NIL).any()


def _cmt_flush_one(g, st):
    return _flush_tvpn(g, st, _pick_flush_victim(st))


# ----------------------------------------------------------------------
# CTP
# ----------------------------------------------------------------------
def _fifo_push(st, tvpn):
    """Dedup'd push: a TVPN is queued at most once (bounds occupancy by
    n_tvpns; matches oracle). Popped slots are NIL'd so the CAM scan over
    the ring cannot false-positive."""
    cap = st.fifo.shape[0]
    present = (st.fifo == tvpn).any()

    def push(st):
        return st._replace(
            fifo=st.fifo.at[jnp.mod(st.fifo_tail, cap)].set(tvpn),
            fifo_tail=st.fifo_tail + 1)

    return lax.cond(present, lambda x: x, push, st)


def _ctp_apply(g, st, s, w, kind, chunk, dest, data):
    ec = g.cmt_entries

    def do_load(st):
        sl = lax.dynamic_slice(st.ctp_data[s, w], (chunk * ec,), (ec,))
        tvpn = st.ctp_tag[s, w]
        return _qpush(st, Q_CTP_RESP, _pkt(g, LOAD_RESP, tvpn, chunk, dest,
                                           data=sl))

    def do_merge(st):
        nd = lax.dynamic_update_slice(st.ctp_data[s, w], data.astype(I),
                                      (chunk * ec,))
        st = st._replace(ctp_data=st.ctp_data.at[s, w].set(nd))
        was_dirty = (st.ctp_flags[s, w] & F_DIRTY) != 0

        def mark(st):
            st = st._replace(
                ctp_flags=st.ctp_flags.at[s, w].set(
                    st.ctp_flags[s, w] | F_DIRTY),
                ctp_dirty=st.ctp_dirty + 1)
            return _fifo_push(st, st.ctp_tag[s, w])

        return lax.cond(was_dirty, lambda x: x, mark, st)

    return lax.cond(kind == LOAD, do_load, do_merge, st)


def _ctp_fill_data(g, st, s, w, page):
    """Fill CTP block and replay its MSHRs in order."""
    st = st._replace(
        ctp_data=st.ctp_data.at[s, w].set(page),
        ctp_flags=st.ctp_flags.at[s, w].set(
            (st.ctp_flags[s, w] & ~F_TRANS) | F_VALID))
    n = st.ctp_mshr_n[s, w]
    st = st._replace(ctp_mshr_n=st.ctp_mshr_n.at[s, w].set(0))

    def body(i, st):
        def replay(st):
            row = st.ctp_mshr[s, w, i]
            mk, chunk, dest = row[0], row[1], row[2]
            data = row[3:3 + g.cmt_entries]
            kind = jnp.where(mk == M_LOAD, LOAD, FLUSH_BLK)
            return _ctp_apply(g, st, s, w, kind, chunk, dest, data)

        return lax.cond(i < n, replay, lambda x: x, st)

    return lax.fori_loop(0, g.ctp_mshr_cap, body, st)


def _targeted_ctp_writeback(g, st, s):
    fl = st.ctp_flags[s]
    dirty = ((fl & F_DIRTY) != 0) & ((fl & F_VALID) != 0)

    def do(st):
        w = jnp.argmax(dirty).astype(I)
        return _writeback_block(g, st, s, w)

    return lax.cond(dirty.any(), do, lambda x: x, st)


def _writeback_block(g, st, s, w):
    tppn = st.tppn_next
    tvpn = st.ctp_tag[s, w]
    st = st._replace(
        flash_tp=st.flash_tp.at[tppn].set(st.ctp_data[s, w]),
        gtd=st.gtd.at[tvpn].set(tppn),
        tppn_next=st.tppn_next + 1,
        ctp_flags=st.ctp_flags.at[s, w].set(st.ctp_flags[s, w] & ~F_DIRTY),
        ctp_dirty=st.ctp_dirty - 1)
    return _emit_prog(st, tvpn, tppn)


def _ctp_handle(g, st, pkt):
    kind, tvpn, chunk, dest = pkt[0], pkt[1], pkt[2], pkt[3]
    data = pkt[5:5 + g.cmt_entries]
    s = jnp.mod(tvpn, g.ctp_sets)
    tags = st.ctp_tag[s]
    flags = st.ctp_flags[s]
    present = (tags == tvpn) & ((flags & (F_VALID | F_TRANS)) != 0)
    found = present.any()
    way = jnp.argmax(present).astype(I)
    is_trans = found & ((flags[way] & F_TRANS) != 0)
    mk = jnp.where(kind == LOAD, M_LOAD, M_FLUSH)
    mshr_row = jnp.concatenate([jnp.stack([mk, chunk, dest]), data])

    def on_transient(st):
        full = st.ctp_mshr_n[s, way] >= g.ctp_mshr_cap

        def merge(st):
            st = _bump(st, ST_MERGE)
            n = st.ctp_mshr_n[s, way]
            return st._replace(
                ctp_mshr=st.ctp_mshr.at[s, way, n].set(mshr_row),
                ctp_mshr_n=st.ctp_mshr_n.at[s, way].set(n + 1))

        return lax.cond(full,
                        lambda st: _stall(st, Q_CTP_REQ, pkt, front=True),
                        merge, st)

    def on_hit(st):
        st = _bump(st, ST_CHIT)
        st = st._replace(ctp_flags=st.ctp_flags.at[s, way].set(
            st.ctp_flags[s, way] | F_REF))
        return _ctp_apply(g, st, s, way, kind, chunk, dest, data)

    def on_miss(st):
        st = _bump(st, ST_CMISS)
        ok, vic, new_flags_row, new_clock = _second_chance(
            st.ctp_flags[s], st.ctp_clock[s], g.ctp_ways)

        def alloc(st):
            st = st._replace(
                ctp_flags=st.ctp_flags.at[s].set(new_flags_row),
                ctp_clock=st.ctp_clock.at[s].set(new_clock))
            st = st._replace(
                ctp_tag=st.ctp_tag.at[s, vic].set(tvpn),
                ctp_flags=st.ctp_flags.at[s, vic].set(F_TRANS | F_REF),
                ctp_mshr=st.ctp_mshr.at[s, vic, 0].set(mshr_row),
                ctp_mshr_n=st.ctp_mshr_n.at[s, vic].set(1))
            tppn = st.gtd[tvpn]

            def never_written(st):
                page = jnp.full((g.entries_per_tp,), NIL, I)
                return _ctp_fill_data(g, st, s, vic, page)

            def flash_read(st):
                return _emit_fc(st, tppn, s, vic)

            return lax.cond(tppn == NIL, never_written, flash_read, st)

        def no_victim(st):
            st = _targeted_ctp_writeback(g, st, s)
            return _stall(st, Q_CTP_REQ, pkt, front=True)

        return lax.cond(ok, alloc, no_victim, st)

    return lax.cond(is_trans, on_transient,
                    lambda st: lax.cond(found, on_hit, on_miss, st), st)


def _fc_handle(g, st, pkt):
    """FC_READ_RESP: f1=tppn, f2=ctp_set, f3=ctp_way."""
    tppn, s, w = pkt[1], pkt[2], pkt[3]
    page = st.flash_tp[tppn]
    return _ctp_fill_data(g, st, s, w, page)


def _ctp_writeback_needed(g, st):
    return ((g.ctp_blocks - st.ctp_dirty) < g.ctp_low()) & \
        (st.fifo_tail > st.fifo_head)


def _ctp_writeback_one(g, st):
    """Pop stale FIFO entries until one dirty match is written back.
    Returns (st, done)."""
    cap = st.fifo.shape[0]

    def cond(carry):
        st, done = carry
        return (~done) & (st.fifo_tail > st.fifo_head)

    def body(carry):
        st, done = carry
        pos = jnp.mod(st.fifo_head, cap)
        tvpn = st.fifo[pos]
        st = st._replace(fifo_head=st.fifo_head + 1,
                         fifo=st.fifo.at[pos].set(NIL))
        s = jnp.mod(tvpn, g.ctp_sets)
        fl = st.ctp_flags[s]
        match = (st.ctp_tag[s] == tvpn) & ((fl & F_VALID) != 0) & \
            ((fl & F_DIRTY) != 0)

        def wb(st):
            w = jnp.argmax(match).astype(I)
            return _writeback_block(g, st, s, w), jnp.asarray(True)

        return lax.cond(match.any(), wb, lambda st: (st, jnp.asarray(False)),
                        st)

    return lax.while_loop(cond, body, (st, jnp.asarray(False)))


# ----------------------------------------------------------------------
# arbitration + step
# ----------------------------------------------------------------------
def _arbitrate(g, st):
    lens = st.qtail - st.qhead
    nonempty = lens > 0
    any_ne = nonempty.any()
    all_zero = jnp.where(nonempty, st.credits <= 0, True).all()
    credits = jnp.where(any_ne & all_zero, st.weights, st.credits)
    ok = nonempty & (credits > 0)
    qid = jnp.argmax(ok).astype(I)
    picked = ok.any()
    credits = jnp.where(picked, credits.at[qid].add(-1), credits)
    return st._replace(credits=credits), picked & any_ne, qid


def step(g: FMMUGeometry, st: FMMUState):
    """One arbitration round. Returns (state, code)."""
    st = _bump(st, ST_STEPS)

    def try_ctp_wb(st):
        st, done = _ctp_writeback_one(g, st)
        return st, jnp.where(done, WORKED, -1)

    def try_cmt_flush(st):
        return _cmt_flush_one(g, st), jnp.asarray(WORKED, I)

    def dispatch(st):
        st, picked, qid = _arbitrate(g, st)

        def idle(st):
            return st, jnp.asarray(IDLE, I)

        def guarded(st):
            qlens = (st.qtail - st.qhead).sum()
            blocked = st.stalls_in_row > qlens + 4

            def do_block(st):
                return st._replace(stalls_in_row=jnp.zeros((), I)), \
                    jnp.asarray(BLOCKED, I)

            def do_packet(st):
                before = st.stalls_in_row
                st, pkt = _qpop(st, qid)

                st = lax.switch(
                    jnp.clip(qid, 0, 4),
                    [lambda st: _fc_handle(g, st, pkt),          # Q_FC_RESP
                     lambda st: _cmt_fill(g, st, pkt),           # Q_CTP_RESP
                     lambda st: _ctp_handle(g, st, pkt),         # Q_CTP_REQ
                     lambda st: _cmt_handle(g, st, pkt, qid),    # Q_HRM
                     lambda st: _cmt_handle(g, st, pkt, qid)],   # Q_GCM
                    st)
                st = st._replace(stalls_in_row=jnp.where(
                    st.stalls_in_row == before, 0, st.stalls_in_row))
                return st, jnp.asarray(WORKED, I)

            return lax.cond(blocked, do_block, do_packet, st)

        return lax.cond(picked, guarded, idle, st)

    # watermark work first (mirrors oracle.step)
    need_wb = _ctp_writeback_needed(g, st)
    st, code = lax.cond(need_wb, try_ctp_wb,
                        lambda st: (st, jnp.asarray(-1, I)), st)

    def after_wb(st_code):
        st, code = st_code
        need_fl = _cmt_flush_needed(g, st)
        return lax.cond(need_fl, try_cmt_flush, dispatch, st)

    st, code = lax.cond(code == WORKED, lambda sc: sc, after_wb, (st, code))
    return st, code


def _deliver_fc(g, st):
    """auto_flash: self-deliver all pending flash reads (zero latency)."""
    cap = st.fc_buf.shape[0]

    def body(i, st):
        row = st.fc_buf[jnp.mod(i, cap)]
        pkt = _pkt(g, 7, row[0], row[1], row[2])
        return _qpush(st, Q_FC_RESP, pkt)

    st = lax.fori_loop(st.fc_head, st.fc_n, body, st)
    return st._replace(fc_head=st.fc_n)


def run(g: FMMUGeometry, st: FMMUState, max_steps: int,
        auto_flash: bool = False):
    """Drive steps until quiescent/blocked (mirrors oracle.run)."""
    def cond(carry):
        st, n, cont = carry
        return cont & (n < max_steps)

    def body(carry):
        st, n, _ = carry
        st, code = step(g, st)
        n = n + 1
        worked = code == WORKED
        if auto_flash:
            can_deliver = (~worked) & (st.fc_n > st.fc_head)
            st = lax.cond(can_deliver, lambda s: _deliver_fc(g, s),
                          lambda s: s, st)
            cont = worked | can_deliver
        else:
            cont = worked
        return st, n, cont

    st, n, _ = lax.while_loop(cond, body,
                              (st, jnp.asarray(0, I), jnp.asarray(True)))
    return st, n


# ======================================================================
# Host-side wrapper with the same driver API as the oracle
# ======================================================================
class FMMUEngine:
    """Jitted FMMU with oracle-compatible driver API for lockstep tests
    and integration into the serving runtime."""

    def __init__(self, geom: FMMUGeometry):
        self.g = geom
        self.state = S.init_state(geom)
        self._run = jax.jit(functools.partial(run, geom),
                            static_argnames=("max_steps", "auto_flash"))

    # -- pushes are host-side numpy edits batched through jnp updates --
    def push_request(self, r: Request):
        q = Q_GCM if r.src else Q_HRM
        pkt = np.full((self.g.pkt_width,), NIL, np.int32)
        pkt[0:5] = (r.kind, r.dlpn, r.dppn, r.old_dppn, r.req_id)
        self._push(q, pkt)

    def push_flash_response(self, tppn: int, ctp_set: int, ctp_way: int):
        pkt = np.full((self.g.pkt_width,), NIL, np.int32)
        pkt[0:5] = (7, tppn, ctp_set, ctp_way, NIL)
        self._push(Q_FC_RESP, pkt)

    def _push(self, q: int, pkt: np.ndarray):
        st = self.state
        cap = self.g.queue_cap
        assert int(st.qtail[q] - st.qhead[q]) < cap, "queue overflow"
        pos = int(st.qtail[q]) % cap
        self.state = st._replace(
            qbuf=st.qbuf.at[q, pos].set(jnp.asarray(pkt)),
            qtail=st.qtail.at[q].add(1))

    def pending_work(self) -> bool:
        return bool((self.state.qtail - self.state.qhead).sum() > 0)

    def run(self, max_steps: int = 100_000, auto_flash: bool = False) -> int:
        self.state, n = self._run(self.state, max_steps=max_steps,
                                  auto_flash=auto_flash)
        return int(n)

    def drain_outputs(self):
        st = self.state
        r0, f0, p0 = int(st.resp_head), int(st.fc_head), int(st.prog_head)
        rn, fn, pn = int(st.resp_n), int(st.fc_n), int(st.prog_n)
        rbuf = np.asarray(st.resp_buf)
        fbuf = np.asarray(st.fc_buf)
        pbuf = np.asarray(st.prog_buf)
        resps = [Response(*map(int, rbuf[i % rbuf.shape[0]]))
                 for i in range(r0, rn)]
        fcs = [tuple(map(int, fbuf[i % fbuf.shape[0]])) for i in range(f0, fn)]
        progs = [tuple(map(int, pbuf[i % pbuf.shape[0]])) for i in range(p0, pn)]
        self.state = st._replace(
            resp_head=jnp.asarray(rn, jnp.int32),
            fc_head=jnp.asarray(fn, jnp.int32),
            prog_head=jnp.asarray(pn, jnp.int32))
        return resps, fcs, progs

    # -- shutdown path -------------------------------------------------
    def flush_all(self, max_rounds: int = 1000):
        g = self.g

        @jax.jit
        def force_flush_one(st):
            any_dtl = (st.dtl_tvpn != NIL).any()
            oldest = jnp.argmin(st.dtl_seq).astype(I)
            return lax.cond(any_dtl,
                            lambda st: _flush_tvpn(g, st, oldest),
                            lambda st: st, st)

        @jax.jit
        def force_wb(st):
            st, done = _ctp_writeback_one(g, st)
            return st, done

        for _ in range(max_rounds):
            dtl_left = bool((np.asarray(self.state.dtl_tvpn) != NIL).any())
            fifo_left = int(self.state.fifo_tail - self.state.fifo_head) > 0
            if not (dtl_left or fifo_left or self.pending_work()):
                break
            if dtl_left:
                self.state = force_flush_one(self.state)
            self.run(auto_flash=True)
            while int(self.state.fifo_tail - self.state.fifo_head) > 0:
                self.state, done = force_wb(self.state)
                if not bool(done):
                    break
            self.run(auto_flash=True)

    # -- inspection ------------------------------------------------------
    def stats(self) -> dict:
        return dict(zip(S.STAT_NAMES, map(int, np.asarray(self.state.stats))))

    def resolve(self, dlpn: int) -> int:
        g = self.g
        st = self.state
        block_id = dlpn // g.cmt_entries
        s = block_id % g.cmt_sets
        tags = np.asarray(st.cmt_tag[s])
        fl = np.asarray(st.cmt_flags[s])
        for w in range(g.cmt_ways):
            if tags[w] == block_id and (fl[w] & F_VALID):
                return int(st.cmt_data[s, w, dlpn % g.cmt_entries])
        tvpn = dlpn // g.entries_per_tp
        ts = tvpn % g.ctp_sets
        ttags = np.asarray(st.ctp_tag[ts])
        tfl = np.asarray(st.ctp_flags[ts])
        for w in range(g.ctp_ways):
            if ttags[w] == tvpn and (tfl[w] & F_VALID):
                return int(st.ctp_data[ts, w, dlpn % g.entries_per_tp])
        tppn = int(st.gtd[tvpn])
        if tppn == NIL:
            return NIL
        return int(st.flash_tp[tppn, dlpn % g.entries_per_tp])
