"""FMMUState: the engine's state as a flat pytree of fixed-shape arrays.

Cache flags are bit-packed per block: VALID|DIRTY|TRANSIENT|REF.
Queues are ring buffers with monotonic head/tail counters (head can move
backwards one slot for head-of-line re-insertion on CTP stalls).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.fmmu.types import FMMUGeometry, NIL

F_VALID, F_DIRTY, F_TRANS, F_REF = 1, 2, 4, 8

# queue ids (must match oracle.py)
Q_FC_RESP, Q_CTP_RESP, Q_CTP_REQ, Q_HRM, Q_GCM = range(5)

# engine step return codes
WORKED, IDLE, BLOCKED = 0, 1, 2


class FMMUState(NamedTuple):
    # --- CMT ---
    cmt_tag: jnp.ndarray      # [S,W]
    cmt_flags: jnp.ndarray    # [S,W]
    cmt_data: jnp.ndarray     # [S,W,E]
    cmt_next: jnp.ndarray     # [S,W]
    cmt_mshr: jnp.ndarray     # [S,W,M,5] kind,off,req_id,dppn,old
    cmt_mshr_n: jnp.ndarray   # [S,W]
    cmt_clock: jnp.ndarray    # [S]
    cmt_dirty: jnp.ndarray    # scalar
    # --- CTP ---
    ctp_tag: jnp.ndarray
    ctp_flags: jnp.ndarray
    ctp_data: jnp.ndarray     # [S2,W2,Et]
    ctp_mshr: jnp.ndarray     # [S2,W2,M2,3+E] kind,chunk,dest,data
    ctp_mshr_n: jnp.ndarray
    ctp_clock: jnp.ndarray
    ctp_dirty: jnp.ndarray
    # --- DTL ---
    dtl_tvpn: jnp.ndarray     # [D]
    dtl_head: jnp.ndarray     # [D]
    dtl_ndirty: jnp.ndarray   # [D]
    dtl_updated: jnp.ndarray  # [D]
    dtl_seq: jnp.ndarray      # [D] registration order; NIL slot = invalid
    dtl_ctr: jnp.ndarray      # scalar monotonic
    # --- CTP flush FIFO ---
    fifo: jnp.ndarray         # [F]
    fifo_head: jnp.ndarray
    fifo_tail: jnp.ndarray
    # --- GTD / flash ---
    gtd: jnp.ndarray          # [n_tvpns]
    flash_tp: jnp.ndarray     # [tppn_cap, Et]
    tppn_next: jnp.ndarray
    # --- queues ---
    qbuf: jnp.ndarray         # [5, cap, PW]
    qhead: jnp.ndarray        # [5]
    qtail: jnp.ndarray        # [5]
    credits: jnp.ndarray      # [5]
    weights: jnp.ndarray      # [5] (runtime-adjustable, §4.6)
    stalls_in_row: jnp.ndarray
    # --- outputs ---
    resp_buf: jnp.ndarray     # [cap,4] req_id,kind,dppn,status
    resp_n: jnp.ndarray       # tail (monotonic)
    resp_head: jnp.ndarray    # drained-up-to pointer
    fc_buf: jnp.ndarray       # [cap,3] tppn,set,way
    fc_n: jnp.ndarray
    fc_head: jnp.ndarray
    prog_buf: jnp.ndarray     # [cap,2] tvpn,new_tppn
    prog_n: jnp.ndarray
    prog_head: jnp.ndarray
    # --- stats (order: hit,miss,mshr_merge,stall,flush_tvpns,flush_blocks,
    #            fc_reads,programs,steps,ctp_hit,ctp_miss) ---
    stats: jnp.ndarray        # [11]


STAT_NAMES = ("hit", "miss", "mshr_merge", "stall", "flush_tvpns",
              "flush_blocks", "fc_reads", "programs", "steps", "ctp_hit",
              "ctp_miss")


def init_state(g: FMMUGeometry) -> FMMUState:
    i32 = jnp.int32
    pw = g.pkt_width
    m2w = 3 + g.cmt_entries
    cap = g.queue_cap
    return FMMUState(
        cmt_tag=jnp.full((g.cmt_sets, g.cmt_ways), NIL, i32),
        cmt_flags=jnp.zeros((g.cmt_sets, g.cmt_ways), i32),
        cmt_data=jnp.full((g.cmt_sets, g.cmt_ways, g.cmt_entries), NIL, i32),
        cmt_next=jnp.full((g.cmt_sets, g.cmt_ways), NIL, i32),
        cmt_mshr=jnp.full((g.cmt_sets, g.cmt_ways, g.mshr_cap, 5), NIL, i32),
        cmt_mshr_n=jnp.zeros((g.cmt_sets, g.cmt_ways), i32),
        cmt_clock=jnp.zeros((g.cmt_sets,), i32),
        cmt_dirty=jnp.zeros((), i32),
        ctp_tag=jnp.full((g.ctp_sets, g.ctp_ways), NIL, i32),
        ctp_flags=jnp.zeros((g.ctp_sets, g.ctp_ways), i32),
        ctp_data=jnp.full((g.ctp_sets, g.ctp_ways, g.entries_per_tp), NIL, i32),
        ctp_mshr=jnp.full((g.ctp_sets, g.ctp_ways, g.ctp_mshr_cap, m2w), NIL, i32),
        ctp_mshr_n=jnp.zeros((g.ctp_sets, g.ctp_ways), i32),
        ctp_clock=jnp.zeros((g.ctp_sets,), i32),
        ctp_dirty=jnp.zeros((), i32),
        dtl_tvpn=jnp.full((g.dtl_entries,), NIL, i32),
        dtl_head=jnp.full((g.dtl_entries,), NIL, i32),
        dtl_ndirty=jnp.zeros((g.dtl_entries,), i32),
        dtl_updated=jnp.zeros((g.dtl_entries,), i32),
        dtl_seq=jnp.full((g.dtl_entries,), jnp.iinfo(jnp.int32).max, i32),
        dtl_ctr=jnp.zeros((), i32),
        fifo=jnp.full((max(16, g.n_tvpns + 1, 2 * g.ctp_blocks),), NIL, i32),
        fifo_head=jnp.zeros((), i32),
        fifo_tail=jnp.zeros((), i32),
        gtd=jnp.full((g.n_tvpns,), NIL, i32),
        flash_tp=jnp.full((g.tppn_cap, g.entries_per_tp), NIL, i32),
        tppn_next=jnp.zeros((), i32),
        qbuf=jnp.zeros((5, cap, pw), i32),
        qhead=jnp.zeros((5,), i32),
        qtail=jnp.zeros((5,), i32),
        credits=jnp.asarray(g.wrr_weights, i32),
        weights=jnp.asarray(g.wrr_weights, i32),
        stalls_in_row=jnp.zeros((), i32),
        resp_buf=jnp.zeros((cap, 4), i32),
        resp_n=jnp.zeros((), i32),
        resp_head=jnp.zeros((), i32),
        fc_buf=jnp.zeros((cap, 3), i32),
        fc_n=jnp.zeros((), i32),
        fc_head=jnp.zeros((), i32),
        prog_buf=jnp.zeros((cap, 2), i32),
        prog_n=jnp.zeros((), i32),
        prog_head=jnp.zeros((), i32),
        stats=jnp.zeros((11,), i32),
    )
