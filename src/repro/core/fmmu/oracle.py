"""Readable Python reference of the FMMU state machine (§4 of the paper).

This is the executable spec: two-level cache (CMT/CTP), in-cache MSHRs,
DTL next-link batch flush, second-chance replacement among non-dirty
blocks, low/high-watermark flushing interleaved with request service,
weighted-round-robin arbitration, GTD, and CondUpdate semantics.

Flash is modeled functionally (``flash_tp`` array + bump allocator);
timing is added by core/sim. Flash read *responses* are delivered by the
driver (possibly out of order / delayed) — that asynchrony is what the
MSHRs absorb, and tests exercise it.

The JAX engine (engine.py) mirrors this machine exactly; property tests
assert identical responses, flash-op sequences, and final translation
state under random traces and delivery orders.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.core.fmmu.types import (
    COND_UPDATE, FC_READ, FC_READ_RESP, FLUSH_BLK, FMMUGeometry, LOAD,
    LOAD_RESP, LOOKUP, M_COND, M_FLUSH, M_LOAD, M_LOOKUP, M_UPDATE, NIL,
    PROGRAM, RESP, Request, Response, ST_OK, ST_STALE, UPDATE)

# queue ids (arbitration order; index into wrr_weights)
Q_FC_RESP, Q_CTP_RESP, Q_CTP_REQ, Q_HRM, Q_GCM = range(5)


class _Block:
    __slots__ = ("tag", "valid", "dirty", "transient", "refbit", "next",
                 "data", "mshrs")

    def __init__(self, entries: int):
        self.tag = NIL
        self.valid = False
        self.dirty = False
        self.transient = False
        self.refbit = False
        self.next = NIL          # packed (set*W+way) link for DTL chains
        self.data = [NIL] * entries
        self.mshrs: List[tuple] = []


class FMMUOracle:
    def __init__(self, geom: FMMUGeometry):
        self.g = geom
        g = geom
        self.cmt = [[_Block(g.cmt_entries) for _ in range(g.cmt_ways)]
                    for _ in range(g.cmt_sets)]
        self.ctp = [[_Block(g.entries_per_tp) for _ in range(g.ctp_ways)]
                    for _ in range(g.ctp_sets)]
        self.cmt_clock = [0] * g.cmt_sets
        self.ctp_clock = [0] * g.ctp_sets
        self.gtd = [NIL] * g.n_tvpns
        self.flash_tp: Dict[int, List[int]] = {}
        self.tppn_next = 0
        # DTL: ordered list of dicts {tvpn, head, ndirty, updated}
        self.dtl: List[dict] = []
        self.ctp_fifo: deque = deque()       # tvpns in CMT-flush order
        self.queues = [deque() for _ in range(5)]
        self.credits = list(g.wrr_weights)
        self.out_resps: List[Response] = []
        self.out_fc_reads: List[tuple] = []  # (tppn, ctp_set, ctp_way)
        self.out_programs: List[tuple] = []  # (tvpn, new_tppn)
        self.cmt_dirty = 0
        self.ctp_dirty = 0
        self._stalls_in_row = 0
        # counters
        self.stats = {"hit": 0, "miss": 0, "mshr_merge": 0, "stall": 0,
                      "flush_tvpns": 0, "flush_blocks": 0, "fc_reads": 0,
                      "programs": 0, "steps": 0, "ctp_hit": 0, "ctp_miss": 0}

    # ------------------------------------------------------------- util
    def _pack(self, s: int, w: int) -> int:
        return s * self.g.cmt_ways + w

    def _unpack(self, p: int) -> Tuple[int, int]:
        return p // self.g.cmt_ways, p % self.g.cmt_ways

    # ---------------------------------------------------------- driver API
    def push_request(self, r: Request):
        q = Q_GCM if r.src else Q_HRM
        self.queues[q].append(("req", r))

    def push_flash_response(self, tppn: int, ctp_set: int, ctp_way: int):
        self.queues[Q_FC_RESP].append(("fc", (tppn, ctp_set, ctp_way)))

    def pending_work(self) -> bool:
        return any(self.queues)

    def drain_outputs(self):
        r, f, p = self.out_resps, self.out_fc_reads, self.out_programs
        self.out_resps, self.out_fc_reads, self.out_programs = [], [], []
        return r, f, p

    # ---------------------------------------------------------- main loop
    WORKED, IDLE, BLOCKED = 0, 1, 2

    def step(self) -> int:
        """One arbitration round. Returns WORKED / IDLE (no queued work)
        / BLOCKED (all queued packets stalled on in-flight flash fills)."""
        self.stats["steps"] += 1
        # watermark work takes precedence (paper §4.5: alternate flush/serve)
        if self._ctp_writeback_needed() and self._ctp_writeback_one():
            return self.WORKED
        if self._cmt_flush_needed() and self._cmt_flush_one():
            return self.WORKED
        qid = self._arbitrate()
        if qid is None:
            return self.IDLE
        # quiescence guard: every queued packet re-stalled in a row means
        # nothing can advance until the driver delivers flash responses.
        if self._stalls_in_row > sum(len(q) for q in self.queues) + 4:
            self._stalls_in_row = 0
            return self.BLOCKED
        before = self._stalls_in_row
        kind, payload = self.queues[qid].popleft()
        if kind == "fc":
            self._ctp_fill(*payload)
        elif qid == Q_CTP_RESP:
            self._cmt_fill(payload)
        elif qid == Q_CTP_REQ:
            self._ctp_handle(payload)
        else:
            self._cmt_handle(payload, qid)
        if self._stalls_in_row == before:      # handler made progress
            self._stalls_in_row = 0
        return self.WORKED

    def run(self, max_steps: int = 1_000_000, auto_flash: bool = False) -> int:
        """Process until quiescent or blocked on the driver. With
        auto_flash, flash-read responses are self-delivered immediately
        (zero-latency flash)."""
        n = 0
        while n < max_steps:
            code = self.step()
            n += 1
            if code == self.WORKED:
                continue
            if auto_flash and self.out_fc_reads:
                reads, self.out_fc_reads = self.out_fc_reads, []
                for tppn, s, w in reads:
                    self.push_flash_response(tppn, s, w)
                continue
            break  # IDLE or BLOCKED with nothing the engine can do
        return n

    def flush_all(self, max_steps: int = 100000) -> int:
        """Force-flush every dirty block (shutdown / checkpoint path).
        Self-serves flash reads (read-modify-write of translation pages)."""
        n = 0
        while n < max_steps and (self.dtl or self.ctp_fifo
                                 or self.pending_work()):
            if self.dtl:
                self._cmt_flush_one(force=True)
            n += self.run(max_steps - n, auto_flash=True)
            while self.ctp_fifo and n < max_steps:
                self._ctp_writeback_one(force=True)
                n += 1
            n += self.run(max_steps - n, auto_flash=True)
        return n

    def _arbitrate(self) -> Optional[int]:
        nonempty = [q for q in range(5) if self.queues[q]]
        if not nonempty:
            return None
        if all(self.credits[q] <= 0 for q in nonempty):
            self.credits = list(self.g.wrr_weights)
        for q in nonempty:
            if self.credits[q] > 0:
                self.credits[q] -= 1
                return q
        return None

    def set_gc_pressure(self, valid_pages_in_victim: int, pages_per_block: int):
        """Paper §4.6: HRM/GCM weights follow GC victim valid-page count."""
        frac = valid_pages_in_victim / max(pages_per_block, 1)
        w = list(self.g.wrr_weights)
        w[Q_GCM] = max(1, int(round(1 + 3 * frac)))
        w[Q_HRM] = max(1, 4 - w[Q_GCM] + 1)
        object.__setattr__(self.g, "wrr_weights", tuple(w))

    # ---------------------------------------------------------- CMT
    def _cmt_loc(self, dlpn: int) -> Tuple[int, int, int]:
        block_id = dlpn // self.g.cmt_entries
        return block_id, block_id % self.g.cmt_sets, dlpn % self.g.cmt_entries

    def _cmt_handle(self, r: Request, qid: int):
        block_id, s, off = self._cmt_loc(r.dlpn)
        ways = self.cmt[s]
        way = next((w for w in range(self.g.cmt_ways)
                    if ways[w].tag == block_id
                    and (ways[w].valid or ways[w].transient)), None)
        if way is not None and ways[way].transient:
            blk = ways[way]
            if len(blk.mshrs) >= self.g.mshr_cap:          # MSHR full: retry
                self._stall(qid, ("req", r))
                return
            self.stats["mshr_merge"] += 1
            blk.mshrs.append((self._mshr_kind(r.kind), off, r.req_id,
                              r.dppn, r.old_dppn))
            return
        if way is not None:                                 # hit
            self.stats["hit"] += 1
            blk = ways[way]
            blk.refbit = True
            self._apply_to_block(blk, s, way, r.kind, off, r.req_id,
                                 r.dppn, r.old_dppn)
            return
        # miss
        self.stats["miss"] += 1
        vic = self._second_chance(ways, self.cmt_clock, s, self.g.cmt_ways)
        if vic is None:
            # all ways dirty/transient: flush a TVPN owning a dirty block
            # in this set (paper: "not processed until a non-dirty cache
            # block is generated by the flush request"), then retry.
            self._targeted_cmt_flush(s)
            self._stall(qid, ("req", r))
            return
        blk = ways[vic]
        blk.tag = block_id
        blk.valid = False
        blk.transient = True
        blk.refbit = True
        blk.next = NIL
        blk.mshrs = [(self._mshr_kind(r.kind), off, r.req_id, r.dppn,
                      r.old_dppn)]
        tvpn = r.dlpn // self.g.entries_per_tp
        chunk = (r.dlpn % self.g.entries_per_tp) // self.g.cmt_entries
        self.queues[Q_CTP_REQ].append(
            ("ctp", (LOAD, tvpn, chunk, self._pack(s, vic), None)))

    @staticmethod
    def _mshr_kind(kind: int) -> int:
        return {LOOKUP: M_LOOKUP, UPDATE: M_UPDATE, COND_UPDATE: M_COND}[kind]

    def _apply_to_block(self, blk: _Block, s: int, w: int, kind: int,
                        off: int, req_id: int, dppn: int, old: int):
        if kind == LOOKUP:
            self.out_resps.append(Response(req_id, LOOKUP, blk.data[off], ST_OK))
            return
        if kind == COND_UPDATE and blk.data[off] != old:
            self.out_resps.append(Response(req_id, COND_UPDATE, blk.data[off],
                                           ST_STALE))
            return
        blk.data[off] = dppn
        if not blk.dirty:
            blk.dirty = True
            self.cmt_dirty += 1
            self._dtl_register(s, w, blk)
        self.out_resps.append(Response(req_id, kind, dppn, ST_OK))

    def _cmt_fill(self, payload):
        _, tvpn, chunk, dest, data = payload
        s, w = self._unpack(dest)
        blk = self.cmt[s][w]
        assert blk.transient and blk.tag == (
            tvpn * self.g.chunks_per_tp + chunk), "fill/dest mismatch"
        blk.data = list(data)
        blk.transient = False
        blk.valid = True
        mshrs, blk.mshrs = blk.mshrs, []
        for mk, off, req_id, dppn, old in mshrs:   # replay in arrival order
            kind = {M_LOOKUP: LOOKUP, M_UPDATE: UPDATE, M_COND: COND_UPDATE}[mk]
            self._apply_to_block(blk, s, w, kind, off, req_id, dppn, old)

    # ---------------------------------------------------------- DTL
    def _dtl_register(self, s: int, w: int, blk: _Block):
        tvpn = blk.tag // self.g.chunks_per_tp
        for e in self.dtl:
            if e["tvpn"] == tvpn:
                blk.next = e["head"]
                e["head"] = self._pack(s, w)
                e["ndirty"] += 1
                e["updated"] = True
                return
        if len(self.dtl) >= self.g.dtl_entries:    # full: flush oldest now
            self._flush_tvpn(self.dtl[0])
        blk.next = NIL
        self.dtl.append({"tvpn": tvpn, "head": self._pack(s, w),
                         "ndirty": 1, "updated": True})

    def _cmt_flush_needed(self) -> bool:
        nondirty = self.g.cmt_blocks - self.cmt_dirty
        return nondirty < self.g.cmt_low() and bool(self.dtl)

    def _pick_flush_victim(self) -> dict:
        # greedy cost-benefit: most dirty blocks; tie -> oldest registration
        best = max(self.dtl, key=lambda e: e["ndirty"])
        return best

    def _cmt_flush_one(self, force: bool = False) -> bool:
        if not self.dtl:
            return False
        e = self.dtl[0] if force else self._pick_flush_victim()
        self._flush_tvpn(e)
        return True

    def _flush_tvpn(self, e: dict):
        """Walk the next-link chain; emit one FLUSH_BLK per dirty block."""
        self.dtl.remove(e)
        self.stats["flush_tvpns"] += 1
        p = e["head"]
        while p != NIL:
            s, w = self._unpack(p)
            blk = self.cmt[s][w]
            nxt = blk.next
            if blk.dirty:                       # chain only holds dirty blocks
                chunk = blk.tag % self.g.chunks_per_tp
                self.queues[Q_CTP_REQ].append(
                    ("ctp", (FLUSH_BLK, e["tvpn"], chunk, NIL,
                             list(blk.data))))
                blk.dirty = False
                blk.next = NIL
                self.cmt_dirty -= 1
                self.stats["flush_blocks"] += 1
            p = nxt

    # ---------------------------------------------------------- CTP
    def _ctp_handle(self, payload):
        kind, tvpn, chunk, dest, data = payload
        s = tvpn % self.g.ctp_sets
        ways = self.ctp[s]
        way = next((w for w in range(self.g.ctp_ways)
                    if ways[w].tag == tvpn
                    and (ways[w].valid or ways[w].transient)), None)
        if way is not None and ways[way].transient:
            blk = ways[way]
            if len(blk.mshrs) >= self.g.ctp_mshr_cap:
                self._stall(Q_CTP_REQ, ("ctp", payload), front=True)
                return
            self.stats["mshr_merge"] += 1
            blk.mshrs.append((M_LOAD if kind == LOAD else M_FLUSH, chunk,
                              dest, data))
            return
        if way is not None:                     # CTP hit
            self.stats["ctp_hit"] += 1
            blk = ways[way]
            blk.refbit = True
            self._ctp_apply(blk, s, way, kind, chunk, dest, data)
            return
        self.stats["ctp_miss"] += 1
        vic = self._second_chance(ways, self.ctp_clock, s, self.g.ctp_ways)
        if vic is None:
            self._targeted_ctp_writeback(s)
            self._stall(Q_CTP_REQ, ("ctp", payload), front=True)
            return
        blk = ways[vic]
        blk.tag = tvpn
        blk.valid = False
        blk.transient = True
        blk.refbit = True
        blk.mshrs = [(M_LOAD if kind == LOAD else M_FLUSH, chunk, dest, data)]
        tppn = self.gtd[tvpn]
        if tppn == NIL:
            # never-written translation page: implicit all-unmapped
            self._ctp_fill_data(blk, s, vic, [NIL] * self.g.entries_per_tp)
        else:
            self.stats["fc_reads"] += 1
            self.out_fc_reads.append((tppn, s, vic))

    def _ctp_apply(self, blk: _Block, s: int, w: int, kind: int, chunk: int,
                   dest: int, data):
        ec = self.g.cmt_entries
        if kind == LOAD:
            sl = blk.data[chunk * ec:(chunk + 1) * ec]
            tvpn = blk.tag
            self.queues[Q_CTP_RESP].append(
                ("resp", (LOAD_RESP, tvpn, chunk, dest, list(sl))))
        else:  # FLUSH_BLK: merge one CMT block into the page
            blk.data[chunk * ec:(chunk + 1) * ec] = list(data)
            if not blk.dirty:
                blk.dirty = True
                self.ctp_dirty += 1
                if blk.tag not in self.ctp_fifo:   # dedup: <=1 entry/tvpn
                    self.ctp_fifo.append(blk.tag)  # first-dirtied order

    def _ctp_fill(self, tppn: int, s: int, w: int):
        blk = self.ctp[s][w]
        assert blk.transient, "flash response for non-transient block"
        self._ctp_fill_data(blk, s, w, list(self.flash_tp[tppn]))

    def _ctp_fill_data(self, blk: _Block, s: int, w: int, page: List[int]):
        blk.data = page
        blk.transient = False
        blk.valid = True
        mshrs, blk.mshrs = blk.mshrs, []
        for mk, chunk, dest, data in mshrs:
            self._ctp_apply(blk, s, w, LOAD if mk == M_LOAD else FLUSH_BLK,
                            chunk, dest, data)

    def _ctp_writeback_needed(self) -> bool:
        nondirty = self.g.ctp_blocks - self.ctp_dirty
        return nondirty < self.g.ctp_low() and bool(self.ctp_fifo)

    def _ctp_writeback_one(self, force: bool = False) -> bool:
        while self.ctp_fifo:
            tvpn = self.ctp_fifo.popleft()
            s = tvpn % self.g.ctp_sets
            way = next((w for w in range(self.g.ctp_ways)
                        if self.ctp[s][w].tag == tvpn
                        and self.ctp[s][w].valid and self.ctp[s][w].dirty),
                       None)
            if way is None:
                continue                        # already cleaned elsewhere
            blk = self.ctp[s][way]
            tppn = self.tppn_next
            self.tppn_next += 1
            assert self.tppn_next < self.g.tppn_cap, "translation space full"
            self.flash_tp[tppn] = list(blk.data)
            self.gtd[tvpn] = tppn
            blk.dirty = False
            self.ctp_dirty -= 1
            self.stats["programs"] += 1
            self.out_programs.append((tvpn, tppn))
            return True
        return False

    # ---------------------------------------------------------- shared
    def _stall(self, qid: int, item, front: bool = False):
        self.stats["stall"] += 1
        self._stalls_in_row += 1
        if front:      # head-of-line block: preserve FIFO dependencies
            self.queues[qid].appendleft(item)
        else:
            self.queues[qid].append(item)

    def _targeted_cmt_flush(self, s: int):
        """Free a way in CMT set s by flushing a TVPN with a dirty block
        there (keeps a full set from deadlocking on the global watermark)."""
        for w in range(self.g.cmt_ways):
            blk = self.cmt[s][w]
            if blk.dirty:
                tvpn = blk.tag // self.g.chunks_per_tp
                for e in self.dtl:
                    if e["tvpn"] == tvpn:
                        self._flush_tvpn(e)
                        return

    def _targeted_ctp_writeback(self, s: int):
        for w in range(self.g.ctp_ways):
            blk = self.ctp[s][w]
            if blk.dirty and blk.valid:
                tppn = self.tppn_next
                self.tppn_next += 1
                assert self.tppn_next < self.g.tppn_cap
                self.flash_tp[tppn] = list(blk.data)
                self.gtd[blk.tag] = tppn
                blk.dirty = False
                self.ctp_dirty -= 1
                self.stats["programs"] += 1
                self.out_programs.append((blk.tag, tppn))
                return

    @staticmethod
    def _second_chance(ways, clocks, s: int, n_ways: int) -> Optional[int]:
        for i in range(2 * n_ways):
            w = (clocks[s] + i) % n_ways
            blk = ways[w]
            if blk.dirty or blk.transient:
                continue
            if blk.refbit:
                blk.refbit = False
                continue
            clocks[s] = (w + 1) % n_ways
            return w
        return None

    # ---------------------------------------------------------- inspection
    def resolve(self, dlpn: int) -> int:
        """Current logical->physical view through CMT -> CTP -> flash."""
        block_id, s, off = self._cmt_loc(dlpn)
        for w in range(self.g.cmt_ways):
            blk = self.cmt[s][w]
            if blk.valid and blk.tag == block_id:
                return blk.data[off]
        tvpn = dlpn // self.g.entries_per_tp
        ts = tvpn % self.g.ctp_sets
        for w in range(self.g.ctp_ways):
            blk = self.ctp[ts][w]
            if blk.valid and blk.tag == tvpn:
                return blk.data[dlpn % self.g.entries_per_tp]
        tppn = self.gtd[tvpn]
        if tppn == NIL:
            return NIL
        return self.flash_tp[tppn][dlpn % self.g.entries_per_tp]
