"""Shared FMMU protocol: geometry, packet formats, request kinds.

The Python oracle (oracle.py) and the JAX engine (engine.py) implement
the *same* deterministic state machine over these types; property tests
drive both with identical traces and assert identical responses, flash
operations, and final address-translation state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


# --- packet kinds ------------------------------------------------------
LOOKUP = 0        # HRM/GCM -> CMT      f1=dlpn                     f4=req_id
UPDATE = 1        # HRM     -> CMT      f1=dlpn f2=dppn             f4=req_id
COND_UPDATE = 2   # GCM     -> CMT      f1=dlpn f2=dppn f3=old_dppn f4=req_id
LOAD = 3          # CMT -> CTP          f1=tvpn f2=chunk f3=dest(cmt set,way)
FLUSH_BLK = 4     # CMT -> CTP          f1=tvpn f2=chunk data=E_c entries
LOAD_RESP = 5     # CTP -> CMT          f1=tvpn f2=chunk f3=dest data=entries
FC_READ = 6       # CTP -> flash        f1=tppn f3=dest(ctp set,way)
FC_READ_RESP = 7  # flash -> CTP        f1=tppn f3=dest(ctp set,way)
PROGRAM = 8       # CTP -> BM/flash     f1=tvpn f2=new_tppn (write-back)
RESP = 9          # FMMU -> HRM/GCM     f1=req_id f2=dppn f3=status

# RESP status codes
ST_OK = 0
ST_STALE = 1      # CondUpdate lost the race (mapping moved on)

# MSHR kinds logged in transient blocks
M_LOOKUP, M_UPDATE, M_COND, M_LOAD, M_FLUSH = 0, 1, 2, 3, 4

NIL = -1

# Swap-pipeline directions for KV tier moves (paging/kv_manager): a
# relocation between the device tier and the host ("flash"-analogue)
# tier is one fused jitted call — CondUpdate map commit + pool
# gather/scatter + ServingMapState.swap_pending lane update — tagged
# with one of these so stats, tests, and the scheduler name the same
# event the same way.
SWAP_OUT = 0      # device -> host tier (preemption / pool pressure)
SWAP_IN = 1       # host -> device tier (resume a paused sequence)

# Tier tag for physical KV block ids: device blocks are [0, HOST_BASE),
# host ("flash"-analogue) blocks are [HOST_BASE, ...). Canonical home is
# here so both the paging layer (pool.BlockPool) and the device-resident
# allocator (batch.ServingMapState) agree without a layering inversion.
# Must stay >= 1<<24 so kernel value gathers exercise the 16-bit-half
# split (f32 MXU loses integers past 2^24).
HOST_BASE = 1 << 24


@dataclasses.dataclass(frozen=True)
class FMMUGeometry:
    """Sizes follow the paper's §5.1 defaults; tests shrink everything."""
    cmt_sets: int = 512            # 64KB / (8 entries * 4B * 4 ways) ≈ 512
    cmt_ways: int = 4
    cmt_entries: int = 8           # DLPN->DPPN entries per CMT block
    ctp_sets: int = 16             # 1MB / (16KB * 4 ways)
    ctp_ways: int = 4
    entries_per_tp: int = 4096     # 16KB page / 4B entry
    n_tvpns: int = 256             # logical pages / entries_per_tp
    dtl_entries: int = 128
    queue_cap: int = 1024
    mshr_cap: int = 8              # in-cache MSHRs per CMT block (= data area)
    ctp_mshr_cap: int = 64
    tppn_cap: int = 16384          # translation-block physical slots
    low_watermark: float = 0.10    # flush when non-dirty share drops below
    high_watermark: float = 0.25
    wrr_weights: tuple = (4, 4, 2, 2, 1)   # FC_RESP, CTP_RESP, CTP_REQ, HRM, GCM

    def __post_init__(self):
        assert self.entries_per_tp % self.cmt_entries == 0
        assert self.mshr_cap <= self.cmt_entries, "in-cache MSHRs live in the data area"

    @property
    def chunks_per_tp(self) -> int:
        return self.entries_per_tp // self.cmt_entries

    @property
    def cmt_blocks(self) -> int:
        return self.cmt_sets * self.cmt_ways

    @property
    def ctp_blocks(self) -> int:
        return self.ctp_sets * self.ctp_ways

    @property
    def pkt_width(self) -> int:
        return 5 + self.cmt_entries  # kind,f1..f4, inline data

    def cmt_low(self) -> int:
        return max(1, int(self.low_watermark * self.cmt_blocks))

    def cmt_high(self) -> int:
        return max(self.cmt_low() + 1, int(self.high_watermark * self.cmt_blocks))

    def ctp_low(self) -> int:
        return max(1, int(self.low_watermark * self.ctp_blocks))

    def ctp_high(self) -> int:
        return max(self.ctp_low() + 1, int(self.high_watermark * self.ctp_blocks))


def small_geometry(**kw) -> FMMUGeometry:
    """Tiny geometry for tests (matches the paper's Fig. 8 scale)."""
    defaults = dict(cmt_sets=4, cmt_ways=2, cmt_entries=4, ctp_sets=2,
                    ctp_ways=2, entries_per_tp=16, n_tvpns=8,
                    dtl_entries=4, queue_cap=256, mshr_cap=4,
                    ctp_mshr_cap=4, tppn_cap=4096)
    defaults.update(kw)
    return FMMUGeometry(**defaults)


@dataclasses.dataclass
class Request:
    kind: int
    dlpn: int
    dppn: int = NIL
    old_dppn: int = NIL
    req_id: int = NIL
    src: int = 0          # 0 = HRM, 1 = GCM


@dataclasses.dataclass(frozen=True)
class Response:
    req_id: int
    kind: int
    dppn: int
    status: int
