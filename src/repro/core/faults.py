"""Deterministic, seedable fault-injection plane for the serving stack.

The paper's FMMU exists because real NAND misbehaves: programs fail
(bad blocks), channels stall, and relocation must therefore be
retryable — which is exactly what the CondUpdate discipline (commit
only if the mapping still points at the old block) buys. This module
gives the reproduction the missing half: a fault model the layers
above (BlockPool, KVPageManager, ServeEngine) can be driven against.

Design (DESIGN.md "Fault plane as a pytree, recovery as relocation"):

* A ``FaultPlan`` is a **pytree of precomputed schedule arrays**, not a
  set of Python callbacks. Every axis is a function of ``(seed, axis,
  op index)`` through a splitmix64 hash, so a plan is (a) fully
  replayable from its integer seed — the chaos harness prints the seed
  of a failing run and nothing else is needed to reproduce it — (b)
  serializable/shippable like any other state pytree, and (c) inert
  data: consuming it never traces, so attaching a plan to a manager
  provably cannot change any device graph (the jaxpr-identity tests
  assert exactly this).

* Faults are **consumed at host commit points** (swap dispatch, pool
  allocation, map-commit of freshly programmed blocks), indexed by
  per-axis operation counters — never inside a jit. The hot path
  therefore pays zero cost when faults are off *and* when they are on:
  failure and recovery are host-side scheduling decisions, and
  recovery itself reuses the existing fused CondUpdate relocation
  machinery (a bad block is "just another relocation").

Axes modeled (mirroring Copycat/SimpleSSD's per-operation error axes):

* ``swap_fail``  — the i-th tier-move (gather/scatter swap) fails
  before any state mutation; the engine retries with capped
  exponential backoff and quarantines persistent failers.
* ``program_fail`` — the i-th block program fails (a bad block); the
  pool retires the block and the manager re-drives the write through
  the fused CondUpdate path on a same-channel replacement.
* ``alloc_fail`` — the i-th pool allocation transiently reports
  exhaustion (typed ``PoolExhausted(transient=True)``); callers pause
  and retry instead of treating it as terminal pressure.
* ``stall``      — per-channel brownout multipliers (>= 1.0): the
  engine divides a browned-out channel's advertised free-block budget
  by its multiplier, shrinking admission/growth there while the other
  channels keep decoding at full rate.
* ``crash``      — sudden power-off (ISSUE 7): the i-th *journaled*
  commit kills the process, optionally mid-record (``crash_tear``
  bounds how many of the record's bytes reach disk — the torn-tail
  case the OOB reverse-map scan recovers). Consumed by
  ``core.journal.Journal.append``; recovery is
  ``ServeEngine.recover``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import numpy as np

# schedule-axis tags folded into the hash (stable across versions)
AX_SWAP, AX_PROGRAM, AX_ALLOC, AX_STALL = 0, 1, 2, 3
AX_CRASH, AX_TEAR = 4, 5

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)


class Crash(RuntimeError):
    """An injected sudden power-off (ISSUE 7): raised by the journal
    layer at a host commit point AFTER an (optionally partial) record
    write — everything in process memory (map state, pools, caches,
    request bookkeeping) is considered lost the instant this
    propagates. The engine object must not be stepped again; recovery
    goes through ``ServeEngine.recover(path)``, which rebuilds state
    purely from the on-disk snapshot + journal (core/journal.py)."""

    def __init__(self, seq: int, kind: str, torn: bool):
        super().__init__(
            f"injected power cut at journal seq={seq} ({kind}"
            f"{', torn record' if torn else ''})")
        self.seq = seq
        self.kind = kind
        self.torn = torn


class SwapFault(RuntimeError):
    """An injected tier-move (swap gather/scatter) failure. Raised by
    ``KVPageManager._swap`` BEFORE any state mutation — map, pools,
    page lists and free lists are exactly as they were, so the caller
    may simply retry the swap later (capped exponential backoff in
    ``ServeEngine``)."""

    def __init__(self, slot: int, direction: int, n_blocks: int):
        super().__init__(
            f"injected swap failure: slot={slot} direction={direction} "
            f"n_blocks={n_blocks}")
        self.slot = slot
        self.direction = direction
        self.n_blocks = n_blocks


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer: stable forever, everywhere —
    schedules must not drift across numpy versions or platforms.
    uint64 wraparound is the algorithm, not an accident."""
    with np.errstate(over="ignore"):
        z = (x + _GOLDEN).astype(np.uint64)
        z = (z ^ (z >> np.uint64(30))) * _M1
        z = (z ^ (z >> np.uint64(27))) * _M2
        return z ^ (z >> np.uint64(31))


def _unit(seed: int, axis: int, n: int) -> np.ndarray:
    """n deterministic floats in [0, 1) for (seed, axis)."""
    with np.errstate(over="ignore"):
        base = _splitmix64(np.uint64(seed & 0xFFFFFFFFFFFFFFFF)
                           ^ (np.uint64(axis) * _M2))
        idx = np.arange(n, dtype=np.uint64)
        bits = _splitmix64(base + idx * _GOLDEN)
    return (bits >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)


class FaultPlan(NamedTuple):
    """Pytree of per-operation failure schedules. All leaves are plain
    data (numpy); ``seed`` regenerates the whole plan via
    ``make_plan``. Schedules are indexed with wraparound by the
    consuming ``FaultPlane``'s per-axis op counters."""
    seed: int
    swap_fail: np.ndarray      # [H] bool — i-th swap op fails
    program_fail: np.ndarray   # [H] bool — i-th block program fails
    alloc_fail: np.ndarray     # [H] bool — i-th pool alloc is transient-dry
    stall: np.ndarray          # [C] float >= 1 — per-channel brownout
    # sudden power-off axis (ISSUE 7): the i-th *journaled commit*
    # kills the process; tear is how much of that commit's on-disk
    # record bytes land before the cut (1.0 = a whole record, i.e. the
    # crash falls between this commit and the next — mid-record
    # fractions are the torn-tail schedules the SPOR scan recovers)
    crash: np.ndarray = np.zeros(0, bool)        # [H] bool
    crash_tear: np.ndarray = np.zeros(0, float)  # [H] float in [0, 1]


def make_plan(seed: int, *, channels: int = 1,
              swap_fail_p: float = 0.0, program_fail_p: float = 0.0,
              alloc_fail_p: float = 0.0,
              stall: Optional[Sequence[float]] = None,
              crash_p: float = 0.0, crash_at: Optional[int] = None,
              horizon: int = 2048) -> FaultPlan:
    """Build a deterministic plan: schedule bit i of axis a is
    ``hash(seed, a, i) < p``. Two calls with the same arguments yield
    bit-identical plans on any platform. ``crash_at`` pins a
    deterministic power cut at exactly the i-th journaled commit
    (benchmarks and unit tests; composes with crash_p for the chaos
    sweeps)."""
    assert horizon > 0
    st = (np.ones(channels, np.float64) if stall is None
          else np.asarray(stall, np.float64))
    assert st.shape == (channels,), (st.shape, channels)
    assert (st >= 1.0).all(), "stall multipliers are >= 1 (1 = healthy)"
    crash = _unit(seed, AX_CRASH, horizon) < crash_p
    if crash_at is not None:
        assert 0 <= crash_at < horizon, (crash_at, horizon)
        crash = crash.copy()
        crash[crash_at] = True
    return FaultPlan(
        seed=int(seed),
        swap_fail=_unit(seed, AX_SWAP, horizon) < swap_fail_p,
        program_fail=_unit(seed, AX_PROGRAM, horizon) < program_fail_p,
        alloc_fail=_unit(seed, AX_ALLOC, horizon) < alloc_fail_p,
        stall=st,
        crash=crash,
        crash_tear=_unit(seed, AX_TEAR, horizon))


class FaultPlane:
    """Host-side consumer of a ``FaultPlan``: one monotone op counter
    per axis, advanced at each commit point the axis models. Purely
    host state — it never enters a traced graph, which is what makes
    the disabled-fault path jaxpr-identical by construction."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.ops = {"swap": 0, "program": 0, "alloc": 0, "crash": 0}
        self.fired = {"swap": 0, "program": 0, "alloc": 0, "crash": 0}

    def _next(self, axis: str, sched: np.ndarray) -> bool:
        i = self.ops[axis]
        self.ops[axis] = i + 1
        hit = bool(sched[i % len(sched)]) if len(sched) else False
        if hit:
            self.fired[axis] += 1
        return hit

    def swap_fails(self) -> bool:
        """Consume the next swap-op schedule entry."""
        return self._next("swap", self.plan.swap_fail)

    def program_fails(self) -> bool:
        """Consume the next block-program schedule entry."""
        return self._next("program", self.plan.program_fail)

    def alloc_fails(self) -> bool:
        """Consume the next pool-allocation schedule entry."""
        return self._next("alloc", self.plan.alloc_fail)

    def crash_next(self) -> Optional[float]:
        """Consume the next journaled-commit schedule entry: None when
        the process survives this commit, else the tear fraction in
        [0, 1] — how much of the commit's on-disk record bytes the
        journal writes before raising ``Crash`` (1.0 = the record
        lands whole; < 1.0 = a torn tail for the SPOR scan). Consumed
        by ``core.journal.Journal.append``, never inside a jit."""
        i = self.ops["crash"]
        hit = self._next("crash", self.plan.crash)
        if not hit:
            return None
        tear = self.plan.crash_tear
        return float(tear[i % len(tear)]) if len(tear) else 1.0

    def stall_vec(self, channels: int) -> np.ndarray:
        """Per-channel stall multipliers, broadcast to `channels` when
        the plan was built for one channel."""
        st = self.plan.stall
        if len(st) == channels:
            return st
        assert len(st) == 1, (len(st), channels)
        return np.full(channels, float(st[0]))

    def counts(self) -> dict:
        """Fired-fault counts per axis (for hit_stats / diagnostics)."""
        return dict(self.fired)

    def describe(self) -> str:
        p = self.plan
        return (f"FaultPlan(seed={p.seed}, "
                f"swap={int(p.swap_fail.sum())}/{len(p.swap_fail)}, "
                f"program={int(p.program_fail.sum())}/{len(p.program_fail)}, "
                f"alloc={int(p.alloc_fail.sum())}/{len(p.alloc_fail)}, "
                f"crash={int(p.crash.sum())}/{max(len(p.crash), 1)}, "
                f"stall={np.asarray(p.stall).tolist()})")
