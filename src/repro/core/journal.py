"""Crash-consistent map journaling + sudden-power-off recovery (SPOR).

The paper's FMMU keeps the hot map in hardware, but every real FTL
pairs that cache with a persistence story: periodic snapshots of the
map, a write-ahead journal of map commits between snapshots, and — as
the last resort after an unclean power cut — a reverse-map scan of the
per-page OOB (out-of-band) metadata that every NAND program writes
alongside its data. This module gives the serving reproduction the
same three layers (DESIGN.md "Journal at host commit points, snapshot
at macro boundaries, OOB scan as torn-tail fallback"):

* **Journal** — an append-only log of sequence-numbered records, one
  per *host commit point*: exactly the points the ISSUE-6 fault plane
  already intercepts (``KVPageManager.new_seq`` / ``extend_seqs`` /
  ``precommit_growth`` / ``reconcile_macro`` / ``free_seq`` / ``_swap``
  / ``retire_bad_blocks`` / ``gc_collect``) plus the engine's
  request-lifecycle events
  (submit / admit / finish / quarantine). Journaling is pure host-side
  file I/O behind an ``if journal is not None`` guard — it never enters
  a traced graph, so the journaling-disabled path is jaxpr-identical by
  construction (same argument as the fault plane; string-compared in
  tests/test_journal.py).

* **Snapshot** — the full host-authoritative serving state (page
  lists, both pool tiers' free lists in exact order, retired blocks,
  request/admission state) written at configurable macro-boundary
  intervals via the tmp -> ``os.rename`` atomic-commit idiom
  (training/checkpoint.py): a snapshot is either entirely present or
  entirely absent, so the torn-write story lives in the journal alone.

* **OOB region** — before a commit's journal record is appended, the
  blocks it programs write their reverse-map metadata — the
  ``(dlpn, seq)`` owner pairs, plus any bad-block marks — to a
  separate append-only region, mirroring NAND's program-time OOB
  write (data+OOB land before the map metadata does). When the
  journal tail is torn (the power cut fell mid-append), replay stops
  at the last whole record and the recovery falls back to the classic
  SPOR path: a per-channel scan of the OOB region for owners newer
  than the replayed seq reconstructs the newest mapping of each dlpn
  by max-seq and re-frees the displaced blocks. A commit whose OOB
  frame itself tore is dropped cleanly — nothing of it reached the
  "flash", so the pre-commit state is the consistent truth.

Durability model: the simulated power cut (``core.faults`` ``crash``
axis) kills the *process* at a commit point — ``Journal.append``
consults the plane, persists the scheduled fraction of the commit's
bytes, and raises ``faults.Crash``. ``flush()`` to the OS page cache
is therefore "durable" here; a real deployment would add fsync /
O_DSYNC, which changes constants, not structure. Torn tails are
injected byte-exactly, so every truncation offset is reachable by the
property tests.

Recovery (``replay`` -> ``ServeEngine.recover``) rebuilds state as
latest-snapshot + journal replay (+ OOB scan), then restarts every
in-flight request with the ISSUE-6 quarantine discipline — output
reset, requeued at its admission position — because the KV data
itself lived in volatile memory: greedy decode is deterministic and
per-slot independent, so the resumed drain is bit-identical to an
uncrashed run (the chaos crash sweep asserts exactly this).
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import zlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core import faults as flt
from repro.core.fmmu.types import HOST_BASE

# ------------------------------------------------------------- framing
# frame = MAGIC u32 | seq u64 | kind u8 | len u32 | payload | crc32 u32
# (crc over seq..payload). Truncation at ANY byte offset is detected:
# a short header, a short payload, or a crc mismatch all mark the tail
# torn and replay stops at the previous whole record.
_MAGIC = 0x4C4A524E                      # "NRJL"
_HDR = struct.Struct("<IQBI")            # magic, seq, kind, length
_CRC = struct.Struct("<I")

# journal record kinds (stable on-disk tags)
OOB = 0          # oob.log frames only: programmed-block reverse map
NEW_SEQ = 1      # map: fresh sequence admitted (slot, dl, blocks)
EXTEND = 2       # map: decode growth, batched (dl, blocks)
PRECOMMIT = 3    # map: sharded macro boundary pre-commit
RECONCILE = 4    # map: C=1 macro scan's device pops, replayed
FREE = 5         # map: sequence freed (slot, blocks)
SWAP = 6         # map: tier move (slot, moving, fresh, pages after)
RETIRE = 7       # map: bad-block retirement relocation
SUBMIT = 8       # engine: request enqueued (rid, tokens, max_new)
ADMIT = 9        # engine: request admitted to a slot (rid, slot)
FINISH = 10      # engine: request completed (rid, out)
QUAR = 11        # engine: request quarantined + front-requeued (rid)
GC = 12          # map: GC victim-walk relocation (moves, returned)
SHARE = 13       # map: prefix sharing — admission at shared blocks
                 #      (n_shared), tree pin, or tree unpin (op field)
COW = 14         # map: copy-on-write relocation of diverging shared
                 #      pages (moves, returned — GC's lane discipline)

_KIND_NAMES = {OOB: "oob", NEW_SEQ: "new_seq", EXTEND: "extend",
               PRECOMMIT: "precommit", RECONCILE: "reconcile",
               FREE: "free", SWAP: "swap", RETIRE: "retire",
               SUBMIT: "submit", ADMIT: "admit", FINISH: "finish",
               QUAR: "quarantine", GC: "gc", SHARE: "share",
               COW: "cow"}

_JOURNAL = "journal.log"
_OOBLOG = "oob.log"
_SNAP_FMT = "snap_%012d.json"


class JournalError(RuntimeError):
    """Unrecoverable journal corruption (never raised for a torn tail
    — that is the normal SPOR case and recovery handles it)."""


def _frame(seq: int, kind: int, payload: dict) -> bytes:
    body = json.dumps(payload, separators=(",", ":")).encode()
    hdr = _HDR.pack(_MAGIC, seq, kind, len(body))
    return hdr + body + _CRC.pack(zlib.crc32(hdr[4:] + body))


def read_frames(path: str) -> Tuple[List[Tuple[int, int, dict]], int, bool]:
    """Parse an append-only frame log. Returns (frames, valid_bytes,
    torn): frames decoded in file order up to the first incomplete or
    corrupt one; ``valid_bytes`` is where the intact prefix ends;
    ``torn`` is True when trailing bytes exist past it (a record whose
    write was cut by the power failure)."""
    frames: List[Tuple[int, int, dict]] = []
    if not os.path.exists(path):
        return frames, 0, False
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    while True:
        if off + _HDR.size > len(data):
            break
        magic, seq, kind, ln = _HDR.unpack_from(data, off)
        end = off + _HDR.size + ln + _CRC.size
        if magic != _MAGIC or end > len(data):
            break
        body = data[off + _HDR.size:end - _CRC.size]
        (crc,) = _CRC.unpack_from(data, end - _CRC.size)
        if crc != zlib.crc32(data[off + 4:off + _HDR.size] + body):
            break
        frames.append((seq, kind, json.loads(body)))
        off = end
    return frames, off, off < len(data)


# ------------------------------------------------------------- journal
class Journal:
    """Write side: one instance per engine, attached alongside the
    fault plane. ``append`` is the single host commit-point hook; the
    crash axis is consumed HERE — mid-append tears included — so a
    journaled run crashes at exactly the commit points the fault plane
    models (including mid-swap: the swap's record append IS its commit
    point)."""

    def __init__(self, path: str, *,
                 faults: Optional["flt.FaultPlane"] = None,
                 resume: bool = False, keep_snapshots: int = 2):
        os.makedirs(path, exist_ok=True)
        self.dir = path
        self.faults = faults
        self.keep_snapshots = int(keep_snapshots)
        self.dead = False
        self.records = 0          # records appended by THIS instance
        self.commit_lanes = 0     # cumulative committed map-write lanes
        self.lanes_base = 0       # value at attach (integrity baseline)
        jpath = os.path.join(path, _JOURNAL)
        opath = os.path.join(path, _OOBLOG)
        if resume:
            # drop any torn tail (its commit was already folded in — or
            # dropped — by the replay that preceded this resume), then
            # continue the sequence numbering past everything on disk
            frames, nbytes, _ = read_frames(jpath)
            oframes, onbytes, _ = read_frames(opath)
            for p, n in ((jpath, nbytes), (opath, onbytes)):
                if os.path.exists(p):
                    with open(p, "r+b") as f:
                        f.truncate(n)
            self.seq = max([s for s, _, _ in frames + oframes] or [0])
        else:
            for name in os.listdir(path):
                if (name in (_JOURNAL, _OOBLOG)
                        or name.startswith("snap_")):
                    os.remove(os.path.join(path, name))
            self.seq = 0
        self._jf = open(jpath, "ab")
        self._of = open(opath, "ab")

    # ------------------------------------------------------------- io
    def close(self):
        for f in (self._jf, self._of):
            try:
                f.close()
            except ValueError:
                pass

    def _write(self, f, data: bytes):
        f.write(data)
        f.flush()    # durable w.r.t. the modeled process-kill power cut

    def append(self, kind: int, payload: dict,
               programmed: Sequence[Tuple[int, int]] = (),
               retired: Sequence[int] = ()) -> int:
        """Persist one host commit: the OOB frame first (the blocks'
        program-time reverse-map metadata — ``programmed`` is the
        commit's (dlpn, block) pairs, ``retired`` its bad-block
        marks), then the sequence-numbered journal record. Consults
        the fault plane's crash axis: a scheduled power cut persists
        ``tear`` of the commit's bytes and raises ``faults.Crash`` —
        torn OOB = the commit never reached flash (dropped cleanly on
        recovery); whole OOB + torn/absent record = the SPOR scan's
        case (replayed from the reverse map)."""
        assert not self.dead, "journal used after an injected power cut"
        self.seq += 1
        programmed = [[int(d), int(b)] for d, b in programmed]
        retired = [int(b) for b in retired]
        payload = dict(payload)
        payload["lanes"] = payload.get("lanes", len(programmed))
        rec = _frame(self.seq, kind, payload)
        oob = b""
        if programmed or retired:
            oob = _frame(self.seq, OOB,
                         {"pairs": programmed, "retired": retired})
        tear = (self.faults.crash_next()
                if self.faults is not None else None)
        if tear is None:
            if oob:
                self._write(self._of, oob)
            self._write(self._jf, rec)
            self.records += 1
            self.commit_lanes += int(payload["lanes"])
            return self.seq
        # injected sudden power-off: persist a byte-exact prefix of
        # the commit's (oob + record) stream, then die
        total = len(oob) + len(rec)
        cut = max(0, min(total, int(round(tear * total))))
        if oob and cut:
            self._write(self._of, oob[:min(cut, len(oob))])
        if cut > len(oob):
            self._write(self._jf, rec[:cut - len(oob)])
        self.dead = True
        self.close()
        raise flt.Crash(self.seq, _KIND_NAMES.get(kind, str(kind)),
                        torn=cut < total)

    # -------------------------------------------------------- snapshot
    def snapshot(self, state: dict) -> str:
        """Atomically commit a full-state snapshot covering records
        1..seq (tmp -> rename: a snapshot is never torn — the journal
        owns that failure mode). Prunes all but the newest
        ``keep_snapshots``."""
        assert not self.dead
        doc = {"seq": self.seq, "lanes": self.commit_lanes}
        doc.update(state)
        path = os.path.join(self.dir, _SNAP_FMT % self.seq)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, separators=(",", ":"))
        os.replace(tmp, path)
        snaps = sorted(n for n in os.listdir(self.dir)
                       if n.startswith("snap_") and not n.endswith(".tmp"))
        for n in snaps[:-self.keep_snapshots]:
            os.remove(os.path.join(self.dir, n))
        return path


# ------------------------------------------------------------ recovery
@dataclasses.dataclass
class Recovered:
    """Replay output: the host-authoritative serving state as of the
    crash, plus recovery diagnostics. Everything is plain host data —
    ``KVPageManager.restore_mapping`` re-derives the device map state
    from it (the map is a pure function of the page lists; the CMT
    refills warm, which SPOR always pays)."""
    cfg: dict
    seq_pages: Dict[int, List[int]]
    host_pages: Dict[int, int]
    free_dev_ch: List[List[int]]
    free_host_ch: List[List[int]]
    rr: int
    retired: Set[int]
    retired_ch: List[int]
    exhausted_ch: List[int]
    stats: dict
    queue: List[int]                 # rids, crash-time deque order
    ever_admitted: Set[int]
    active: Dict[int, int]           # rid -> slot, admission order
    done: Dict[int, List[int]]
    submits: Dict[int, Tuple[List[int], int]]
    rid: int
    boundary: int
    # prefix sharing (ISSUE 10): mapping refcounts of share-managed
    # blocks and the radix tree's pinned set. Durable truth for the
    # free-gate; the tree CONTENT is volatile and never recovered.
    ref: Dict[int, int] = dataclasses.field(default_factory=dict)
    pinned: Set[int] = dataclasses.field(default_factory=set)
    # diagnostics
    snap_seq: int = 0
    last_seq: int = 0
    replayed: int = 0
    lanes: int = 0
    torn: bool = False
    oob_scan: bool = False

    # ------------------------------------------------------ invariants
    def check(self):
        """Map-consistency invariants ("never a corrupt map"): every
        block lives in exactly one of {a free list, a page list, the
        retired set}; free lists respect channel striping; page lists
        have no holes. Prefix sharing (ISSUE 10) relaxes exactly one
        clause: a share-managed block (in ``ref``) may appear in
        SEVERAL page lists — then its refcount must equal its mapper
        count, and a pinned block with zero mappers is owned by the
        tree. Raises JournalError on violation."""
        C = self.cfg["channels"]
        n_dev, n_host = self.cfg["n_device"], self.cfg["n_host"]
        seen: Dict[int, str] = {}

        def claim(b, who):
            if b in seen:
                raise JournalError(
                    f"block {b} owned twice: {seen[b]} and {who}")
            seen[b] = who

        for c in range(C):
            for b in self.free_dev_ch[c]:
                if b % C != c or not 0 <= b < n_dev:
                    raise JournalError(f"dev block {b} in channel {c}")
                claim(b, f"free_dev[{c}]")
            for b in self.free_host_ch[c]:
                i = b - HOST_BASE
                if i % C != c or not 0 <= i < n_host:
                    raise JournalError(f"host block {b} in channel {c}")
                claim(b, f"free_host[{c}]")
        mappers: Dict[int, int] = {}
        for s, pages in self.seq_pages.items():
            for b in pages:
                if b in self.ref:
                    mappers[b] = mappers.get(b, 0) + 1
                    if mappers[b] == 1:
                        claim(b, f"slot{s}")
                else:
                    claim(b, f"slot{s}")
            hp = sum(b >= HOST_BASE for b in pages)
            if hp != self.host_pages.get(s, 0):
                raise JournalError(
                    f"slot {s}: host_pages {self.host_pages.get(s, 0)}"
                    f" != counted {hp}")
        for b, n in self.ref.items():
            if n != mappers.get(b, 0):
                raise JournalError(
                    f"shared block {b}: refcount {n} != "
                    f"{mappers.get(b, 0)} mapping slots")
            if b not in seen:
                if b not in self.pinned:
                    raise JournalError(
                        f"share-managed block {b} has no owner")
                claim(b, "pinned")      # tree holds the last reference
        for b in self.retired:
            claim(b, "retired")
        every = ([b for b in range(n_dev)]
                 + [HOST_BASE + i for i in range(n_host)])
        missing = [b for b in every if b not in seen]
        if missing:
            raise JournalError(f"blocks unaccounted for: {missing}")

    def mapping(self) -> Dict[int, int]:
        """dlpn -> block of every mapped page (the dense-table view of
        the recovered map; the property tests compare this against the
        pre-/post-commit oracle maps)."""
        mp = self.cfg["max_pages"]
        return {s * mp + i: b
                for s, pages in self.seq_pages.items()
                for i, b in enumerate(pages)}


def _fresh_shadow(cfg: dict) -> Recovered:
    C = cfg["channels"]
    return Recovered(
        cfg=cfg,
        seq_pages={}, host_pages={},
        free_dev_ch=[[b for b in range(cfg["n_device"])
                      if b % C == c][::-1] for c in range(C)],
        free_host_ch=[[HOST_BASE + i for i in range(cfg["n_host"])
                       if i % C == c][::-1] for c in range(C)],
        rr=0, retired=set(), retired_ch=[0] * C, exhausted_ch=[0] * C,
        stats={"allocs": 0, "frees": 0, "swaps_out": 0, "swaps_in": 0,
               "peak_used": 0, "retired": 0},
        queue=[], ever_admitted=set(), active={}, done={}, submits={},
        rid=0, boundary=0)


def _load_snapshot(sh: Recovered, doc: dict):
    sh.seq_pages = {int(s): list(p)
                    for s, p in doc["seq_pages"].items()}
    sh.host_pages = {int(s): int(n)
                     for s, n in doc["host_pages"].items()}
    sh.free_dev_ch = [list(ch) for ch in doc["free_dev_ch"]]
    sh.free_host_ch = [list(ch) for ch in doc["free_host_ch"]]
    sh.rr = int(doc["rr"])
    sh.retired = set(doc["retired"])
    sh.retired_ch = list(doc["retired_ch"])
    sh.exhausted_ch = list(doc["exhausted_ch"])
    sh.stats = dict(doc["stats"])
    # request bookkeeping is absent from manager-only snapshots
    # (KVPageManager.snapshot_state without an engine)
    sh.queue = list(doc.get("queue", []))
    sh.ever_admitted = set(doc.get("ever_admitted", []))
    sh.active = {int(r): int(s) for r, s in doc.get("active", [])}
    sh.done = {int(r): list(o) for r, o in doc.get("done", {}).items()}
    sh.submits = {int(r): (list(t), int(m))
                  for r, (t, m) in doc.get("submits", {}).items()}
    sh.rid = int(doc.get("rid", 0))
    sh.boundary = int(doc.get("boundary", 0))
    sh.ref = {int(b): int(n) for b, n in doc.get("ref", {}).items()}
    sh.pinned = set(int(b) for b in doc.get("pinned", []))
    sh.lanes = int(doc.get("lanes", 0))


def _channel_of(cfg: dict, block: int) -> int:
    b = block - HOST_BASE if block >= HOST_BASE else block
    return b % cfg["channels"]


def _take(sh: Recovered, block: int, host: bool):
    lists = sh.free_host_ch if host else sh.free_dev_ch
    ch = lists[_channel_of(sh.cfg, block)]
    try:
        ch.remove(block)
    except ValueError:
        raise JournalError(
            f"replay popped block {block} that is not free")


def _peak(sh: Recovered):
    """Mirror BlockPool._bump_alloc's peak tracking: sampled right
    after an allocation's pops, before any frees in the same commit."""
    used = sh.cfg["n_device"] - sum(len(c) for c in sh.free_dev_ch)
    sh.stats["peak_used"] = max(sh.stats["peak_used"], used)


def _give(sh: Recovered, block: int):
    if block in sh.retired:
        return
    host = block >= HOST_BASE
    lists = sh.free_host_ch if host else sh.free_dev_ch
    lists[_channel_of(sh.cfg, block)].append(block)


def _unref_give(sh: Recovered, block: int) -> int:
    """Drop one mapping reference and give the block back only when no
    references remain (KVPageManager._unref's shadow twin): untracked
    blocks free as before; a share-managed block returns to the pool at
    zero refs with no tree pin. Returns 1 when a non-retired block
    actually reached a free list (the live run's ``frees`` increment)."""
    n = sh.ref.get(block)
    if n is not None:
        sh.ref[block] = n - 1
        if n - 1 > 0 or block in sh.pinned:
            return 0
        del sh.ref[block]
    _give(sh, block)
    return int(block not in sh.retired)


def _apply(sh: Recovered, kind: int, p: dict):
    """Replay one whole journal record onto the shadow state. The
    free-list mutations remove exactly the block ids the live pool
    popped from its list tails, so the surviving list ORDER matches
    the live pool's bit-for-bit — which is what makes the post-restore
    allocator mirror (sync_allocator) exact."""
    mp = sh.cfg["max_pages"]
    if kind == NEW_SEQ:
        for b in p["blocks"]:
            _take(sh, b, host=False)
        _peak(sh)
        sh.seq_pages[p["slot"]] = list(p["blocks"])
        sh.stats["allocs"] += len(p["blocks"])
    elif kind in (EXTEND, PRECOMMIT, RECONCILE):
        for d, b in zip(p["dl"], p["blocks"]):
            _take(sh, b, host=False)
            sh.seq_pages[d // mp].append(b)
        _peak(sh)
        sh.stats["allocs"] += len(p["blocks"])
        if "rr" in p:
            sh.rr = p["rr"]
    elif kind == FREE:
        sh.seq_pages.pop(p["slot"], None)
        sh.host_pages.pop(p["slot"], None)
        # refcount-gated (ISSUE 10): per-block in lane order, exactly
        # the live free_seq — share-managed blocks only reach the free
        # list when their last mapper lets go (and no tree pin holds)
        sh.stats["frees"] += sum(_unref_give(sh, b)
                                 for b in p["blocks"])
    elif kind == SWAP:
        for b in p["fresh"]:
            _take(sh, b, host=p["out"])
        _peak(sh)
        for b in p["moving"]:
            _give(sh, b)
        sh.seq_pages[p["slot"]] = list(p["pages"])
        sh.host_pages[p["slot"]] = p["hp"]
        key = "swaps_out" if p["out"] else "swaps_in"
        sh.stats[key] += len(p["moving"])
        sh.stats["frees"] += sum(b not in sh.retired
                                 for b in p["moving"])
        sh.stats["allocs"] += len(p["fresh"])
    elif kind == RETIRE:
        for b in p["popped"]:
            _take(sh, b, host=False)
            _peak(sh)    # live pops one candidate per alloc_for call
        sh.stats["allocs"] += len(p["popped"])
        for b in p["retired"]:
            sh.retired.add(b)
            sh.retired_ch[_channel_of(sh.cfg, b)] += 1
        sh.stats["retired"] += len(p["retired"])
        for s, pages in p["pages"].items():
            sh.seq_pages[int(s)] = list(pages)
    elif kind == GC:
        # GC victim-walk relocation (ISSUE 9): the live run popped ALL
        # destinations first (pool.alloc_gc per channel), dispatched
        # the batched CondUpdate, then freed the applied lanes' old
        # frames followed by the stale lanes' unused destinations
        # ("returned"). Takes all precede gives here too, so the peak
        # sample and the surviving free-list order match the live pool
        # bit-for-bit (removal is by value; appends are in the live
        # free() order: applied olds, then returned news).
        for d, old, new in p["moves"]:
            _take(sh, new, host=False)
        for b in p.get("returned", []):
            _take(sh, b, host=False)
        _peak(sh)
        sh.stats["allocs"] += len(p["moves"]) + len(p.get("returned", []))
        for d, old, new in p["moves"]:
            sh.seq_pages[d // mp][d % mp] = new
        freed = 0
        for d, old, new in p["moves"]:
            _give(sh, old)
            freed += int(old not in sh.retired)
        for b in p.get("returned", []):
            _give(sh, b)
            freed += int(b not in sh.retired)
        sh.stats["frees"] += freed
    elif kind == SHARE:
        op = p.get("op")
        if op is None:
            # shared admission: only the fresh tail left the free
            # lists; the leading n_shared blocks are references to
            # blocks another slot (or the tree) already owns
            k = p["n_shared"]
            for b in p["blocks"][k:]:
                _take(sh, b, host=False)
            _peak(sh)
            sh.seq_pages[p["slot"]] = list(p["blocks"])
            sh.stats["allocs"] += len(p["blocks"]) - k
            for b in p["blocks"][:k]:
                sh.ref[b] = sh.ref.get(b, 0) + 1
        elif op == "pin":
            # a pin converts the owner's private block to share-managed
            # (ref counts its one mapping) and adds the tree reference
            for b in p["blocks"]:
                sh.pinned.add(b)
                sh.ref.setdefault(b, 1)
        else:
            assert op == "unpin", op
            freed = 0
            for b in p["blocks"]:
                sh.pinned.discard(b)
                if sh.ref.get(b, 0) <= 0:
                    sh.ref.pop(b, None)
                    _give(sh, b)
                    freed += int(b not in sh.retired)
            sh.stats["frees"] += freed
    elif kind == COW:
        # copy-on-write relocation: like GC, all destination pops
        # precede any gives (stale lanes' unused destinations return
        # last); the old shared frame drops ONE mapping ref and only
        # reaches the free list when it was the last
        for s, pg, old, new in p["moves"]:
            _take(sh, new, host=False)
        for b in p.get("returned", []):
            _take(sh, b, host=False)
        _peak(sh)
        sh.stats["allocs"] += len(p["moves"]) + len(p.get("returned", []))
        freed = 0
        for s, pg, old, new in p["moves"]:
            sh.seq_pages[s][pg] = new
            freed += _unref_give(sh, old)
        for b in p.get("returned", []):
            _give(sh, b)
            freed += int(b not in sh.retired)
        sh.stats["frees"] += freed
    elif kind == SUBMIT:
        sh.submits[p["rid"]] = (list(p["tokens"]), p["max_new"])
        sh.queue.append(p["rid"])
        sh.rid = max(sh.rid, p["rid"] + 1)
    elif kind == ADMIT:
        if p["rid"] in sh.queue:
            sh.queue.remove(p["rid"])
        sh.active.pop(p["rid"], None)   # re-admission moves to the end
        sh.active[p["rid"]] = p["slot"]
        sh.ever_admitted.add(p["rid"])
        sh.boundary = max(sh.boundary, p.get("boundary", 0))
    elif kind == FINISH:
        sh.done[p["rid"]] = list(p["out"])
        sh.active.pop(p["rid"], None)
        sh.submits.pop(p["rid"], None)
    elif kind == QUAR:
        sh.active.pop(p["rid"], None)
        sh.queue.insert(0, p["rid"])
        sh.ever_admitted.add(p["rid"])
    else:
        raise JournalError(f"unknown journal record kind {kind}")
    sh.lanes += int(p.get("lanes", 0))


def _oob_scan(sh: Recovered, pairs: List[List[int]],
              retired: List[int]):
    """The SPOR torn-tail fallback: the dangling commit's journal
    record never made it, but its blocks' program-time OOB metadata
    did. Each channel's flash array (blocks with block % C == c)
    yields its own (dlpn, seq) owners newer than the replayed seq
    (here: the one dangling frame, already newer than everything
    replayed); the per-channel owner sets are then merged and applied
    in dlpn order — a slot's pages stripe ACROSS channels, so
    channel-major application would see page holes for any commit
    programming more pages than channels. A displaced older owner
    returns to the free pool; OOB bad-block marks re-apply retirement
    (the bad-block table also lives in OOB on real NAND). A retired
    mark must also pull the block out of its shadow free list when
    present: the live run popped schedule-failed replacement
    candidates from the pool before retiring them, and the replayed
    shadow never saw those pops (tolerant miss — a bad block
    displaced from a page list was never free)."""
    mp = sh.cfg["max_pages"]
    for b in retired:
        if b in sh.retired:
            continue
        lists = sh.free_host_ch if b >= HOST_BASE else sh.free_dev_ch
        ch = lists[_channel_of(sh.cfg, b)]
        if b in ch:
            ch.remove(b)
        sh.retired.add(b)
        sh.retired_ch[_channel_of(sh.cfg, b)] += 1
        sh.stats["retired"] += 1
    # prefix sharing (ISSUE 10): a dangling SHARE commit's OOB frame
    # carries metadata-only owner pairs for its shared lanes — their
    # blocks are already mapped elsewhere, so the scan bumps a mapping
    # ref instead of popping a free list; a displaced older owner
    # likewise drops ONE ref and frees only as the last mapper.
    mapped = {b for ps in sh.seq_pages.values() for b in ps}
    taken = 0
    for d, b in sorted((int(d), int(b)) for d, b in pairs):
        slot, page = divmod(d, mp)
        pages = sh.seq_pages.setdefault(slot, [])
        if page > len(pages):
            raise JournalError(
                f"OOB owner (dlpn={d}) maps a hole at page {page}")
        if b in mapped or b in sh.ref:    # shared lane — or a block
            sh.ref[b] = sh.ref.get(b, 0) + 1   # only the tree still holds
        else:
            _take(sh, b, host=b >= HOST_BASE)
            taken += 1
            mapped.add(b)
        if page == len(pages):
            pages.append(b)
        else:
            old = pages[page]
            pages[page] = b
            if old != b:
                _unref_give(sh, old)
        sh.host_pages[slot] = sum(x >= HOST_BASE for x in pages)
    sh.stats["allocs"] += taken


def latest_snapshot(path: str) -> Optional[dict]:
    snaps = sorted((n for n in os.listdir(path)
                    if n.startswith("snap_") and n.endswith(".json")),
                   reverse=True)
    for name in snaps:
        try:
            with open(os.path.join(path, name)) as f:
                return json.load(f)
        except (OSError, ValueError):
            continue    # unreadable snapshot: fall back to the previous
    return None


def replay(path: str) -> Recovered:
    """Rebuild the crash-time serving state from disk: latest
    snapshot, then every whole journal record past it, then — when
    the journal tail is torn or a commit's record never landed — the
    OOB reverse-map scan for the single dangling commit (OOB frames
    are written before their record, so at most one commit can be
    newer than the journal). Ends with the map-consistency check:
    recovery either replays a tail commit fully or drops it cleanly,
    never a corrupt map."""
    snap = latest_snapshot(path)
    if snap is None:
        raise JournalError(f"no snapshot in {path}")
    sh = _fresh_shadow(snap["cfg"])
    _load_snapshot(sh, snap)
    sh.snap_seq = snap["seq"]

    frames, _, torn = read_frames(os.path.join(path, _JOURNAL))
    last = sh.snap_seq
    for seq, kind, p in frames:
        if seq <= sh.snap_seq:
            continue
        if seq != last + 1:
            raise JournalError(
                f"journal gap: record {seq} after {last}")
        _apply(sh, kind, p)
        sh.replayed += 1
        last = seq
    sh.torn = torn
    sh.last_seq = last

    oframes, _, otorn = read_frames(os.path.join(path, _OOBLOG))
    dangling = [(s, p) for s, k, p in oframes if s > last and k == OOB]
    if len(dangling) > 1:
        raise JournalError(
            f"multiple dangling OOB commits: {[s for s, _ in dangling]}")
    if dangling:
        seq, p = dangling[0]
        _oob_scan(sh, p["pairs"], p["retired"])
        sh.oob_scan = True
        sh.last_seq = seq
        sh.replayed += 1
    sh.torn = torn or otorn or sh.oob_scan

    # a FREE / FINISH pair cut between records can strand a mapped
    # slot with no owning request (FINISH landed, FREE did not): give
    # the orphan's pages back — the request is done, its KV is gone.
    # Only meaningful when request bookkeeping exists at all (an
    # engine journal); a bare map-layer journal owns no slots.
    if sh.active or sh.submits or sh.queue or sh.done or sh.ever_admitted:
        owned = set(sh.active.values())
        for slot in [s for s in sh.seq_pages if s not in owned]:
            for b in sh.seq_pages.pop(slot):
                sh.stats["frees"] += _unref_give(sh, b)
            sh.host_pages.pop(slot, None)
    sh.check()
    return sh
