"""Structured counter registry for host-side instrumentation.

The serving stack grew a handful of module-level mutable-list counters
(``XLATE_CALLS = [0]`` in kv_manager, ``PROBE_TRACES``/``INSERT_TRACES``
in the fused map layer, ``MACRO_DISPATCHES``/``HOST_SYNCS`` in the
engine). Each is a one-element list so call sites can bump shared state
without ``global``; tests snapshot them by hand with ad-hoc
``before = X[0]`` bookkeeping. This module keeps the cheap mutable-cell
representation — a cell IS still a one-element list, and the historical
module-level names are re-bound to the very same list objects, so every
existing ``NAME[0]`` read or ``NAME[0] += 1`` bump keeps working — but
hangs every cell off one registry with ``snapshot()/reset()/delta()``
so contract tests and the bench can treat "all counters" as a value.

Counters are host-only instrumentation: nothing here ever enters a
traced graph, and trace-time counters (``fmmu.probe_traces``) count
*tracings*, not executions, exactly as before.
"""
from __future__ import annotations

from typing import Dict, List, Optional


class Counters:
    """A named registry of mutable integer cells.

    ``cell(name)`` returns the underlying one-element list itself (not a
    copy) — aliasing it to a module-level name preserves the legacy
    ``NAME[0] += 1`` idiom at zero cost while keeping the cell
    enumerable through the registry.
    """

    def __init__(self) -> None:
        self._cells: Dict[str, List[int]] = {}

    def cell(self, name: str) -> List[int]:
        """Get (or create at 0) the mutable cell for ``name``."""
        return self._cells.setdefault(name, [0])

    def snapshot(self) -> Dict[str, int]:
        """Current value of every registered counter, as plain ints."""
        return {k: int(v[0]) for k, v in self._cells.items()}

    def reset(self, name: Optional[str] = None) -> None:
        """Zero one counter (or all of them when ``name`` is None).

        Resets mutate the existing cells in place — aliases stay valid.
        """
        if name is not None:
            self.cell(name)[0] = 0
            return
        for v in self._cells.values():
            v[0] = 0

    def delta(self, base: Dict[str, int]) -> Dict[str, int]:
        """Per-counter change since a prior ``snapshot()``.

        Counters created after the base snapshot report their full
        current value (base 0).
        """
        return {k: int(v[0]) - int(base.get(k, 0))
                for k, v in self._cells.items()}


# The process-wide registry. Subsystems register their cells at import
# time (`X = COUNTERS.cell("sub.x")`) and keep bumping `X[0]` as before.
COUNTERS = Counters()
