"""Discrete-event SSD simulator (DiskSim/SSD-extension style, §5.1).

Models: multi-channel/multi-way flash (chip cell-op servers + per-channel
bus pipes), NVMe host pipes, a map unit (software FTL on 1..n cores, or
the FMMU hardware pipeline), write buffering with NAND backpressure,
page-mapped BM with greedy GC, and shared in-flight translation-page
reads (the simulator-level realization of non-blocking miss merging).

The "ideal" scheme has zero FTL execution time — the paper's ideal
anchor. Absolute ideal numbers derive from Table 1 timing from first
principles (they differ from DiskSim's internal overheads; EXPERIMENTS.md
§Paper-repro reports both and validates ratios/shapes).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.configs.fmmu_paper import SSDConfig
from repro.core.ftl.costmodel import us
from repro.core.ftl.mapcache import SCHEMES, AccessPlan, FMMUCache
from repro.core.sim.events import EventQueue, Pipe, Server


@dataclasses.dataclass
class Cmd:
    op: str              # 'r' | 'w'
    dlpn: int            # first logical page
    npages: int
    bytes_per_page: int  # host payload per page (<= page size)


class SSDSim:
    WRITE_BUF_BYTES = 64 << 20

    def __init__(self, cfg: SSDConfig, scheme: str = "fmmu",
                 n_cores: int = 1, t_ftl_us: Optional[float] = None,
                 fixed_miss: bool = False, zero_exec: bool = False):
        # fixed_miss: with scheme='ideal'/fixed cost, force every access
        # through a translation-page flash read (Fig. 2 'map miss' case)
        # zero_exec: paper's "ideal" — the map cache behaves normally
        # (incl. its translation-page flash IO) but costs zero exec time
        self.cfg = cfg
        self.ev = EventQueue()
        n = cfg.nand
        self.page = n.page_data_bytes
        self.ppb = n.pages_per_block
        self.n_chips = cfg.channels * cfg.ways
        self.chips = [Server(self.ev, 1, f"chip{i}")
                      for i in range(self.n_chips)]
        self.buses = [Pipe(self.ev, n.bus_mbps, f"ch{c}",
                           op_overhead_us=n.bus_op_overhead_us)
                      for c in range(cfg.channels)]
        self.host_in = Pipe(self.ev, cfg.host_bw_gbps * 1000, "host_in")
        self.host_out = Pipe(self.ev, cfg.host_bw_gbps * 1000, "host_out")
        self.scheme = scheme
        self.t_ftl_us = t_ftl_us
        self.fixed_miss = fixed_miss
        self.zero_exec = zero_exec
        if scheme in SCHEMES:
            self.cache = SCHEMES[scheme](cfg)
            cores = 1 if scheme == "fmmu" else n_cores
        else:                      # 'ideal' or fixed-cost
            self.cache = None
            cores = max(1, n_cores)
        self.map_unit = Server(self.ev, cores, "ftl")
        # --- BM / physical state ---
        self.n_pages_logical = cfg.logical_pages
        n_blocks = int(cfg.physical_pages // self.ppb)
        self.n_blocks = n_blocks
        self.map = np.full(self.n_pages_logical, -1, np.int64)   # truth
        self.rmap = np.full(n_blocks * self.ppb, -1, np.int64)
        self.valid = np.zeros(n_blocks, np.int32)
        self.next_page = np.zeros(n_blocks, np.int32)
        self.free_blocks = list(range(self.n_chips, n_blocks))[::-1]
        self.active = list(range(self.n_chips))  # one active block per chip
        self.rr_chip = 0
        # GC thresholds adapt to the over-provisioning headroom so that
        # in-flight GC copies can never exhaust the reserve:
        #   max GC demand = GC_PARALLEL blocks <= RESERVE_BLOCKS - margin
        logical_blocks = self.n_pages_logical // self.ppb
        op_blocks = max(4, n_blocks - logical_blocks)
        self.GC_PARALLEL = min(16, max(2, op_blocks // 8))
        self.RESERVE_BLOCKS = self.GC_PARALLEL + 2
        self.GC_LOW = self.RESERVE_BLOCKS + self.GC_PARALLEL
        self.GC_HIGH = min(max(op_blocks // 2, self.GC_LOW + 2),
                           self.GC_LOW * 2)
        self.gc_chains = 0
        self.in_gc: set = set()
        self.free_pages = (len(self.free_blocks) + len(self.active)) * self.ppb
        self.alloc_waiters: List[Callable] = []
        self.write_buf = self.WRITE_BUF_BYTES
        self.buf_waiters: List[Tuple[int, Callable]] = []
        # shared in-flight TP reads: tvpn -> waiter callbacks
        self.tp_inflight: Dict[int, List[Callable]] = {}
        self.stats = {"reads": 0, "writes": 0, "gc_moves": 0, "erases": 0,
                      "tp_reads": 0, "tp_programs": 0, "host_bytes": 0}

    # ----------------------------------------------------------- layout
    def chip_of_block(self, blk: int) -> int:
        return blk % self.n_chips

    def chan_of_chip(self, chip: int) -> int:
        return chip % self.cfg.channels

    def tp_chip(self, tvpn: int) -> int:
        return tvpn % self.n_chips

    # ----------------------------------------------------------- alloc
    def _alloc(self) -> int:
        """Allocate next physical page, striping chips round-robin."""
        for _ in range(self.n_chips):
            chip = self.rr_chip
            self.rr_chip = (self.rr_chip + 1) % self.n_chips
            blk = self.active[chip]
            if self.next_page[blk] < self.ppb:
                p = blk * self.ppb + int(self.next_page[blk])
                self.next_page[blk] += 1
                self.free_pages -= 1
                return p
            if self.free_blocks:
                nb = self.free_blocks.pop()
                self.active[chip] = nb
                p = nb * self.ppb
                self.next_page[nb] = 1
                self.free_pages -= 1
                return p
        raise RuntimeError("out of space (GC failing)")

    def _host_can_alloc(self) -> bool:
        return self.free_pages > self.RESERVE_BLOCKS * self.ppb

    def _host_alloc_gate(self, cb: Callable):
        """Backpressure: host writes wait while GC digs out of the
        reserve (real SSDs throttle exactly like this)."""
        if self._host_can_alloc():
            cb()
        else:
            self.alloc_waiters.append(cb)
            self._maybe_gc()

    def _release_alloc_waiters(self):
        while self.alloc_waiters and self._host_can_alloc():
            self.alloc_waiters.pop(0)()

    def _write_page(self, dlpn: int, dppn: int):
        old = self.map[dlpn]
        if old >= 0:
            self.valid[old // self.ppb] -= 1
        self.map[dlpn] = dppn
        self.rmap[dppn] = dlpn
        self.valid[dppn // self.ppb] += 1

    # ----------------------------------------------------------- flash ops
    def flash_read(self, dppn_chip: int, nbytes: int, done: Callable):
        chip = dppn_chip
        self.chips[chip].request(
            self.cfg.nand.read_us,
            lambda: self.buses[self.chan_of_chip(chip)].transfer(nbytes, done))

    def flash_program(self, chip: int, nbytes: int, done: Callable):
        self.buses[self.chan_of_chip(chip)].transfer(
            nbytes,
            lambda: self.chips[chip].request(self.cfg.nand.program_us, done))

    def flash_erase(self, chip: int, done: Callable):
        self.chips[chip].request(self.cfg.nand.erase_us, done)

    # ----------------------------------------------------------- map unit
    def map_access(self, dlpn: int, write: bool, done: Callable):
        """Run the map-cache access (exec + possible TP read + flush IO)."""
        if self.cache is None:
            t = self.t_ftl_us or 0.0
            tvpn = dlpn // self.cfg.entries_per_tp

            def finish():
                if self.fixed_miss:
                    self.stats["tp_reads"] += 1
                    self.flash_read(self.tp_chip(tvpn), self.page,
                                    lambda: (self.map_unit.request(t, done)
                                             if t > 0 else done()))
                else:
                    done()

            if t > 0:
                self.map_unit.request(t, finish)
            else:
                self.ev.after(0.0, finish)
            return
        plan = self.cache.access(dlpn, write)
        if self.zero_exec:
            plan.cycles = 0.0
            plan.fill_cycles = 0.0
            if plan.flush is not None:
                plan.flush.cycles = 0.0
        if plan.flush is not None:
            self._schedule_flush(plan.flush)

        def after_exec():
            if plan.tp_read is None:
                done()
            else:
                self._tp_read(plan.tp_read, plan.fill_cycles, done)

        if self.zero_exec:
            self.ev.after(0.0, after_exec)
            return
        if self.scheme == "fmmu":
            # pipelined hardware: occupancy = initiation interval,
            # remaining latency elapses without holding the unit
            from repro.core.ftl.costmodel import HW
            occ = us(min(plan.cycles, HW.pipeline_ii))
            lat = us(plan.cycles) - occ
            self.map_unit.request(occ, lambda: self.ev.after(lat, after_exec))
        else:
            self.map_unit.request(us(plan.cycles), after_exec)

    def _tp_read(self, tvpn: int, fill_cycles: float, done: Callable):
        if tvpn in self.tp_inflight:            # merge (MSHR semantics)
            if isinstance(self.cache, FMMUCache):
                extra = us(min(self.cache.merged_cycles(), 16))
            else:
                extra = us(100)
            self.tp_inflight[tvpn].append(
                lambda: self.map_unit.request(extra, done))
            return
        self.tp_inflight[tvpn] = []
        self.stats["tp_reads"] += 1

        def arrived():
            waiters = self.tp_inflight.pop(tvpn, [])
            self.map_unit.request(us(fill_cycles), done)
            for wcb in waiters:
                wcb()

        chip = self.tp_chip(tvpn)
        self.flash_read(chip, self.page, arrived)

    def _schedule_flush(self, fw):
        for tvpn in fw.tp_reads:
            self.stats["tp_reads"] += 1
            self.flash_read(self.tp_chip(tvpn), self.page, lambda: None)
        for tvpn in fw.tp_programs:
            self.stats["tp_programs"] += 1
            self.flash_program(self.tp_chip(tvpn), self.page, lambda: None)
        if fw.cycles:
            self.map_unit.request(us(fw.cycles), lambda: None)

    # ----------------------------------------------------------- GC
    def _maybe_gc(self):
        if len(self.free_blocks) >= self.GC_LOW:
            return
        while self.gc_chains < self.GC_PARALLEL:
            if not self._gc_step():
                break

    def _gc_step(self) -> bool:
        if len(self.free_blocks) >= self.GC_HIGH:
            return False
        active = set(self.active)
        cands = [b for b in range(self.n_blocks)
                 if b not in active and b not in self.in_gc
                 and self.next_page[b] >= self.ppb]
        if not cands:
            return False
        victim = min(cands, key=lambda b: self.valid[b])
        self.in_gc.add(victim)
        self.gc_chains += 1
        pages = [victim * self.ppb + i for i in range(self.ppb)]
        live = [p for p in pages if self.rmap[p] >= 0
                and self.map[self.rmap[p]] == p]
        moves = len(live)
        self.stats["gc_moves"] += moves

        def next_move(i: int):
            if i >= len(live):
                def erased():
                    self.stats["erases"] += 1
                    self.next_page[victim] = 0
                    self.valid[victim] = 0
                    self.free_blocks.append(victim)
                    self.free_pages += self.ppb
                    self.in_gc.discard(victim)
                    self.gc_chains -= 1
                    self._release_alloc_waiters()
                    self._maybe_gc()

                self.flash_erase(self.chip_of_block(victim), erased)
                return
            src = live[i]
            dlpn = int(self.rmap[src])

            def after_read():
                dst = self._alloc()

                def after_prog():
                    # CondUpdate through the map unit (GCM path)
                    if self.map[dlpn] == src:   # not raced by host write
                        self._write_page(dlpn, dst)
                    self.map_access(dlpn, True, lambda: next_move(i + 1))

                self.flash_program(self.chip_of_block(dst // self.ppb),
                                   self.page, after_prog)

            self.flash_read(self.chip_of_block(src // self.ppb), self.page,
                            after_read)

        next_move(0)

    # ----------------------------------------------------------- host ops
    def read_page(self, dlpn: int, nbytes: int, done: Callable):
        self.stats["reads"] += 1

        def after_map():
            dppn = int(self.map[dlpn])
            chip = (self.chip_of_block(dppn // self.ppb) if dppn >= 0
                    else dlpn % self.n_chips)

            def after_flash():
                self.stats["host_bytes"] += nbytes
                self.host_out.transfer(nbytes, done)

            self.flash_read(chip, nbytes, after_flash)

        self.map_access(dlpn, False, after_map)

    def write_page(self, dlpn: int, nbytes: int, done: Callable):
        self.stats["writes"] += 1

        def buffered():
            self.stats["host_bytes"] += nbytes
            dppn = self._alloc()
            self._write_page(dlpn, dppn)
            self._maybe_gc()

            def after_prog():
                self.write_buf += self.page
                if self.buf_waiters:
                    nb, cb = self.buf_waiters.pop(0)
                    self._acquire_buf(nb, cb)

            self.flash_program(self.chip_of_block(dppn // self.ppb),
                               self.page, after_prog)
            self.map_access(dlpn, True, done)   # ack after map update

        def after_host():
            self._acquire_buf(self.page, lambda: self._host_alloc_gate(buffered))

        self.host_in.transfer(nbytes, after_host)

    def _acquire_buf(self, nbytes: int, cb: Callable):
        if self.write_buf >= nbytes:
            self.write_buf -= nbytes
            cb()
        else:
            self.buf_waiters.append((nbytes, cb))

    # ----------------------------------------------------------- driver
    def submit(self, cmd: Cmd, done: Callable):
        left = [cmd.npages]

        def page_done():
            left[0] -= 1
            if left[0] == 0:
                done()

        for i in range(cmd.npages):
            dlpn = (cmd.dlpn + i) % self.n_pages_logical
            if cmd.op == "r":
                self.read_page(dlpn, cmd.bytes_per_page, page_done)
            else:
                self.write_page(dlpn, cmd.bytes_per_page, page_done)

    def precondition_sequential(self):
        """Instant (untimed) sequential fill of the whole logical space:
        map, BM and cache state warmed per-policy, no events."""
        for dlpn in range(self.n_pages_logical):
            dppn = self._alloc()
            self._write_page(dlpn, dppn)
            if self.cache is not None:
                self.cache.access(dlpn, True)
        if self.cache is not None:
            self.cache.stats = {k: (0 if isinstance(v, (int, float)) else v)
                                for k, v in self.cache.stats.items()}

    def run_closed_loop(self, workload: Iterator[Cmd], n_cmds: int,
                        outstanding: Optional[int] = None,
                        warmup_cmds: int = 0) -> dict:
        """Closed-loop driver; with warmup_cmds, an untimed steady-state
        warmup phase precedes measurement (stats reset at the boundary)."""
        outstanding = outstanding or self.cfg.outstanding
        it = iter(workload)
        if warmup_cmds:
            state_w = {"issued": 0, "done": 0}

            def issue_w():
                if state_w["issued"] >= warmup_cmds:
                    return
                try:
                    cmd = next(it)
                except StopIteration:
                    state_w["issued"] = warmup_cmds
                    return
                state_w["issued"] += 1
                self.submit(cmd, lambda: (state_w.__setitem__(
                    "done", state_w["done"] + 1), issue_w()))

            for _ in range(min(outstanding, warmup_cmds)):
                issue_w()
            self.ev.run()
            for srv in self.chips + [self.map_unit]:
                srv.busy_time = 0.0
            for p in self.buses + [self.host_in, self.host_out]:
                p.srv.busy_time = 0.0
            for k in self.stats:
                self.stats[k] = 0
        state = {"issued": 0, "done": 0}
        t0 = self.ev.now

        def issue_next():
            if state["issued"] >= n_cmds:
                return
            try:
                cmd = next(it)
            except StopIteration:
                state["issued"] = n_cmds
                return
            state["issued"] += 1
            self.submit(cmd, lambda: (state.__setitem__("done", state["done"] + 1),
                                      issue_next()))

        for _ in range(min(outstanding, n_cmds)):
            issue_next()
        self.ev.run()
        elapsed = self.ev.now - t0
        chips_util = float(np.mean([c.utilization(elapsed) for c in self.chips]))
        bus_util = float(np.mean([b.utilization(elapsed) for b in self.buses]))
        res = {
            "elapsed_us": elapsed,
            "cmds": state["done"],
            "iops": state["done"] / (elapsed / 1e6) if elapsed else 0.0,
            "gbps": self.stats["host_bytes"] / max(elapsed, 1e-9) / 1000.0,
            "util_chip": chips_util,
            "util_bus": bus_util,
            "util_ftl": self.map_unit.utilization(elapsed),
            "util_host": max(self.host_in.utilization(elapsed),
                             self.host_out.utilization(elapsed)),
            "stats": dict(self.stats),
        }
        if self.cache is not None:
            res["cache"] = dict(self.cache.stats)
        return res
