"""Workload generators: the paper's synthetic set (§5.1) and MSR/UMass
trace *surrogates* matched to Table 3's statistics (the original traces
are not redistributable and this container is offline; EXPERIMENTS.md
flags every number derived from surrogates)."""
from __future__ import annotations

import dataclasses
import random
from typing import Iterator

from repro.configs.fmmu_paper import SSDConfig
from repro.core.sim.ssd import Cmd


def _pages(cfg: SSDConfig, nbytes: int) -> int:
    return max(1, nbytes // cfg.nand.page_data_bytes)


def rand_read_4k(cfg: SSDConfig, seed: int = 0) -> Iterator[Cmd]:
    rng = random.Random(seed)
    n = cfg.logical_pages
    while True:
        yield Cmd("r", rng.randrange(n), 1, 4096)


def rand_write_4k(cfg: SSDConfig, seed: int = 0) -> Iterator[Cmd]:
    rng = random.Random(seed)
    n = cfg.logical_pages
    while True:
        yield Cmd("w", rng.randrange(n), 1, 4096)


def seq_read_64k(cfg: SSDConfig) -> Iterator[Cmd]:
    npg = _pages(cfg, 65536)
    pos = 0
    n = cfg.logical_pages
    while True:
        yield Cmd("r", pos, npg, cfg.nand.page_data_bytes)
        pos = (pos + npg) % n


def seq_write_64k(cfg: SSDConfig) -> Iterator[Cmd]:
    npg = _pages(cfg, 65536)
    pos = 0
    n = cfg.logical_pages
    while True:
        yield Cmd("w", pos, npg, cfg.nand.page_data_bytes)
        pos = (pos + npg) % n


# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Table 3 statistics."""
    name: str
    read_ratio: float          # of commands
    avg_read_kb: float
    avg_write_kb: float
    hot_fraction: float        # footprint share receiving most accesses
    hot_weight: float          # probability mass on the hot set
    seq_prob: float            # chance a command continues a stream


MSR_PROJ = TraceSpec("MSR_proj", 0.1248, 17.83, 40.91, 0.04, 0.70, 0.55)
MSR_HM = TraceSpec("MSR_hm", 0.3550, 7.36, 8.33, 0.04, 0.85, 0.25)
WEBSEARCH = TraceSpec("WebSearch", 0.9998, 15.14, 8.60, 0.15, 0.80, 0.40)

TRACES = {t.name: t for t in (MSR_PROJ, MSR_HM, WEBSEARCH)}


def trace_surrogate(cfg: SSDConfig, spec: TraceSpec,
                    seed: int = 0) -> Iterator[Cmd]:
    rng = random.Random(seed)
    n = cfg.logical_pages
    hot_n = max(1, int(n * spec.hot_fraction))
    stream_pos = rng.randrange(n)

    def pick_lpn() -> int:
        if rng.random() < spec.hot_weight:
            return rng.randrange(hot_n)
        return hot_n + rng.randrange(max(1, n - hot_n))

    while True:
        is_read = rng.random() < spec.read_ratio
        avg_kb = spec.avg_read_kb if is_read else spec.avg_write_kb
        # sizes ~ clipped exponential around the Table-3 mean
        kb = max(4, min(512, int(rng.expovariate(1.0 / avg_kb)) or 4))
        npg = max(1, (kb * 1024) // cfg.nand.page_data_bytes)
        if rng.random() < spec.seq_prob:
            lpn = stream_pos
            stream_pos = (stream_pos + npg) % n
        else:
            lpn = pick_lpn()
            stream_pos = (lpn + npg) % n
        last_bytes = min(kb * 1024, npg * cfg.nand.page_data_bytes)
        yield Cmd("r" if is_read else "w", lpn, npg,
                  min(cfg.nand.page_data_bytes, last_bytes))
