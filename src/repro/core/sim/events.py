"""Minimal discrete-event kernel + resource primitives for the SSD sim."""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Callable, List, Optional, Tuple


class EventQueue:
    def __init__(self):
        self._h: List[Tuple[float, int, Callable]] = []
        self._seq = itertools.count()
        self.now = 0.0

    def at(self, t: float, fn: Callable):
        heapq.heappush(self._h, (max(t, self.now), next(self._seq), fn))

    def after(self, dt: float, fn: Callable):
        self.at(self.now + dt, fn)

    def run(self, until: Optional[float] = None) -> float:
        while self._h:
            t, _, fn = heapq.heappop(self._h)
            if until is not None and t > until:
                heapq.heappush(self._h, (t, next(self._seq), fn))
                self.now = until
                return self.now
            self.now = t
            fn()
        return self.now

    def __bool__(self):
        return bool(self._h)


class Server:
    """k identical units with a shared FIFO queue. Tracks busy time."""

    def __init__(self, ev: EventQueue, k: int, name: str = ""):
        self.ev = ev
        self.k = k
        self.name = name
        self.free = k
        self.q: deque = deque()
        self.busy_time = 0.0

    def request(self, dur: float, done: Callable):
        if self.free > 0:
            self.free -= 1
            self._start(dur, done)
        else:
            self.q.append((dur, done))

    def _start(self, dur: float, done: Callable):
        self.busy_time += dur

        def finish():
            if self.q:
                ndur, ndone = self.q.popleft()
                self._start(ndur, ndone)
            else:
                self.free += 1
            done()

        self.ev.after(dur, finish)

    def utilization(self, elapsed: float) -> float:
        return self.busy_time / max(elapsed * self.k, 1e-12)


class Pipe:
    """Serial bandwidth resource (one transfer at a time, FIFO)."""

    def __init__(self, ev: EventQueue, bytes_per_us: float, name: str = "",
                 op_overhead_us: float = 0.0):
        self.srv = Server(ev, 1, name)
        self.bpu = bytes_per_us
        self.ovh = op_overhead_us

    def transfer(self, nbytes: float, done: Callable):
        self.srv.request(nbytes / self.bpu + self.ovh, done)

    def utilization(self, elapsed: float) -> float:
        return self.srv.utilization(elapsed)
