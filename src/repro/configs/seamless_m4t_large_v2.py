"""SeamlessM4T-Large-v2 — encoder-decoder multimodal (audio) backbone.

[arXiv:2308.11596; hf-verified tier]
24 encoder + 24 decoder layers, d_model 1024, 16 heads (MHA: kv=16,
head_dim 64), d_ff 8192, vocab 256206. The speech frontend
(w2v-BERT conformer feature extractor) is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings [B, S_src, d].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    act="gelu",
    frontend="audio",
    norm_eps=1e-5,
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large",
)
