"""LLaVA-NeXT (v1.6) Mistral-7B — VLM; transformer backbone only.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified tier]
32 layers, d_model 4096, 32 heads (GQA kv=8, head_dim 128), d_ff 14336,
vocab 32000. The anyres-tiling vision tower (CLIP-ViT-L + projector) is
a STUB per the assignment: ``input_specs()`` provides precomputed patch
embeddings [B, prefix_len, d] (anyres: up to 5 tiles x 576 patches = 2880).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    frontend="vision",
    prefix_len=2880,
    norm_eps=1e-5,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (backbone: mistralai/Mistral-7B-Instruct-v0.2)",
)
