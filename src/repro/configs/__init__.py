"""Config registry: ``--arch <id>`` ids map to ArchConfig instances."""
from __future__ import annotations

from repro.configs.base import (
    ArchConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES,
    shape_applicable, smoke_config,
)
from repro.configs.fmmu_paper import PAPER_SSD, SSDConfig, NAND_V1, NAND_V2

from repro.configs import (
    jamba_1_5_large_398b,
    mamba2_1_3b,
    qwen2_72b,
    gemma2_9b,
    llama3_2_1b,
    glm4_9b,
    seamless_m4t_large_v2,
    dbrx_132b,
    arctic_480b,
    llava_next_mistral_7b,
)

_MODULES = [
    jamba_1_5_large_398b,
    mamba2_1_3b,
    qwen2_72b,
    gemma2_9b,
    llama3_2_1b,
    glm4_9b,
    seamless_m4t_large_v2,
    dbrx_132b,
    arctic_480b,
    llava_next_mistral_7b,
]

ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def arch_ids():
    return list(ARCHS.keys())


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells():
    """Every assigned (arch, shape) dry-run cell with applicability flag."""
    out = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            ok, why = shape_applicable(a, s)
            out.append((a, s, ok, why))
    return out


__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
    "shape_applicable", "smoke_config", "ARCHS", "arch_ids", "get_arch",
    "get_shape", "all_cells", "PAPER_SSD", "SSDConfig", "NAND_V1", "NAND_V2",
]
