"""Qwen2-72B — dense decoder with GQA and QKV bias.

[arXiv:2407.10671; hf-verified tier]
80 layers, d_model 8192, 64 heads (GQA kv=8), d_ff 29568, vocab 152064.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    source="arXiv:2407.10671; hf:Qwen/Qwen2-72B",
)
