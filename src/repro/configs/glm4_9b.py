"""GLM4-9B — dense decoder, RoPE, aggressive GQA (kv=2).

[hf:THUDM/glm-4-9b; hf-verified tier]
40 layers, d_model 4096, 32 heads (GQA kv=2, head_dim 128), d_ff 13696,
vocab 151552. (GLM4 uses partial rotary (0.5); we apply full RoPE — noted
as an adaptation in DESIGN.md since it does not change any roofline term.)
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    qkv_bias=True,
    norm_eps=1.5625e-07,
    source="hf:THUDM/glm-4-9b",
)
