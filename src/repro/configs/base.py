"""Architecture / shape configuration dataclasses.

Every assigned architecture gets one module in this package holding an
``ArchConfig`` with the exact published numbers (source cited in the
module docstring). ``smoke_config`` derives a reduced same-family config
for CPU smoke tests; the full configs are only ever lowered via the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden size
    every: int = 1               # layer i hosts MoE iff (i % every) == moe_offset
    moe_offset: int = 0
    dense_residual: bool = False  # arctic: dense FFN running in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_dim: int = 4            # depthwise causal conv width

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # --- attention details ---
    qkv_bias: bool = False
    use_rope: bool = True        # jamba: no positional encoding (mamba provides order)
    rope_theta: float = 10_000.0
    attn_softcap: float = 0.0    # gemma2 attention-logit softcap
    final_softcap: float = 0.0   # gemma2 final-logit softcap
    sliding_window: int = 0      # window for 'local' layers; 0 = full attention
    layer_pattern: Tuple[str, ...] = ()   # e.g. ('local','global'); () = all 'global'
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    post_norms: bool = False     # gemma2 post-attention/post-ffn extra norms
    act: str = "silu"            # 'silu' (SwiGLU) | 'gelu' (GeGLU)
    # --- moe ---
    moe: Optional[MoEConfig] = None
    # --- ssm / hybrid ---
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0          # hybrid: one attention layer per period of this many
    attn_offset: int = 0         # index of the attention layer within the period
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    # --- modality frontend stub ---
    frontend: str = ""           # '' | 'audio' | 'vision'
    prefix_len: int = 0          # frames/patches prepended by the stub
    source: str = ""             # citation string

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.layer_pattern:
            assert self.n_layers % len(self.layer_pattern) == 0, self.name
        if self.attn_every:
            assert self.n_layers % self.attn_every == 0, self.name

    # --- structural helpers -------------------------------------------
    @property
    def period(self) -> int:
        """Length of the repeating layer super-block (for lax.scan)."""
        p = 1
        if self.layer_pattern:
            p = math.lcm(p, len(self.layer_pattern))
        if self.attn_every:
            p = math.lcm(p, self.attn_every)
        if self.moe is not None and self.moe.every > 1:
            p = math.lcm(p, self.moe.every)
        return p

    def layer_kind(self, i: int) -> str:
        """'attn' | 'mamba' for mixer at layer i."""
        if self.family == "ssm":
            return "mamba"
        if self.attn_every:
            return "attn" if (i % self.attn_every) == self.attn_offset else "mamba"
        return "attn"

    def attn_kind(self, i: int) -> str:
        """'global' | 'local' attention flavour at layer i."""
        if self.layer_pattern:
            return self.layer_pattern[i % len(self.layer_pattern)]
        return "global"

    def is_moe_layer(self, i: int) -> bool:
        m = self.moe
        return m is not None and (i % m.every) == m.moe_offset

    @property
    def n_attn_layers(self) -> int:
        return sum(1 for i in range(self.n_layers) if self.layer_kind(i) == "attn")

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run 500k-token contexts (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (seamless is enc-dec)

    # --- parameter counting (for 6ND roofline terms) -------------------
    def _mixer_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "mamba":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            # in_proj: d -> 2*di + 2*n_groups*d_state + nh  (x, z, B, C, dt)
            in_p = d * (2 * di + 2 * s.d_state + nh)
            conv = (di + 2 * s.d_state) * s.conv_dim
            out_p = di * d
            extra = nh * 2 + di  # A_log, dt_bias, norm
            return in_p + conv + out_p + extra
        # attention
        q = d * self.n_heads * self.head_dim
        kv = 2 * d * self.n_kv_heads * self.head_dim
        o = self.n_heads * self.head_dim * d
        b = (self.n_heads + 2 * self.n_kv_heads) * self.head_dim if self.qkv_bias else 0
        return q + kv + o + b

    def _ffn_params(self, i: int) -> Tuple[int, int]:
        """(total, active) FFN params at layer i."""
        d = self.d_model
        dense = 3 * d * self.d_ff if self.d_ff else 0
        if self.is_moe_layer(i):
            m = self.moe
            expert = 3 * d * m.d_ff
            total = m.n_experts * expert + d * m.n_experts  # + router
            active = m.top_k * expert + d * m.n_experts
            if m.dense_residual:
                total += dense
                active += dense
            return total, active
        return dense, dense

    def count_params(self) -> Tuple[int, int]:
        """(total, active) parameter counts, embeddings included once."""
        d = self.d_model
        total = active = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d      # lm head
            active += self.vocab_size * d
        n_dec = self.n_layers
        for i in range(n_dec):
            mix = self._mixer_params(self.layer_kind(i))
            ff_t, ff_a = self._ffn_params(i)
            norms = 2 * d * (2 if self.post_norms else 1)
            total += mix + ff_t + norms
            active += mix + ff_a + norms
        for _ in range(self.n_enc_layers):   # encoder: full attn + dense ffn
            mix = self._mixer_params("attn")
            total += mix + 3 * d * self.d_ff + 2 * d
            active += mix + 3 * d * self.d_ff + 2 * d
        if self.n_enc_layers:                # decoder cross-attention
            for _ in range(n_dec):
                mix = self._mixer_params("attn")
                total += mix + d
                active += mix + d
        return total, active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a valid dry-run cell, with reason if not."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "full-attention arch: 500k context is quadratic-infeasible (DESIGN.md §5)"
    return True, ""


# ----------------------------------------------------------------------
def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests (one super-block period,
    tiny widths, few experts) — preserves every structural feature."""
    period = cfg.period
    n_layers = period * (2 if period <= 4 else 1)
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff=64)
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else n_heads
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        moe=moe,
        ssm=ssm,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        prefix_len=8 if cfg.prefix_len else 0,
    )
