"""Llama-3.2-1B — small dense llama3 decoder.

[hf:meta-llama/Llama-3.2-1B; unverified tier]
16 layers, d_model 2048, 32 heads (GQA kv=8, head_dim 64), d_ff 8192,
vocab 128256, tied embeddings, rope theta 500000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500_000.0,
    norm_eps=1e-5,
    source="hf:meta-llama/Llama-3.2-1B",
)
