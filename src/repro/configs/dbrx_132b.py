"""DBRX-132B — fine-grained MoE decoder (16 experts, top-4).

[hf:databricks/dbrx-base; unverified tier]
40 layers, d_model 6144, 48 heads (GQA kv=8, head_dim 128), per-expert
d_ff 10752, 16 experts top-4 on every layer, vocab 100352.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff=10752, every=1),
    norm_eps=1e-5,
    source="hf:databricks/dbrx-base",
)
