"""Mamba2-1.3b — pure SSM (state-space duality / SSD), attention-free.

[arXiv:2405.21060; unverified tier]
48 layers, d_model 2048, attention-free (d_ff=0: the Mamba2 block replaces
both mixer and MLP), vocab 50280, ssm_state=128, headdim 64, expand 2.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    norm_eps=1e-5,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-1.3b",
)
