"""The paper's own experimental configuration (FMMU, Woo & Min 2017).

Table 1 (V1/V2 2-bit 3D NAND), §5.1 experimental setup: 16GB SSD,
16-channel × 8-way, 15% over-provisioning, two planes per chip,
NVMe over PCIe 3.0 x16 (15.76 GB/s), 1,088KB map-cache RAM
(DFTL: all CMT; CDFTL/FMMU: 64KB CMT + 1,024KB CTP), second-chance
replacement everywhere, 400MHz ARM Cortex-R4 / 400MHz FMMU clock.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NandTiming:
    """Table 1 — per-die timing/geometry of 2-bit 3D NAND."""
    name: str
    page_data_bytes: int
    page_oob_bytes: int
    pages_per_block: int
    read_us: float
    program_us: float
    erase_us: float
    bus_mbps: float          # per-channel data transfer rate (MB/s)
    bus_op_overhead_us: float = 0.2   # cmd/addr cycles + DMA setup per op

    @property
    def block_bytes(self) -> int:
        return self.page_data_bytes * self.pages_per_block

    def transfer_us(self, nbytes: int) -> float:
        return nbytes / self.bus_mbps  # MB/s == bytes/us


# V1: 8K page, 3M+336K block -> 384 pages/block; V2: 16K page, 4M block -> 256
NAND_V1 = NandTiming("V1", 8192, 896, 384, 49.0, 600.0, 4000.0, 533.0)
NAND_V2 = NandTiming("V2", 16384, 1536, 256, 35.0, 390.0, 4000.0, 667.0)


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    nand: NandTiming = NAND_V2
    channels: int = 16
    ways: int = 8
    planes: int = 2
    capacity_gb: int = 16
    op_ratio: float = 0.15           # over-provisioning share of raw capacity
    sector_bytes: int = 4096         # host logical sector (4KB)
    host_bw_gbps: float = 15.76      # NVMe over PCIe 3.0 x16
    outstanding: int = 512
    # --- map cache unit (bytes of RAM) ---
    map_ram_bytes: int = 1088 * 1024
    cmt_ram_bytes: int = 64 * 1024   # CDFTL / FMMU first level
    ctp_ram_bytes: int = 1024 * 1024
    cmt_block_entries: int = 8       # consecutive DLPN->DPPN entries per CMT block
    assoc: int = 4                   # set associativity (both levels)
    map_entry_bytes: int = 4         # DPPN width
    # --- FMMU engine ---
    fmmu_clock_mhz: float = 400.0
    cpu_clock_mhz: float = 400.0     # ARM Cortex-R4
    dtl_entries: int = 128
    flush_low_watermark: float = 0.10   # of blocks non-dirty
    flush_high_watermark: float = 0.25

    @property
    def entries_per_tp(self) -> int:
        """DLPN->DPPN entries per translation page."""
        return self.nand.page_data_bytes // self.map_entry_bytes

    @property
    def n_chips(self) -> int:
        return self.channels * self.ways

    @property
    def logical_pages(self) -> int:
        usable = int(self.capacity_gb * (1 << 30))
        return usable // self.nand.page_data_bytes

    @property
    def physical_pages(self) -> int:
        raw = int(self.capacity_gb * (1 << 30) / (1.0 - self.op_ratio))
        return raw // self.nand.page_data_bytes

    @property
    def host_transfer_us_4k(self) -> float:
        return 4096 / (self.host_bw_gbps * 1000.0)  # GB/s == bytes/ns -> us


PAPER_SSD = SSDConfig()
