"""Jamba-1.5-Large (398B total / ~94B active) — hybrid Mamba+attention MoE.

[arXiv:2403.19887 + ai21labs/AI21-Jamba-1.5-Large; hf-verified tier]
72 layers, d_model 8192, 64 Q heads (GQA kv=8), d_ff 24576, vocab 65536,
MoE 16 experts top-2 on every 2nd layer, attention 1:7 interleave
(attn_layer_period=8, attn_layer_offset=4), no RoPE (Mamba carries order).
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    use_rope=False,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=24576, every=2, moe_offset=1),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    attn_every=8,
    attn_offset=4,
    norm_eps=1e-6,
    source="arXiv:2403.19887; hf:ai21labs/AI21-Jamba-1.5-Large",
)
