"""Gemma2-9B — dense decoder, alternating local/global attention, softcaps.

[arXiv:2408.00118; hf-verified tier]
42 layers, d_model 3584, 16 heads (GQA kv=8, head_dim 256), d_ff 14336,
vocab 256000, sliding window 4096 on local layers, attn softcap 50,
final-logit softcap 30, GeGLU, pre+post norms, tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    layer_pattern=("local", "global"),
    tie_embeddings=True,
    post_norms=True,
    act="gelu",
    norm_eps=1e-6,
    source="arXiv:2408.00118; hf:google/gemma-2-9b",
)
