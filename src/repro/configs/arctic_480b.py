"""Snowflake Arctic (480B) — dense+MoE hybrid: 128 experts top-2 with a
dense residual MLP in parallel on every layer.

[hf:Snowflake/snowflake-arctic-base; hf-verified tier]
35 layers, d_model 7168, 56 heads (GQA kv=8, head_dim 128), d_ff 4864
(both the dense residual MLP and each expert), 128 experts top-2,
vocab 32000. 56 Q heads are not divisible by the 16-way model axis —
GSPMD shards unevenly (pads 56→64); recorded in DESIGN.md §5.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff=4864, every=1,
                  dense_residual=True),
    norm_eps=1e-5,
    source="hf:Snowflake/snowflake-arctic-base",
)
