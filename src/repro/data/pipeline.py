"""Deterministic synthetic LM data pipeline: document sampling, packing
with segment ids, host-side prefetch, per-host sharding.

Synthetic corpus: "documents" are integer sequences from a seeded
zipf-ish unigram model with strong local structure (bigram chains) so
that small models show real loss curves. Deterministic per (seed, step,
host): restarts and elastic rescales reproduce the exact stream.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    pack: bool = True
    mean_doc_len: int = 96
    prefetch: int = 2
    host_index: int = 0
    host_count: int = 1


def _doc(rng: np.random.Generator, cfg: DataConfig) -> np.ndarray:
    n = max(8, int(rng.exponential(cfg.mean_doc_len)))
    v = cfg.vocab_size
    start = rng.integers(2, v)
    # bigram chain: next token is a deterministic mix of prev + noise
    toks = [start]
    for _ in range(n - 1):
        nxt = (toks[-1] * 31 + 7) % (v - 2) + 2 if rng.random() < 0.7 \
            else int(rng.integers(2, v))
        toks.append(nxt)
    return np.asarray(toks, np.int32)


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """One deterministic global batch (this host's shard)."""
    per_host = cfg.global_batch // cfg.host_count
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_index]))
    tokens = np.zeros((per_host, cfg.seq_len), np.int32)
    labels = np.full((per_host, cfg.seq_len), -1, np.int32)
    segs = np.zeros((per_host, cfg.seq_len), np.int32)
    pos = np.zeros((per_host, cfg.seq_len), np.int32)
    for b in range(per_host):
        off, seg = 0, 0
        while off < cfg.seq_len:
            d = _doc(rng, cfg)
            take = min(len(d), cfg.seq_len - off)
            tokens[b, off:off + take] = d[:take]
            labels[b, off:off + take - 1] = d[1:take]
            segs[b, off:off + take] = seg
            pos[b, off:off + take] = np.arange(take)
            off += take
            seg += 1
            if not cfg.pack:
                break
    out = {"tokens": tokens, "labels": labels, "positions": pos}
    if cfg.pack:
        out["segment_ids"] = segs
    return out


class Prefetcher:
    """Background-thread batch producer (host-side pipeline overlap)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._t = threading.Thread(target=self._work, daemon=True)
        self._t.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self.q.get()
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._t.join(timeout=2)


def data_iter(cfg: DataConfig, start_step: int = 0, prefetch: bool = True):
    if prefetch:
        return Prefetcher(cfg, start_step)

    def gen():
        step = start_step
        while True:
            yield make_batch(cfg, step)
            step += 1

    return gen()
