"""AdamW with ZeRO-style state sharding and gradient clipping.

Pure-JAX (no optax): states are a pytree mirroring params. Optimizer
moments inherit the parameter's tensor-parallel sharding AND are
additionally sharded over the data axis on their largest divisible dim
(ZeRO-1 flavour) via with_sharding_constraint inside the update step —
GSPMD keeps them resident in the sharded layout between steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def zero_shard_specs(param_specs, param_shapes, ctx) -> Any:
    """Moments: param spec + data-axis sharding on the largest
    still-unsharded divisible dimension (ZeRO-1). Specs stay LOGICAL
    ('data'); ctx.resolve expands to the physical (pod, data) axes."""
    dp = "data"
    dp_size = ctx.dp_size

    def one(spec, shape_leaf):
        shape = shape_leaf.shape if hasattr(shape_leaf, "shape") else shape_leaf
        entries = list(spec) + [None] * (len(shape) - len(spec))
        best, best_size = -1, 0
        for i, (s, n) in enumerate(zip(entries, shape)):
            if s is None and n % dp_size == 0 and n > best_size:
                best, best_size = i, n
        if best >= 0:
            entries[best] = dp
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree.map(one, param_specs, param_shapes,
                        is_leaf=lambda s: isinstance(s, P))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState,
                 moment_shardings=None):
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** step)
        vhat = v2 / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:   # no decay on norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    params2 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    mu2 = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    nu2 = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    if moment_shardings is not None:
        mu2 = jax.lax.with_sharding_constraint(mu2, moment_shardings)
        nu2 = jax.lax.with_sharding_constraint(nu2, moment_shardings)
    return params2, OptState(step=step, mu=mu2, nu=nu2), \
        {"lr": lr, "grad_norm": gnorm}
