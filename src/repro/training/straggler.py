"""Straggler detection & mitigation hooks.

On real multi-host deployments each host reports its step wall-time; a
host whose EWMA-normalized time exceeds k·sigma is flagged, and the
driver can (a) log+alert, (b) trigger elastic rescale without it, or
(c) skip-step by quorum. Single-process here: the monitor tracks the
local step-time distribution and the same thresholding logic, and the
tests inject synthetic delays (simulated slow hosts) to verify the
detector + the quorum policy."""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple


@dataclasses.dataclass
class StragglerEvent:
    step: int
    host: int
    step_time: float
    ewma: float
    threshold: float


class StragglerMonitor:
    """EWMA + variance tracker with k-sigma flagging."""

    def __init__(self, alpha: float = 0.1, k_sigma: float = 4.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.k = k_sigma
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.events: List[StragglerEvent] = []
        self._sum = 0.0

    def record(self, step: int, dt: float, host: int = 0) -> bool:
        """Returns True if this measurement is a straggler event."""
        self.n += 1
        self._sum += dt
        if self.ewma is None:
            self.ewma = dt
            return False
        sigma = math.sqrt(self.var) if self.var > 0 else self.ewma * 0.1
        threshold = self.ewma + self.k * sigma
        is_straggler = self.n > self.warmup and dt > threshold
        if is_straggler:
            self.events.append(StragglerEvent(step, host, dt, self.ewma,
                                              threshold))
        else:  # stragglers don't poison the baseline
            d = dt - self.ewma
            self.ewma += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler

    def mean(self) -> float:
        return self._sum / max(self.n, 1)


class QuorumPolicy:
    """Skip-step quorum: proceed when >= quorum fraction of hosts have
    reported; missing hosts' microbatches are redistributed (here:
    recorded) — the backup-worker pattern at step granularity."""

    def __init__(self, n_hosts: int, quorum: float = 0.95):
        self.n_hosts = n_hosts
        self.quorum = quorum
        self.skipped: List[Tuple[int, List[int]]] = []

    def decide(self, step: int, reported_hosts: List[int]) -> bool:
        ok = len(reported_hosts) >= math.ceil(self.quorum * self.n_hosts)
        if ok and len(reported_hosts) < self.n_hosts:
            missing = [h for h in range(self.n_hosts)
                       if h not in reported_hosts]
            self.skipped.append((step, missing))
        return ok
