"""Elastic scaling: rebuild the mesh after a device-count change and
reshard training state from checkpoints (logical specs make layouts
portable across any mesh that keeps the axis names)."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import numpy as np

from repro.parallel.sharding import ParallelCtx, make_mesh


def plan_mesh(n_devices: int, *, model_parallel: int,
              pods: int = 1) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Choose a mesh shape for the available devices: model axis fixed
    (weights must fit), data axis absorbs the change, pod axis kept if
    divisible."""
    assert n_devices % model_parallel == 0, (
        f"{n_devices} devices not divisible by TP={model_parallel}")
    rest = n_devices // model_parallel
    if pods > 1 and rest % pods == 0:
        return (pods, rest // pods, model_parallel), ("pod", "data", "model")
    return (rest, model_parallel), ("data", "model")


def make_ctx(n_devices: int, *, model_parallel: int,
             pods: int = 1) -> ParallelCtx:
    shape, axes = plan_mesh(n_devices, model_parallel=model_parallel,
                            pods=pods)
    mesh = make_mesh(shape, axes)
    dp = ("pod", "data") if "pod" in axes else ("data",)
    return ParallelCtx(mesh=mesh, dp=dp)


def rescale(mgr, tree_like: Any, old_ctx: Optional[ParallelCtx],
            new_ctx: ParallelCtx, step: Optional[int] = None):
    """Restore the latest checkpoint onto a different mesh. The manifest
    carries logical specs, so this is just restore(ctx=new_ctx); provided
    as a named operation for the failure-recovery path:
        ctx = make_ctx(len(jax.devices()) - lost, model_parallel=...)
        state, step = rescale(mgr, state_like, old_ctx, ctx)
    """
    return mgr.restore(tree_like, step=step, ctx=new_ctx)
