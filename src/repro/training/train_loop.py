"""train_step factory: remat'd loss, grad accumulation via scan,
ZeRO-sharded AdamW, optional int8-compressed cross-pod gradient
reduction, straggler watchdog hooks, checkpoint/resume."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.model import Model
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.straggler import StragglerMonitor


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: opt.OptState

    def tree(self):
        return {"params": self.params, "opt": self.opt_state._asdict()}


def make_train_step(model: Model, cfg: opt.AdamWConfig, *,
                    grad_accum: int = 1, compress_pods: bool = False):
    """Returns (train_step, init_state, state_specs)."""
    ctx = model.ctx

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    param_specs = model.specs()
    param_shapes = model.param_shapes()
    moment_specs = opt.zero_shard_specs(param_specs, param_shapes, ctx)
    moment_shardings = ctx.tree_shardings(moment_specs, param_shapes)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if grad_accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)

            def acc(carry, mb):
                g_sum, l_sum = carry
                (loss, metrics), g = grad_fn(state.params, mb)
                g_sum = jax.tree.map(jnp.add, g_sum, g)
                return (g_sum, l_sum + loss), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss_sum), metrics = jax.lax.scan(
                acc, (zeros, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
            metrics["loss"] = loss_sum / grad_accum
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)
        params, opt_state, om = opt.adamw_update(
            cfg, state.params, grads, state.opt_state,
            moment_shardings=moment_shardings)
        metrics.update(om)
        return TrainState(params, opt_state), metrics

    def init_state(key) -> TrainState:
        params = model.init(key)
        if ctx.n_devices > 1:
            params = jax.device_put(params,
                                    ctx.tree_shardings(param_specs, params))
        return TrainState(params, opt.init_opt_state(params))

    def state_specs() -> Dict[str, Any]:
        return {"params": param_specs,
                "opt": {"step": P(), "mu": moment_specs,
                        "nu": moment_specs}}

    return train_step, init_state, state_specs


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_last: int = 2
    async_ckpt: bool = True
    grad_accum: int = 1


def train(model: Model, data_iter, opt_cfg: opt.AdamWConfig,
          tcfg: TrainerConfig, *, seed: int = 0,
          on_step: Optional[Callable] = None) -> Tuple[Any, Dict]:
    """End-to-end training driver with checkpoint/resume + straggler
    monitoring. Returns (final TrainState, summary)."""
    step_fn, init_state, state_specs = make_train_step(
        model, opt_cfg, grad_accum=tcfg.grad_accum)
    jstep = jax.jit(step_fn, donate_argnums=(0,))
    mgr = (CheckpointManager(tcfg.ckpt_dir, tcfg.keep_last)
           if tcfg.ckpt_dir else None)
    monitor = StragglerMonitor()
    state = init_state(jax.random.key(seed))
    start = 0
    if mgr and mgr.latest_step() is not None:
        tree, start = mgr.restore(
            {"params": state.params, "opt": state.opt_state._asdict()},
            ctx=model.ctx)
        state = TrainState(tree["params"],
                           opt.OptState(**tree["opt"]))
    history = []
    for step in range(start, tcfg.total_steps):
        batch = next(data_iter)
        t0 = time.perf_counter()
        state, metrics = jstep(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        monitor.record(step, dt)
        if on_step:
            on_step(step, metrics)
        if step % tcfg.log_every == 0 or step == tcfg.total_steps - 1:
            history.append((step, float(metrics["loss"])))
        if mgr and tcfg.ckpt_every and (step + 1) % tcfg.ckpt_every == 0:
            mgr.save(step + 1,
                     {"params": state.params,
                      "opt": state.opt_state._asdict()},
                     state_specs(), async_=tcfg.async_ckpt)
    if mgr:
        mgr.save(tcfg.total_steps,
                 {"params": state.params, "opt": state.opt_state._asdict()},
                 state_specs(), async_=False)
    return state, {"history": history,
                   "stragglers": monitor.events,
                   "mean_step_s": monitor.mean()}
