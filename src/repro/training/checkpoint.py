"""Sharded, manifest-driven checkpointing with async write, atomic
commit, integrity hashes, keep-last-k retention, and ELASTIC restore
(load onto a different mesh / device count than the writer's).

Layout:
  <dir>/step_000123/
      manifest.json      tree structure, shapes, dtypes, logical specs,
                         per-leaf crc32, step, mesh shape at save time
      arrays/000.npy ... one file per leaf (host-gathered)
  <dir>/step_000123.tmp -> renamed to step_000123 on commit (atomic)
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def _spec_to_json(spec: P) -> list:
    out = []
    for s in spec:
        if s is None:
            out.append(None)
        elif isinstance(s, (tuple, list)):
            out.append(list(s))
        else:
            out.append(s)
    return out


def _spec_from_json(j) -> P:
    return P(*[tuple(s) if isinstance(s, list) else s for s in j])


@dataclasses.dataclass
class SaveResult:
    path: str
    step: int
    n_leaves: int
    bytes: int


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------ save
    def save(self, step: int, tree: Any, specs: Any,
             async_: bool = False) -> Optional[SaveResult]:
        """Snapshot to host memory synchronously (cheap), write to disk
        (optionally on a background thread), commit atomically."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        spec_leaves = jax.tree.leaves(
            specs, is_leaf=lambda s: isinstance(s, P))
        assert len(leaves) == len(spec_leaves), "specs/tree mismatch"
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        mesh_shape = {}
        if leaves and hasattr(leaves[0], "sharding") and \
                getattr(leaves[0].sharding, "mesh", None) is not None:
            mesh_shape = dict(leaves[0].sharding.mesh.shape)

        def work() -> SaveResult:
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(os.path.join(tmp, "arrays"))
            manifest = {"step": step, "treedef": str(treedef),
                        "mesh_shape": mesh_shape, "leaves": []}
            total = 0
            for i, (arr, spec) in enumerate(zip(host, spec_leaves)):
                np.save(os.path.join(tmp, "arrays", f"{i:05d}.npy"), arr)
                manifest["leaves"].append({
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "spec": _spec_to_json(spec),
                    "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
                })
                total += arr.nbytes
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)            # atomic commit
            self._retain()
            return SaveResult(final, step, len(host), total)

        if async_:
            def run():
                try:
                    work()
                except BaseException as e:   # surfaced on next wait()
                    self._error = e

            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
            return None
        return work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _retain(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ load
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                ctx=None, verify: bool = True) -> Tuple[Any, int]:
        """Restore into the structure of `tree_like`. With a ParallelCtx,
        leaves are device_put with shardings resolved from the SAVED
        logical specs against the CURRENT mesh — elastic restore onto any
        device count. Without ctx, plain host arrays are returned."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = jax.tree.flatten(tree_like)
        assert len(leaves_like) == len(manifest["leaves"]), (
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"model expects {len(leaves_like)}")
        out = []
        for i, meta in enumerate(manifest["leaves"]):
            arr = np.load(os.path.join(path, "arrays", f"{i:05d}.npy"))
            if verify:
                crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
                if crc != meta["crc32"]:
                    raise IOError(f"corrupt leaf {i} in {path}")
            if ctx is not None:
                sh = ctx.sharding(_spec_from_json(meta["spec"]),
                                  tuple(arr.shape))
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return treedef.unflatten(out), step
