"""Jit'd dispatch wrappers for the compute hot-spots.

``impl`` selects the lowering:
  auto             — Pallas on TPU, blocked-jnp elsewhere (CPU dry-run /
                     tests). This keeps .lower().compile() working on the
                     512-virtual-device CPU mesh while targeting Mosaic
                     on real hardware.
  pallas           — pl.pallas_call, native (TPU)
  pallas_interpret — pl.pallas_call(interpret=True): kernel body
                     executed by the Pallas interpreter on CPU; used by
                     the per-kernel allclose tests.
  blocked          — chunked pure-jnp engine (same tiling as the kernel)
  naive            — O(S^2) oracle (tests only)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _default_impl(impl: Optional[str]) -> str:
    if impl not in (None, "auto"):
        return impl
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "blocked"


# ----------------------------------------------------------------------
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    segment_ids=None, bidirectional=False, impl=None,
                    q_chunk=512, kv_chunk=512):
    sel = _default_impl(impl)
    if sel in ("pallas", "pallas_interpret") and segment_ids is not None:
        sel = "blocked"   # packing masks: blocked lowering handles segments
    if sel in ("pallas", "pallas_interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            segment_ids=segment_ids, bidirectional=bidirectional,
            interpret=(sel == "pallas_interpret"))
    if sel == "blocked":
        return ref.flash_attention_blocked(
            q, k, v, causal=causal, window=window, softcap=softcap,
            segment_ids=segment_ids, bidirectional=bidirectional,
            q_chunk=q_chunk, kv_chunk=kv_chunk)
    return ref.attention_naive(q, k, v, causal=causal, window=window,
                               softcap=softcap, segment_ids=segment_ids,
                               bidirectional=bidirectional)


# ----------------------------------------------------------------------
def paged_attention(q, k_pool, v_pool, block_table, ctx_lens, *,
                    softcap=0.0, window=0, page_mask=None,
                    return_stats=False, impl=None, pages_per_chunk=None):
    sel = _default_impl(impl)
    if sel in ("pallas", "pallas_interpret") and page_mask is not None:
        sel = "blocked"   # striped-page masking: blocked lowering
    if sel in ("pallas", "pallas_interpret"):
        from repro.kernels import paged_attention as pa
        return pa.paged_attention(
            q, k_pool, v_pool, block_table, ctx_lens, softcap=softcap,
            window=window, return_stats=return_stats,
            interpret=(sel == "pallas_interpret"))
    if sel == "blocked":
        if pages_per_chunk is None:
            # auto: chunking bounds live memory at O(c * P) per (B,H),
            # but every chunk is a scan iteration of tiny ops — the
            # dominant CPU decode cost — so take the whole table in one
            # chunk whenever it fits a modest live window
            maxp, p = block_table.shape[1], k_pool.shape[1]
            pages_per_chunk = maxp if maxp * p <= 1024 else 8
        return ref.paged_attention_blocked(
            q, k_pool, v_pool, block_table, ctx_lens, softcap=softcap,
            window=window, page_mask=page_mask,
            pages_per_chunk=pages_per_chunk, return_stats=return_stats)
    return ref.paged_attention_naive(q, k_pool, v_pool, block_table,
                                     ctx_lens, softcap=softcap,
                                     window=window, page_mask=page_mask,
                                     return_stats=return_stats)


# ----------------------------------------------------------------------
def mamba_chunk_scan(x, dt, A, B, C, D, *, chunk=256, initial_state=None,
                     impl=None):
    sel = _default_impl(impl)
    if sel in ("pallas", "pallas_interpret"):
        from repro.kernels import mamba_scan as ms
        return ms.mamba_chunk_scan(
            x, dt, A, B, C, D, chunk=chunk, initial_state=initial_state,
            interpret=(sel == "pallas_interpret"))
    if sel == "blocked":
        return ref.mamba_chunk_scan_blocked(x, dt, A, B, C, D, chunk=chunk,
                                            initial_state=initial_state)
    return ref.mamba_chunk_scan_naive(x, dt, A, B, C, D, chunk=chunk,
                                      initial_state=initial_state)


# ----------------------------------------------------------------------
def fmmu_lookup(tags, valid, data, dlpns, *, entries_per_block, impl=None):
    sel = _default_impl(impl)
    if sel in ("pallas", "pallas_interpret"):
        from repro.kernels import fmmu_lookup as fl
        return fl.fmmu_lookup(tags, valid, data, dlpns,
                              entries_per_block=entries_per_block,
                              interpret=(sel == "pallas_interpret"))
    return ref.fmmu_lookup_ref(tags, valid, data, dlpns,
                               entries_per_block=entries_per_block)


# ----------------------------------------------------------------------
def fmmu_translate(tags, valid, refbits, data, backing, dlpns, touch, *,
                   entries_per_block, impl=None):
    """Fused translate probe (probe + backing fallback + ref touch) —
    the single kernel invocation behind core/fmmu/batch.translate_batch.
    Returns (hit, out_dppn, set_idx, way, refbits')."""
    sel = _default_impl(impl)
    if sel in ("pallas", "pallas_interpret"):
        from repro.kernels import fmmu_translate as ft
        return ft.fmmu_translate(tags, valid, refbits, data, backing,
                                 dlpns, touch,
                                 entries_per_block=entries_per_block,
                                 interpret=(sel == "pallas_interpret"))
    return ref.fmmu_translate_ref(tags, valid, refbits, data, backing,
                                  dlpns, touch,
                                  entries_per_block=entries_per_block)


combine_partial_attention = ref.combine_partial_attention
mamba_decode_step = ref.mamba_decode_step
