"""Pallas TPU kernel for the fused FMMU translate pipeline.

One kernel invocation services the whole probe side of a mixed-op
translate batch (core/fmmu/batch.translate_batch): CMT tag probe,
backing-table fallback for misses, ref-bit touch for hits, and hit-way
selection — where the pre-fusion path issued a probe kernel and then
fixed up misses / ref bits on the host side of the graph.

Hardware adaptation (DESIGN.md, "Fused translate pipeline"): as in
fmmu_lookup, the paper's CAM-style parallel tag compare becomes a
one-hot matmul gather on the MXU. The backing-table fallback — the
paper's flash-resident translation-page read that the FMMU overlaps
with new probes — streams through a second, chunk-sized grid
dimension: only one `backing_chunk` tile is VMEM-resident at a time,
so the table never has to fit on-chip (per-lane-block outputs are
revisited across chunk steps and accumulate the fallback value).
Like the tag CAM, this trades FLOPs for regularity — the streamed
one-hot gather is O(Bq x NP) MXU work instead of an O(Bq) random
gather, which is the right trade for CMT-scale tables on a systolic
array; a scalar-prefetch (PrefetchScalarGridSpec) gather indexed by
the miss DLPNs is the refinement path for very large tables. The
CPU/serving default (`impl="blocked"`) uses the reference lowering's
exact O(Bq) gather and is unaffected.

Value gathers (cached DPPNs, backing entries) must be bit-exact for
any int32 — the paging layer tags host-tier blocks at 1<<24 and above,
past f32's exact-integer range — so they use `fmmu_lookup.gather16`
(two matmuls over the 16-bit halves, recombined in int32). Tag/set
*compares* stay in single f32: block ids are dlpn // E < 2^24 at any
supported geometry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fmmu_lookup import gather16


def _ft_kernel(tags_ref, valid_ref, data_ref, backing_ref, dlpn_ref,
               touch_ref, refin_ref, hit_ref, dppn_ref, set_ref, way_ref,
               refout_ref, *, entries_per_block, n_sets, n_ways,
               backing_chunk, n_backing, blk):
    i = pl.program_id(0)      # lane block (outer)
    c = pl.program_id(1)      # backing chunk (inner, fastest)
    dlpns = dlpn_ref[...]                              # [blk]
    active = dlpns >= 0

    @pl.when((i == 0) & (c == 0))
    def _init_ref():
        refout_ref[...] = refin_ref[...]

    @pl.when(c == 0)
    def _probe():
        block_id = dlpns // entries_per_block
        offset = jnp.mod(dlpns, entries_per_block)
        set_idx = jnp.mod(block_id, n_sets)
        # one-hot gather of the probe sets via the MXU
        onehot = (set_idx[:, None] ==
                  jax.lax.broadcasted_iota(jnp.int32, (blk, n_sets), 1)
                  ).astype(jnp.float32)                # [blk, S]
        tags = tags_ref[...].astype(jnp.float32)       # [S, W]
        valid = valid_ref[...].astype(jnp.float32)     # [S, W]
        row_tags = jax.lax.dot(onehot, tags,
                               preferred_element_type=jnp.float32)
        row_valid = jax.lax.dot(onehot, valid,
                                preferred_element_type=jnp.float32)
        match = (row_tags == block_id[:, None].astype(jnp.float32)) & \
            (row_valid > 0.5)                          # [blk, W]
        hit = match.any(axis=1) & active
        way = jnp.argmax(match, axis=1).astype(jnp.int32)

        e = entries_per_block
        data2d = data_ref[...].reshape(n_sets, n_ways * e)
        row_data = gather16(onehot, data2d)            # [blk, W*E]
        col = way * e + offset
        picked = jnp.take_along_axis(row_data, col[:, None], axis=1)[:, 0]

        hit_ref[...] = hit.astype(jnp.int32)
        set_ref[...] = set_idx.astype(jnp.int32)
        way_ref[...] = way
        # misses start at 0 and accumulate their backing value chunk by
        # chunk; hits are final immediately, inactive lanes stay NIL
        dppn_ref[...] = jnp.where(hit, picked,
                                  jnp.where(active, 0, -1))

        # ref-bit touch; only the selected (argmax) way is touched,
        # matching the reference lowering even on degenerate states
        # with duplicate tags in a set
        touch = (touch_ref[...] != 0) & hit            # [blk]
        tmask = (way[:, None] ==
                 jax.lax.broadcasted_iota(jnp.int32, (blk, n_ways), 1)) & \
            touch[:, None]                             # [blk, W]
        acc = jax.lax.dot(onehot.T, tmask.astype(jnp.float32),
                          preferred_element_type=jnp.float32) > 0.5
        refout_ref[...] = refout_ref[...] | acc.astype(jnp.int32)

    # every (i, c) step: fold this backing chunk into the miss lanes;
    # clip like the reference lowering so an out-of-contract dlpn
    # (>= NP) reads backing[NP-1] on every impl path instead of
    # silently matching nothing / the pad region
    miss = active & (hit_ref[...] == 0)
    seg = backing_ref[...]                             # [backing_chunk]
    loc = jnp.clip(dlpns, -1, n_backing - 1) - c * backing_chunk
    oh = ((loc[:, None] ==
           jax.lax.broadcasted_iota(jnp.int32, (blk, backing_chunk), 1))
          & miss[:, None]).astype(jnp.float32)
    dppn_ref[...] = dppn_ref[...] + gather16(oh, seg[:, None])[:, 0]


def fmmu_translate(tags, valid, refbits, data, backing, dlpns, touch, *,
                   entries_per_block, block_size=256, backing_chunk=512,
                   interpret=False):
    """tags [S,W] int32; valid/refbits [S,W] bool; data [S,W,E] int32;
    backing [NP] int32; dlpns/touch [Bq] ->
    (hit bool, out_dppn, set, way, refbits' [S,W] bool)."""
    n_sets, n_ways = tags.shape
    bq = dlpns.shape[0]
    blk = min(block_size, bq)
    bq_p = -(-bq // blk) * blk
    if bq_p != bq:
        dlpns = jnp.pad(dlpns, (0, bq_p - bq), constant_values=-1)
        touch = jnp.pad(touch, (0, bq_p - bq))
    np_ = backing.shape[0]
    ch = min(backing_chunk, np_)
    np_p = -(-np_ // ch) * ch
    if np_p != np_:
        backing = jnp.pad(backing, (0, np_p - np_), constant_values=-1)
    kernel = functools.partial(
        _ft_kernel, entries_per_block=entries_per_block, n_sets=n_sets,
        n_ways=n_ways, backing_chunk=ch, n_backing=np_, blk=blk)
    hit, dppn, set_idx, way, new_ref = pl.pallas_call(
        kernel,
        grid=(bq_p // blk, np_p // ch),
        in_specs=[
            pl.BlockSpec((n_sets, n_ways), lambda i, c: (0, 0)),
            pl.BlockSpec((n_sets, n_ways), lambda i, c: (0, 0)),
            pl.BlockSpec((n_sets, n_ways, entries_per_block),
                         lambda i, c: (0, 0, 0)),
            pl.BlockSpec((ch,), lambda i, c: (c,)),
            pl.BlockSpec((blk,), lambda i, c: (i,)),
            pl.BlockSpec((blk,), lambda i, c: (i,)),
            pl.BlockSpec((n_sets, n_ways), lambda i, c: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((blk,), lambda i, c: (i,))] * 4 +
                  [pl.BlockSpec((n_sets, n_ways), lambda i, c: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((bq_p,), jnp.int32)] * 4 +
                  [jax.ShapeDtypeStruct((n_sets, n_ways), jnp.int32)],
        interpret=interpret,
    )(tags, valid.astype(jnp.int32), data, backing,
      dlpns, touch.astype(jnp.int32), refbits.astype(jnp.int32))
    return (hit[:bq].astype(bool), dppn[:bq], set_idx[:bq], way[:bq],
            new_ref.astype(bool))
