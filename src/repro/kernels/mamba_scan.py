"""Pallas TPU Mamba2 SSD chunked scan.

Grid = (batch, heads, chunks); the chunk axis is minor-most and carries
the inter-chunk SSM state [head_dim, d_state] in VMEM scratch — the
sequential recurrence collapses to one small FMA per chunk while all
intra-chunk work is dense matmuls on (chunk x chunk) / (chunk x P/N)
tiles, keeping the MXU busy (the SSD duality). Chunk=256 with P=64,
N=128 gives tiles of at most 256x256 — a few hundred KB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ms_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, s0_ref,
               y_ref, fin_ref, state_sc, *, chunk, has_init):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        if has_init:
            state_sc[...] = s0_ref[0, 0].astype(jnp.float32)
        else:
            state_sc[...] = jnp.zeros_like(state_sc)

    x = x_ref[0, :, 0].astype(jnp.float32)          # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # [L]
    A = a_ref[0].astype(jnp.float32)                # scalar
    B = b_ref[0].astype(jnp.float32)                # [L, N]
    C = c_ref[0].astype(jnp.float32)                # [L, N]
    D = d_ref[0].astype(jnp.float32)

    a = dt * A                                      # [L] log-decay
    a_cum = jnp.cumsum(a)
    # lower-triangular decay matrix L[i,j] = exp(a_cum[i]-a_cum[j]) i>=j
    diff = a_cum[:, None] - a_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    dtx = dt[:, None] * x                           # [L, P]
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, L]
    y_diag = jax.lax.dot((cb * Lmat), dtx,
                         preferred_element_type=jnp.float32)      # [L, P]

    state = state_sc[...]                           # [P, N]
    in_decay = jnp.exp(a_cum)                       # decay from chunk start
    y_off = jax.lax.dot(C, state.T,
                        preferred_element_type=jnp.float32)       # [L, P]
    y_off = y_off * in_decay[:, None]

    y_ref[0, :, 0] = (y_diag + y_off + D * x).astype(y_ref.dtype)

    # chunk state update: S = S * exp(sum a) + sum_j exp(a_end - a_j) dtx_j B_j^T
    decay_to_end = jnp.exp(a_cum[-1] - a_cum)       # [L]
    S_new = jax.lax.dot_general(dtx * decay_to_end[:, None], B,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [P,N]
    state_sc[...] = state * jnp.exp(a_cum[-1]) + S_new

    @pl.when(ci == nc - 1)
    def _finish():
        fin_ref[0, 0] = state_sc[...]


def mamba_chunk_scan(x, dt, A, B, C, D, *, chunk=256, initial_state=None,
                     interpret=False):
    """x [Bt,S,H,P]; dt [Bt,S,H]; A [H]; B,C [Bt,S,N]; D [H].
    Returns (y [Bt,S,H,P], final_state [Bt,H,P,N])."""
    bt, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, "pad sequence to chunk multiple"
    nc = s // chunk
    has_init = initial_state is not None
    s0 = (initial_state if has_init
          else jnp.zeros((bt, h, p, n), jnp.float32))
    kernel = functools.partial(_ms_kernel, chunk=chunk, has_init=has_init)
    y, fin = pl.pallas_call(
        kernel,
        grid=(bt, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, c: (b, c, hh)),
            pl.BlockSpec((1,), lambda b, hh, c: (hh,)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, hh, c: (hh,)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bt, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D, s0)
    return y, fin
