"""Pallas TPU paged decode attention over FMMU block tables.

The block table (the FMMU's translation output: logical page -> physical
block) rides in as a *scalar-prefetch* operand, so each grid step's KV
tile is DMA'd straight from the physical block the table names —
`k_pool[table[b, i]]` is expressed in the BlockSpec index_map and the
Mosaic pipeline overlaps tile i+1's DMA with tile i's compute. This is
the TPU rendering of the paper's "FMMU keeps all flash channels busy":
the map unit's output drives the memory pipeline directly.

Grid = (batch, n_pages); online-softmax stats carried in VMEM scratch
across the page axis; per-sequence length masking from a prefetched
ctx_lens vector. Returns optional (m, l) stats for the cross-shard
flash-decoding combine used by sequence-parallel 500k decode.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(table_ref, ctx_ref, q_ref, k_ref, v_ref, o_ref, m_ref,
               l_ref, macc, lacc, acc, *, scale, softcap, window, page, kv,
               group):
    b = pl.program_id(0)
    i = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        macc[...] = jnp.full_like(macc, NEG_INF)
        lacc[...] = jnp.zeros_like(lacc)
        acc[...] = jnp.zeros_like(acc)

    ctx = ctx_ref[b]
    # page i covers positions [i*page, (i+1)*page)
    live = i * page < ctx
    if window and window > 0:   # pages wholly below the window: skip DMA'd tile
        live &= (i + 1) * page > ctx - window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # [H, D]
        k = k_ref[0].astype(jnp.float32)                # [page, KV, D]
        v = v_ref[0].astype(jnp.float32)
        h, d = q.shape
        qg = q.reshape(kv, group, d)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        # s: [KV, G, page]
        if softcap and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        pos = i * page + jax.lax.broadcasted_iota(
            jnp.int32, (kv, group, page), 2)
        valid = pos < ctx
        if window and window > 0:
            valid &= pos >= ctx - window
        s = jnp.where(valid, s, NEG_INF)
        s = s.reshape(h, page)
        m_prev = macc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                          # [H, page]
        lacc[...] = lacc[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.reshape(kv, group, page), v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)         # [KV, G, D]
        acc[...] = acc[...] * alpha + pv.reshape(h, d)
        macc[...] = m_new

    @pl.when(i == np_ - 1)
    def _finish():
        o_ref[0] = (acc[...] / jnp.maximum(lacc[...], 1e-30)).astype(o_ref.dtype)
        m_ref[0] = macc[...][:, 0]
        l_ref[0] = lacc[...][:, 0]


def paged_attention(q, k_pool, v_pool, block_table, ctx_lens, *,
                    softcap=0.0, window=0, return_stats=False,
                    interpret=False):
    """q [B,H,D]; pools [NB,P,KV,D]; block_table [B,MAXP] int32;
    ctx_lens [B] int32 -> [B,H,D] (+ (m,l) [B,H] fp32)."""
    b, h, d = q.shape
    nb, page, kv, _ = k_pool.shape
    maxp = block_table.shape[1]
    group = h // kv
    kernel = functools.partial(
        _pa_kernel, scale=1.0 / math.sqrt(d), softcap=softcap,
        window=window, page=page, kv=kv, group=group)
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, maxp),
            in_specs=[
                pl.BlockSpec((1, h, d), lambda bi, i, tbl, ctx: (bi, 0, 0)),
                pl.BlockSpec((1, page, kv, d),
                             lambda bi, i, tbl, ctx: (tbl[bi, i], 0, 0, 0)),
                pl.BlockSpec((1, page, kv, d),
                             lambda bi, i, tbl, ctx: (tbl[bi, i], 0, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, h, d), lambda bi, i, tbl, ctx: (bi, 0, 0)),
                pl.BlockSpec((1, h), lambda bi, i, tbl, ctx: (bi, 0)),
                pl.BlockSpec((1, h), lambda bi, i, tbl, ctx: (bi, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((h, 1), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
                pltpu.VMEM((h, d), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), q.dtype),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(block_table, ctx_lens, q, k_pool, v_pool)
    if return_stats:
        return out, (m, l)
    return out
