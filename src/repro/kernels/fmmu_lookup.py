"""Pallas TPU kernel for a bare batched CMT probe (probe-only).

Hardware adaptation (DESIGN.md §2): the paper's CAM-style parallel tag
compare becomes a *one-hot matmul gather* — set indices are expanded to
a one-hot [blk, S] matrix and multiplied against the VMEM-resident tag /
data arrays, turning the irregular per-request set lookup into two MXU
matmuls (TPUs have no CAM, but they have a 128x128 systolic array).
The whole CMT (paper geometry: 512 sets x 4 ways x 8 entries x 4B ≈
64KB tags+data) fits in VMEM, exactly like the SRAM block of the
hardware unit; only the request vector streams through the grid.

Fused translate pipeline (DESIGN.md): the batch engine's hot path no
longer uses this probe-only kernel — `fmmu_translate.py` fuses the
probe with the backing-table fallback and the ref-bit touch so
`translate_batch` issues ONE kernel per mixed-op batch. This kernel
remains the probe primitive for the unfused reference path
(`core/fmmu/batch.*_unfused`, equivalence tests + benchmarks) and for
callers that need a side-effect-free probe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def gather16(onehot, vals2d):
    """Bit-exact int32 one-hot gather on the MXU: two f32 matmuls over
    the 16-bit halves (lo = v & 0xffff in [0, 2^16), hi = v >> 16 in
    [-2^15, 2^15) — each f32-exact), recombined in int32. Needed
    because gathered values may exceed f32's 2^24 exact-integer range:
    the paging layer tags host-tier block ids at 1<<24 and above.
    onehot [r, c] f32 (exactly one 1.0 per row, or all-zero rows);
    vals2d [c, k] int32 -> [r, k] int32."""
    lo = jax.lax.dot(onehot, (vals2d & 0xffff).astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    hi = jax.lax.dot(onehot, (vals2d >> 16).astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return hi.astype(jnp.int32) * 65536 + lo.astype(jnp.int32)


def _fl_kernel(tags_ref, valid_ref, data_ref, dlpn_ref, hit_ref, dppn_ref,
               set_ref, way_ref, *, entries_per_block, n_sets, n_ways,
               blk):
    dlpns = dlpn_ref[...]                              # [blk]
    block_id = dlpns // entries_per_block
    offset = jnp.mod(dlpns, entries_per_block)
    set_idx = jnp.mod(block_id, n_sets)
    active = dlpns >= 0

    # one-hot gather of the probe sets via the MXU
    onehot = (set_idx[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (blk, n_sets), 1)
              ).astype(jnp.float32)                    # [blk, S]
    tags = tags_ref[...].astype(jnp.float32)           # [S, W]
    valid = valid_ref[...].astype(jnp.float32)         # [S, W]
    row_tags = jax.lax.dot(onehot, tags,
                           preferred_element_type=jnp.float32)
    row_valid = jax.lax.dot(onehot, valid,
                            preferred_element_type=jnp.float32)
    match = (row_tags == block_id[:, None].astype(jnp.float32)) & \
        (row_valid > 0.5)                              # [blk, W]
    hit = match.any(axis=1) & active
    way = jnp.argmax(match, axis=1).astype(jnp.int32)

    e = entries_per_block
    data2d = data_ref[...].reshape(n_sets, n_ways * e)
    row_data = gather16(onehot, data2d)                # [blk, W*E]
    col = way * e + offset
    picked = jnp.take_along_axis(row_data, col[:, None], axis=1)[:, 0]
    dppn = jnp.where(hit, picked, -1)

    hit_ref[...] = hit.astype(jnp.int32)
    dppn_ref[...] = dppn
    set_ref[...] = set_idx.astype(jnp.int32)
    way_ref[...] = way


def fmmu_lookup(tags, valid, data, dlpns, *, entries_per_block,
                block_size=256, interpret=False):
    """tags [S,W] int32; valid [S,W] bool; data [S,W,E] int32;
    dlpns [Bq] int32 -> (hit bool, dppn, set, way)."""
    n_sets, n_ways = tags.shape
    bq = dlpns.shape[0]
    blk = min(block_size, bq)
    bq_p = -(-bq // blk) * blk
    if bq_p != bq:
        dlpns = jnp.pad(dlpns, (0, bq_p - bq), constant_values=-1)
    kernel = functools.partial(
        _fl_kernel, entries_per_block=entries_per_block, n_sets=n_sets,
        n_ways=n_ways, blk=blk)
    full = lambda *_: tuple(0 for _ in range(2))
    hit, dppn, set_idx, way = pl.pallas_call(
        kernel,
        grid=(bq_p // blk,),
        in_specs=[
            pl.BlockSpec((n_sets, n_ways), lambda i: (0, 0)),
            pl.BlockSpec((n_sets, n_ways), lambda i: (0, 0)),
            pl.BlockSpec((n_sets, n_ways, entries_per_block),
                         lambda i: (0, 0, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((blk,), lambda i: (i,))] * 4,
        out_shape=[jax.ShapeDtypeStruct((bq_p,), jnp.int32)] * 4,
        interpret=interpret,
    )(tags, valid.astype(jnp.int32), data, dlpns)
    return (hit[:bq].astype(bool), dppn[:bq], set_idx[:bq], way[:bq])
