"""Pallas TPU kernel for the FMMU's hot path: batched CMT probe.

Hardware adaptation (DESIGN.md §2): the paper's CAM-style parallel tag
compare becomes a *one-hot matmul gather* — set indices are expanded to
a one-hot [blk, S] matrix and multiplied against the VMEM-resident tag /
data arrays, turning the irregular per-request set lookup into two MXU
matmuls (TPUs have no CAM, but they have a 128x128 systolic array).
The whole CMT (paper geometry: 512 sets x 4 ways x 8 entries x 4B ≈
64KB tags+data) fits in VMEM, exactly like the SRAM block of the
hardware unit; only the request vector streams through the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fl_kernel(tags_ref, valid_ref, data_ref, dlpn_ref, hit_ref, dppn_ref,
               set_ref, way_ref, *, entries_per_block, n_sets, n_ways,
               blk):
    dlpns = dlpn_ref[...]                              # [blk]
    block_id = dlpns // entries_per_block
    offset = jnp.mod(dlpns, entries_per_block)
    set_idx = jnp.mod(block_id, n_sets)
    active = dlpns >= 0

    # one-hot gather of the probe sets via the MXU
    onehot = (set_idx[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (blk, n_sets), 1)
              ).astype(jnp.float32)                    # [blk, S]
    tags = tags_ref[...].astype(jnp.float32)           # [S, W]
    valid = valid_ref[...].astype(jnp.float32)         # [S, W]
    row_tags = jax.lax.dot(onehot, tags,
                           preferred_element_type=jnp.float32)
    row_valid = jax.lax.dot(onehot, valid,
                            preferred_element_type=jnp.float32)
    match = (row_tags == block_id[:, None].astype(jnp.float32)) & \
        (row_valid > 0.5)                              # [blk, W]
    hit = match.any(axis=1) & active
    way = jnp.argmax(match, axis=1).astype(jnp.int32)

    e = entries_per_block
    data2d = data_ref[...].reshape(n_sets, n_ways * e).astype(jnp.float32)
    row_data = jax.lax.dot(onehot, data2d,
                           preferred_element_type=jnp.float32)  # [blk, W*E]
    col = way * e + offset
    picked = jnp.take_along_axis(row_data, col[:, None], axis=1)[:, 0]
    dppn = jnp.where(hit, picked.astype(jnp.int32), -1)

    hit_ref[...] = hit.astype(jnp.int32)
    dppn_ref[...] = dppn
    set_ref[...] = set_idx.astype(jnp.int32)
    way_ref[...] = way


def fmmu_lookup(tags, valid, data, dlpns, *, entries_per_block,
                block_size=256, interpret=False):
    """tags [S,W] int32; valid [S,W] bool; data [S,W,E] int32;
    dlpns [Bq] int32 -> (hit bool, dppn, set, way)."""
    n_sets, n_ways = tags.shape
    bq = dlpns.shape[0]
    blk = min(block_size, bq)
    bq_p = -(-bq // blk) * blk
    if bq_p != bq:
        dlpns = jnp.pad(dlpns, (0, bq_p - bq), constant_values=-1)
    kernel = functools.partial(
        _fl_kernel, entries_per_block=entries_per_block, n_sets=n_sets,
        n_ways=n_ways, blk=blk)
    full = lambda *_: tuple(0 for _ in range(2))
    hit, dppn, set_idx, way = pl.pallas_call(
        kernel,
        grid=(bq_p // blk,),
        in_specs=[
            pl.BlockSpec((n_sets, n_ways), lambda i: (0, 0)),
            pl.BlockSpec((n_sets, n_ways), lambda i: (0, 0)),
            pl.BlockSpec((n_sets, n_ways, entries_per_block),
                         lambda i: (0, 0, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((blk,), lambda i: (i,))] * 4,
        out_shape=[jax.ShapeDtypeStruct((bq_p,), jnp.int32)] * 4,
        interpret=interpret,
    )(tags, valid.astype(jnp.int32), data, dlpns)
    return (hit[:bq].astype(bool), dppn[:bq], set_idx[:bq], way[:bq])
