"""Pure-jnp oracles and blocked (memory-frugal) reference engines.

Two tiers per op:
  * ``*_naive``   — smallest possible oracle, O(S^2) memory, used only in
                    tests as ground truth.
  * ``*_blocked`` — chunked/online-softmax jnp implementation with the
                    same tiling structure as the Pallas kernel. Used (a)
                    as the CPU/dry-run lowering (realistic FLOPs + memory
                    in the compiled HLO) and (b) as the oracle for the
                    Pallas kernels at larger shapes.

Conventions: activations are [B, S, H, D] ("BSHD"); KV may have fewer
heads (GQA) and is broadcast by grouping. Softmax statistics in fp32.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0.0:
        return cap * jnp.tanh(x / cap)
    return x


def _group_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B,S,KV,D] -> [B,S,H,D] by repeating each kv head H/KV times."""
    b, s, kv, d = k.shape
    if kv == n_heads:
        return k
    rep = n_heads // kv
    return jnp.repeat(k, rep, axis=2)


# ======================================================================
# Full attention — naive oracle
# ======================================================================
def attention_naive(q, k, v, *, causal=True, window=0, softcap=0.0,
                    segment_ids=None, bidirectional=False):
    """q [B,Sq,H,D]; k,v [B,Skv,KV,D] -> [B,Sq,H,D]. fp32 math."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    kf = _group_kv(k, h).astype(jnp.float32)
    vf = _group_kv(v, h).astype(jnp.float32)
    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(d))
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    logits = _softcap(logits, softcap)
    qpos = jnp.arange(sq)[:, None] + (skv - sq)  # right-aligned query positions
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal and not bidirectional:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    mask_b = jnp.broadcast_to(mask[None, None], logits.shape)
    if segment_ids is not None:
        seg_q, seg_k = segment_ids
        smask = seg_q[:, None, :, None] == seg_k[:, None, None, :]
        mask_b = mask_b & smask
    logits = jnp.where(mask_b, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)


# ======================================================================
# Full attention — blocked flash (scan over kv chunks per q chunk)
# ======================================================================
def _online_block(carry, qf, kc, vc, mask):
    """One online-softmax accumulation step. qf [T,D] (pre-scaled fp32),
    kc/vc [C,D] fp32, mask [T,C] bool. carry = (m, l, acc)."""
    m, l, acc = carry
    s = qf @ kc.T                       # [T, C]
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[:, None])
    l = l * alpha + p.sum(axis=-1)
    acc = acc * alpha[:, None] + p @ vc
    return (m_new, l, acc)


def _online_block_softcap(carry, qf, kc, vc, mask, softcap):
    m, l, acc = carry
    s = qf @ kc.T
    s = _softcap(s, softcap)
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[:, None])
    l = l * alpha + p.sum(axis=-1)
    acc = acc * alpha[:, None] + p @ vc
    return (m_new, l, acc)


def flash_attention_blocked(q, k, v, *, causal=True, window=0, softcap=0.0,
                            segment_ids=None, bidirectional=False,
                            q_chunk=512, kv_chunk=512):
    """Triangular-work blocked attention.

    Python loop over query chunks gives each chunk a *static* KV extent
    (no wasted masked FLOPs in the compiled HLO); a lax.scan over KV
    chunks inside keeps live memory at O(q_chunk * kv_chunk).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    if sq % q_chunk or skv % kv_chunk:
        return attention_naive(q, k, v, causal=causal, window=window,
                               softcap=softcap, segment_ids=segment_ids,
                               bidirectional=bidirectional)
    kf = _group_kv(k, h).astype(jnp.float32)
    vf = _group_kv(v, h).astype(jnp.float32)
    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(d))
    off = skv - sq                                   # right-aligned queries
    seg_q, seg_k = (segment_ids if segment_ids is not None else (None, None))

    def q_block(qi):
        q0 = qi * q_chunk
        qpos = q0 + jnp.arange(q_chunk) + off
        # static KV extent for this q chunk
        if causal and not bidirectional:
            hi = min(skv, q0 + q_chunk + off)
        else:
            hi = skv
        lo = 0
        if window and window > 0:
            lo = max(0, q0 + off - window + 1)
        lo = (lo // kv_chunk) * kv_chunk
        hi = -(-hi // kv_chunk) * kv_chunk
        hi = min(hi, skv)
        n_kv = (hi - lo) // kv_chunk
        qb = qf[:, q0:q0 + q_chunk]                  # [B, T, H, D]
        kb = lax.dynamic_slice_in_dim(kf, lo, hi - lo, 1)
        vb = lax.dynamic_slice_in_dim(vf, lo, hi - lo, 1)
        kb = kb.reshape(b, n_kv, kv_chunk, h, d)
        vb = vb.reshape(b, n_kv, kv_chunk, h, d)
        sq_b = seg_q[:, q0:q0 + q_chunk] if seg_q is not None else None
        sk_b = (seg_k[:, lo:hi].reshape(b, n_kv, kv_chunk)
                if seg_k is not None else None)

        def per_bh(qv, kvs, vvs, sqv, skvs):
            # qv [T,D]; kvs/vvs [n_kv, C, D]
            def step(carry, xs):
                if sqv is None:
                    kc, vc, kpos = xs
                    skc = None
                else:
                    kc, vc, kpos, skc = xs
                mask = jnp.ones((q_chunk, kv_chunk), dtype=bool)
                if causal and not bidirectional:
                    mask &= kpos[None, :] <= qpos[:, None]
                if window and window > 0:
                    mask &= kpos[None, :] > qpos[:, None] - window
                if skc is not None:
                    mask &= sqv[:, None] == skc[None, :]
                if softcap:
                    return _online_block_softcap(carry, qv, kc, vc, mask, softcap), None
                return _online_block(carry, qv, kc, vc, mask), None

            kpos_all = lo + jnp.arange(hi - lo).reshape(n_kv, kv_chunk)
            init = (jnp.full((q_chunk,), NEG_INF, jnp.float32),
                    jnp.zeros((q_chunk,), jnp.float32),
                    jnp.zeros((q_chunk, d), jnp.float32))
            xs = (kvs, vvs, kpos_all) if sqv is None else (kvs, vvs, kpos_all, skvs)
            (m, l, acc), _ = lax.scan(step, init, xs)
            return acc / jnp.maximum(l, 1e-30)[:, None]

        fn = per_bh
        # vmap over heads then batch
        fn = jax.vmap(fn, in_axes=(1, 2, 2, None, None), out_axes=1)      # heads
        fn = jax.vmap(fn, in_axes=(0, 0, 0, 0 if sq_b is not None else None,
                                   0 if sk_b is not None else None))       # batch
        return fn(qb, kb, vb, sq_b, sk_b)            # [B, T, H, D]

    outs = [q_block(qi) for qi in range(sq // q_chunk)]
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


# ======================================================================
# Paged decode attention
# ======================================================================
def paged_attention_naive(q, k_pool, v_pool, block_table, ctx_lens, *,
                          softcap=0.0, window=0, page_mask=None,
                          return_stats=False):
    """One-token decode attention over a paged KV pool.

    q           [B, H, D]
    k/v_pool    [NB, P, KV, D]   physical blocks (pages of P tokens)
    block_table [B, MAXP] int32  logical page i of seq b -> physical block
    ctx_lens    [B] int32        tokens of context (including none of q)
    returns     [B, H, D]  (+ (m, l) fp32 stats if return_stats, for
                            cross-shard flash-decoding combine)
    """
    b, h, d = q.shape
    nb, p, kv, _ = k_pool.shape
    maxp = block_table.shape[1]
    kg = k_pool.astype(jnp.float32)
    vg = v_pool.astype(jnp.float32)
    # gather pages: [B, MAXP, P, KV, D]
    kseq = kg[block_table]
    vseq = vg[block_table]
    kseq = kseq.reshape(b, maxp * p, kv, d)
    vseq = vseq.reshape(b, maxp * p, kv, d)
    kseq = _group_kv(kseq, h)
    vseq = _group_kv(vseq, h)
    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(d))
    logits = jnp.einsum("bhd,bkhd->bhk", qf, kseq)
    logits = _softcap(logits, softcap)
    pos = jnp.arange(maxp * p)[None, :]
    mask = pos < ctx_lens[:, None]
    if window and window > 0:   # sliding window: only last `window` tokens
        mask &= pos >= ctx_lens[:, None] - window
    if page_mask is not None:   # striped pools: only locally-owned pages
        mask &= jnp.repeat(page_mask, p, axis=1)
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    m = logits.max(axis=-1)
    pexp = jnp.exp(logits - m[..., None])
    l = pexp.sum(axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", pexp, vseq) / jnp.maximum(l, 1e-30)[..., None]
    if return_stats:
        return out.astype(q.dtype), (m, l)
    return out.astype(q.dtype)


def paged_attention_blocked(q, k_pool, v_pool, block_table, ctx_lens, *,
                            softcap=0.0, window=0, page_mask=None,
                            pages_per_chunk=8, return_stats=False):
    """Flash-decoding style: scan over page chunks with online softmax.
    Live memory O(pages_per_chunk * P) per (B,H)."""
    b, h, d = q.shape
    nb, p, kv, _ = k_pool.shape
    maxp = block_table.shape[1]
    c = min(pages_per_chunk, maxp)
    if maxp % c:
        c = 1
    n_chunks = maxp // c
    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(d))
    group = h // kv

    def per_b(qb, table_b, ctx_b, pmask_b):
        # qb [H, D]
        def step(carry, ci):
            m, l, acc = carry
            pages = lax.dynamic_slice_in_dim(table_b, ci * c, c, 0)   # [c]
            pm = (lax.dynamic_slice_in_dim(pmask_b, ci * c, c, 0)
                  if pmask_b is not None else None)
            kc = k_pool[pages].astype(jnp.float32)    # [c, P, KV, D]
            vc = v_pool[pages].astype(jnp.float32)
            kc = kc.reshape(c * p, kv, d)
            vc = vc.reshape(c * p, kv, d)
            pos = ci * (c * p) + jnp.arange(c * p)
            valid = pos < ctx_b
            if window and window > 0:
                valid &= pos >= ctx_b - window
            if pm is not None:
                valid &= jnp.repeat(pm, p)
            # logits per kv head group: q heads grouped [KV, G, D]
            qg = qb.reshape(kv, group, d)
            s = jnp.einsum("kgd,tkd->kgt", qg, kc)    # [KV, G, T]
            s = _softcap(s, softcap).reshape(h, c * p)
            s = jnp.where(valid[None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pe = jnp.exp(s - m_new[:, None])
            l2 = l * alpha + pe.sum(axis=-1)
            pv = jnp.einsum("kgt,tkd->kgd", pe.reshape(kv, group, c * p), vc)
            acc2 = acc * alpha[:, None] + pv.reshape(h, d)
            return (m_new, l2, acc2), None

        init = (jnp.full((h,), NEG_INF, jnp.float32),
                jnp.zeros((h,), jnp.float32),
                jnp.zeros((h, d), jnp.float32))
        if n_chunks == 1:
            # skip the scan machinery: a single-chunk table is the CPU
            # decode hot path (ops.paged_attention auto-widens)
            (m, l, acc), _ = step(init, 0)
        else:
            (m, l, acc), _ = lax.scan(step, init, jnp.arange(n_chunks))
        return acc / jnp.maximum(l, 1e-30)[:, None], m, l

    if page_mask is None:
        out, m, l = jax.vmap(
            lambda a, b_, c_: per_b(a, b_, c_, None))(qf, block_table,
                                                      ctx_lens)
    else:
        out, m, l = jax.vmap(per_b)(qf, block_table, ctx_lens, page_mask)
    if return_stats:
        return out.astype(q.dtype), (m, l)
    return out.astype(q.dtype)


def combine_partial_attention(outs, ms, ls):
    """Combine per-shard flash-decoding partials along a leading axis.
    outs [K,B,H,D] (already l-normalized per shard), ms/ls [K,B,H]."""
    m = ms.max(axis=0)
    w = jnp.exp(ms - m[None]) * ls                # effective weights
    denom = w.sum(axis=0)
    out = (outs * w[..., None]).sum(axis=0) / jnp.maximum(denom, 1e-30)[..., None]
    return out


# ======================================================================
# Mamba2 SSD chunked scan
# ======================================================================
def _segsum(a):
    """a [..., L] log-decays -> [..., L, L] lower-triangular cumulative
    sums: out[i,j] = sum_{k=j+1..i} a[k] for i>=j else -inf."""
    L = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    idx = jnp.arange(L)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def mamba_chunk_scan_naive(x, dt, A, B, C, D, *, chunk, initial_state=None):
    """Sequential-scan oracle for the SSD op.

    x  [Bt, S, H, P]   (P = head dim)
    dt [Bt, S, H]      (already softplus'd, >=0)
    A  [H]             (negative; decay = exp(dt*A))
    B  [Bt, S, N]      (single group, shared across heads)
    C  [Bt, S, N]
    D  [H]             skip
    returns y [Bt, S, H, P], final_state [Bt, H, P, N]
    """
    bt, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt_, ct = inp                      # [H,P], [H], [N], [N]
        da = jnp.exp(dtt * Af)                      # [H]
        state = state * da[:, None, None] + jnp.einsum(
            "h,hp,n->hpn", dtt, xt, bt_)
        y = jnp.einsum("hpn,n->hp", state, ct)
        return state, y

    def per_batch(xb, dtb, bb, cb, s0):
        state, ys = lax.scan(step, s0, (xb, dtb, bb, cb))
        return ys, state

    s0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((bt, h, p, n), jnp.float32))
    ys, state = jax.vmap(per_batch)(xf, dtf, Bf, Cf, s0)
    ys = ys + xf * D.astype(jnp.float32)[None, None, :, None]
    return ys.astype(x.dtype), state


def mamba_chunk_scan_blocked(x, dt, A, B, C, D, *, chunk,
                             initial_state=None):
    """Chunked SSD (Dao & Gu 2024, Alg. 1): intra-chunk matmul form +
    inter-chunk recurrence over chunk states. Matmul-heavy -> MXU-friendly;
    identical math to the sequential oracle."""
    bt, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:
        return mamba_chunk_scan_naive(x, dt, A, B, C, D, chunk=chunk,
                                      initial_state=initial_state)
    nc = s // chunk
    xf = x.astype(jnp.float32).reshape(bt, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bt, nc, chunk, h)
    Bf = B.astype(jnp.float32).reshape(bt, nc, chunk, n)
    Cf = C.astype(jnp.float32).reshape(bt, nc, chunk, n)
    Af = A.astype(jnp.float32)

    a = dtf * Af[None, None, None, :]               # [bt,nc,L,h] log-decay
    a = jnp.moveaxis(a, -1, 2)                      # [bt,nc,h,L]
    a_cum = jnp.cumsum(a, axis=-1)                  # within-chunk cumsum
    Lmat = jnp.exp(_segsum(a))                      # [bt,nc,h,L,L]

    # --- intra-chunk (diagonal) ---
    cb = jnp.einsum("bcln,bcmn->bclm", Cf, Bf)      # [bt,nc,L,L]
    dtx = dtf[..., None] * xf                       # dt-weighted inputs
    y_diag = jnp.einsum("bclm,bchlm,bcmhp->bclhp",
                        cb, Lmat, dtx)

    # --- chunk states ---
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)          # [bt,nc,h,L]
    states = jnp.einsum("bchl,bcln,bclhp->bchpn",
                        decay_to_end, Bf, dtx)                # [bt,nc,h,p,n]

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(a_cum[..., -1])                     # [bt,nc,h]
    s0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((bt, h, p, n), jnp.float32))

    def inter(carry, inp):
        st, dec = inp                                         # [bt,h,p,n],[bt,h]
        prev = carry
        new = prev * dec[..., None, None] + st
        return new, prev                                      # emit state *entering* chunk

    final, prev_states = lax.scan(inter, s0,
                                  (jnp.moveaxis(states, 1, 0),
                                   jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)             # [bt,nc,h,p,n]

    # --- inter-chunk (off-diagonal) output ---
    in_decay = jnp.exp(a_cum)                                 # decay from chunk start
    y_off = jnp.einsum("bcln,bchl,bchpn->bclhp",
                       Cf, in_decay, prev_states)

    y = (y_diag + y_off).reshape(bt, s, h, p)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final


def mamba_decode_step(state, x, dt, A, B, C, D):
    """Single-token SSD recurrence. state [Bt,H,P,N]; x [Bt,H,P];
    dt [Bt,H]; B,C [Bt,N]. Returns (y [Bt,H,P], new_state)."""
    da = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32)[None, :])
    xf = x.astype(jnp.float32)
    state = state * da[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt.astype(jnp.float32), xf, B.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", state, C.astype(jnp.float32))
    y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), state


# ======================================================================
# FMMU batched CMT probe (the paper's hot path) — reference
# ======================================================================
def fmmu_lookup_ref(tags, valid, data, dlpns, *, entries_per_block):
    """Vectorized first-level (CMT) probe.

    tags  [S, W] int32   block id (dlpn // entries_per_block) per way
    valid [S, W] bool
    data  [S, W, E] int32 DPPN entries
    dlpns [Bq] int32     query logical page numbers (-1 = inactive slot)
    returns (hit [Bq] bool, dppn [Bq] int32, set_idx, way [Bq] int32)
    """
    n_sets, n_ways = tags.shape
    block_id = dlpns // entries_per_block
    offset = dlpns % entries_per_block
    set_idx = block_id % n_sets
    active = dlpns >= 0
    way_tags = tags[set_idx]                       # [Bq, W]
    way_valid = valid[set_idx]
    match = (way_tags == block_id[:, None]) & way_valid
    hit = match.any(axis=1) & active
    way = jnp.argmax(match, axis=1).astype(jnp.int32)
    dppn = data[set_idx, way, offset]
    dppn = jnp.where(hit, dppn, -1)
    return hit, dppn, set_idx.astype(jnp.int32), way


def fmmu_translate_ref(tags, valid, refbits, data, backing, dlpns, touch, *,
                       entries_per_block):
    """Fused translate probe: CMT probe + backing-table fallback +
    ref-bit touch in one lowering (the single-probe pipeline of
    core/fmmu/batch.translate_batch).

    tags    [S, W] int32   block id (dlpn // entries_per_block) per way
    valid   [S, W] bool
    refbits [S, W] bool    second-chance reference bits
    data    [S, W, E] int32 DPPN entries
    backing [NP] int32     full flat map table (flash-resident pages)
    dlpns   [Bq] int32     query DLPNs (-1 = inactive slot)
    touch   [Bq] bool      lanes whose hit should set the ref bit
    returns (hit [Bq] bool, out [Bq] int32, set_idx, way [Bq] int32,
             refbits' [S, W] bool)

    ``out`` is the pre-call mapping: the cached DPPN on a hit, the
    backing-table entry on an active miss, NIL on inactive lanes.
    """
    n_sets, n_ways = tags.shape
    block_id = dlpns // entries_per_block
    offset = jnp.mod(dlpns, entries_per_block)
    set_idx = jnp.mod(block_id, n_sets).astype(jnp.int32)
    active = dlpns >= 0
    way_tags = tags[set_idx]                       # [Bq, W]
    way_valid = valid[set_idx]
    match = (way_tags == block_id[:, None]) & way_valid
    hit = match.any(axis=1) & active
    way = jnp.argmax(match, axis=1).astype(jnp.int32)
    cached = data[set_idx, way, offset]
    backing_val = backing[jnp.clip(dlpns, 0, backing.shape[0] - 1)]
    out = jnp.where(hit, cached, jnp.where(active, backing_val, -1))
    flat = jnp.where(hit & touch, set_idx * n_ways + way, n_sets * n_ways)
    new_ref = refbits.reshape(-1).at[flat].set(True, mode="drop").reshape(
        refbits.shape)
    return hit, out.astype(jnp.int32), set_idx, way, new_ref
