"""Pallas TPU flash attention (causal / sliding-window / softcap, GQA).

Tiling: grid = (batch, q_heads, q_blocks, kv_blocks); the kv_blocks axis
is minor-most, so the online-softmax statistics (m, l, acc) live in VMEM
scratch carried across kv iterations. Fully-masked kv blocks (beyond the
causal frontier / outside the sliding window) are skipped with pl.when —
on hardware they cost only grid overhead. KV tiles for GQA are indexed
at kv_head = q_head // group via the BlockSpec index map, so each q-head
program DMAs only its shared KV tile. Block shapes default to
(q=512, kv=512) with full head_dim — (512, 128) tiles keep the MXU fed
and the working set (q + k + v + acc + p: ~5 * 512*128 * 4B ≈ 1.3MB)
comfortably inside the ~16MB VMEM budget.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
               scale, causal, window, softcap, q_block, kv_block, seq_kv,
               bidirectional, q_offset):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # right-aligned query positions (cross-length causal: q row i sits at
    # absolute position i + (seq_kv - seq_q))
    qpos = q_offset + iq * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 0)
    kpos = ik * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 1)

    run = jnp.asarray(True)
    if causal and not bidirectional:
        run = run & (ik * kv_block <= q_offset + (iq + 1) * q_block - 1)
    if window and window > 0:
        run = run & ((ik + 1) * kv_block - 1 > q_offset + iq * q_block - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [qb, d]
        k = k_ref[0, 0].astype(jnp.float32)                  # [kb, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpos < seq_kv
        if causal and not bidirectional:
            mask &= kpos <= qpos
        if window and window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_sc[...] = l_sc[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_sc[...] /
                       jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    segment_ids=None, bidirectional=False,
                    q_block=512, kv_block=512, interpret=False):
    """q [B,Sq,H,D]; k,v [B,Skv,KV,D] -> [B,Sq,H,D]."""
    if segment_ids is not None:
        raise NotImplementedError(
            "segment_ids: use the blocked-jnp lowering (ops.py falls back)")
    b, sq, h, d = q.shape
    skv, kv = k.shape[1], k.shape[2]
    group = h // kv
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    # pad to block multiples
    sq_p = -(-sq // q_block) * q_block
    skv_p = -(-skv // kv_block) * kv_block
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    qt = q.transpose(0, 2, 1, 3)      # [B,H,S,D]
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    grid = (b, h, sq_p // q_block, skv_p // kv_block)
    kernel = functools.partial(
        _fa_kernel, scale=1.0 / math.sqrt(d), causal=causal, window=window,
        softcap=softcap, q_block=q_block, kv_block=kv_block, seq_kv=skv,
        bidirectional=bidirectional, q_offset=skv - sq)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, kv_block, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, kv_block, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :sq]
