"""Token sampling policies."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits, key, temp: float = 1.0, top_k: int = 0):
    if temp <= 0:
        return greedy(logits)
    l = logits / temp
    if top_k:
        thresh = jax.lax.top_k(l, top_k)[0][..., -1:]
        l = jnp.where(l < thresh, -1e30, l)
    return jax.random.categorical(key, l).astype(jnp.int32)
