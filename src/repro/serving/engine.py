"""Serving engine: continuous batching over a fixed slot grid, with the
FMMU page manager owning logical->physical KV translation.

Prefill writes each request's KV into pool blocks named by the FMMU
block table; decode steps run the whole slot batch through
Model.decode_step with tables rebuilt by the FMMU on every admission /
relocation (cheap: one batched translate). Pool exhaustion preempts the
longest victim sequence to the host tier (swap_out, CondUpdate-guarded)
— the serving analogue of the paper's GC path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.common import Runtime
from repro.models.model import Model, _src_len
from repro.paging.kv_manager import KVPageManager
from repro.paging.pool import OutOfBlocks


@dataclasses.dataclass
class Request:
    rid: int
    tokens: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    src_emb: Optional[jnp.ndarray] = None
    prefix_emb: Optional[jnp.ndarray] = None


class ServeEngine:
    def __init__(self, model: Model, params, *, n_slots: int,
                 max_ctx: int, n_device_blocks: Optional[int] = None,
                 n_host_blocks: int = 0, eos_id: int = -1):
        self.m = model
        self.cfg = model.cfg
        self.rt = model.rt
        self.params = params
        self.n_slots = n_slots
        self.page = self.rt.page_size
        self.max_pages = -(-max_ctx // self.page)
        n_dev = n_device_blocks or (n_slots * self.max_pages)
        self.kvm = KVPageManager(n_slots, self.max_pages, n_dev,
                                 n_host_blocks)
        src_len = _src_len(self.cfg, max_ctx)
        # +1 scratch block: unmapped table entries (inactive slots) write
        # their garbage KV there instead of corrupting block 0
        self.scratch_block = n_dev + n_host_blocks
        self.caches = transformer.init_decode_caches(
            self.cfg, self.rt, n_slots, self.max_pages,
            n_dev + n_host_blocks + 1, self.rt.compute_dtype,
            src_len=src_len)
        self.ctx_lens = np.zeros(n_slots, np.int64)
        self.src_cap = src_len
        self.src_lens = np.zeros(n_slots, np.int64)
        self.active: Dict[int, Request] = {}
        self.eos_id = eos_id
        self.queue: List[Request] = []
        self._rid = 0
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn)
        self.metrics = {"prefills": 0, "decode_steps": 0, "preemptions": 0,
                        "generated": 0}

    # ------------------------------------------------------------- API
    def submit(self, tokens: List[int], max_new: int = 16, *,
               src_emb=None, prefix_emb=None) -> int:
        rid = self._rid
        self._rid += 1
        self.queue.append(Request(rid, list(tokens), max_new,
                                  src_emb=src_emb, prefix_emb=prefix_emb))
        return rid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        done: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            if not self.step(done):
                break
        return done

    # ------------------------------------------------------------- steps
    def step(self, done: Dict[int, List[int]]) -> bool:
        self._admit()
        if not self.active:
            return bool(self.queue)
        self._decode_step(done)
        return bool(self.active or self.queue)

    def _free_slots(self) -> List[int]:
        used = {r.slot for r in self.active.values()}
        return [s for s in range(self.n_slots) if s not in used]

    def _admit(self):
        free = self._free_slots()
        while self.queue and free:
            req = self.queue[0]
            slot = free[0]
            n_prefix = (req.prefix_emb.shape[0]
                        if req.prefix_emb is not None else 0)
            n_pages = -(-(len(req.tokens) + n_prefix + req.max_new)
                        // self.page)
            n_pages = min(n_pages, self.max_pages)
            try:
                self.kvm.new_seq(slot, n_pages)
            except OutOfBlocks:
                if not self._preempt(exclude=slot):
                    return
                continue
            self.queue.pop(0)
            free.pop(0)
            req.slot = slot
            self.active[req.rid] = req
            self._do_prefill(req)

    def _preempt(self, exclude: int) -> bool:
        """Swap the longest active sequence out to the host tier."""
        victims = [r for r in self.active.values() if r.slot != exclude]
        if not victims or self.kvm.pool.n_host == 0:
            return False
        victim = max(victims, key=lambda r: self.ctx_lens[r.slot])
        pools = [self.caches["pool_k"], self.caches["pool_v"]]
        pools, moved = self.kvm.swap_out(victim.slot, pools, block_axis=2)
        self.caches["pool_k"], self.caches["pool_v"] = pools
        self.metrics["preemptions"] += 1
        return moved > 0

    def _is_resident(self, slot: int) -> bool:
        return not any(b >= (1 << 24)
                       for b in self.kvm.seq_pages.get(slot, []))

    def _ensure_resident(self):
        """Swap in any host-tier pages of active sequences (before decode).
        Sequences that cannot come back yet PAUSE (they are excluded from
        the decode batch) until device blocks free up."""
        for r in sorted(self.active.values(),
                        key=lambda r: len(self.kvm.seq_pages.get(r.slot, []))):
            if not self._is_resident(r.slot):
                try:
                    pools = [self.caches["pool_k"], self.caches["pool_v"]]
                    pools, _ = self.kvm.swap_in(r.slot, pools,
                                                block_axis=2)
                    self.caches["pool_k"], self.caches["pool_v"] = pools
                except OutOfBlocks:
                    pass  # stays swapped & paused; retried next round

    # ------------------------------------------------------------- prefill
    def _prefill_fn(self, params, batch, caches, table_row, slot):
        logits, cols = self.m.prefill(params, batch)
        caches = _scatter_prefill(self.cfg, self.rt, caches, cols,
                                  table_row, slot)
        return logits, caches

    def _do_prefill(self, req: Request):
        toks = jnp.asarray(req.tokens, jnp.int32)[None]
        batch = {"tokens": toks}
        if req.prefix_emb is not None:
            batch["prefix_emb"] = req.prefix_emb[None]
        if req.src_emb is not None:
            batch["src_emb"] = req.src_emb[None]
            batch["src_valid"] = jnp.ones(req.src_emb.shape[:1], jnp.int32)[None]
        tables = np.asarray(self.kvm.block_tables())
        row = jnp.asarray(tables[req.slot], jnp.int32)
        logits, self.caches = self._prefill(self.params, batch, self.caches,
                                            row, req.slot)
        n_ctx = len(req.tokens) + (req.prefix_emb.shape[0]
                                   if req.prefix_emb is not None else 0)
        self.ctx_lens[req.slot] = n_ctx
        if req.src_emb is not None:
            self.src_lens[req.slot] = req.src_emb.shape[0]
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)
        self.metrics["prefills"] += 1
        self.metrics["generated"] += 1

    # ------------------------------------------------------------- decode
    def _decode_fn(self, params, tokens, caches, ctx_lens, tables,
                   src_valid=None):
        logits, caches = self.m.decode_step(
            params, tokens, caches, ctx_lens=ctx_lens, block_table=tables,
            src_valid=src_valid)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    def _decode_step(self, done: Dict[int, List[int]]):
        self._ensure_resident()
        residents = [r for r in self.active.values()
                     if self._is_resident(r.slot)]
        if not residents:
            return
        resident_slots = {r.slot for r in residents}
        tokens = np.zeros(self.n_slots, np.int32)
        for r in residents:
            tokens[r.slot] = r.out[-1] if r.out else r.tokens[-1]
        tables = self.kvm.block_tables()
        # grow pages for sequences crossing a page boundary
        for r in residents:
            need = -(-int(self.ctx_lens[r.slot] + 1) // self.page)
            have = len(self.kvm.seq_pages[r.slot])
            if need > have and have < self.max_pages:
                try:
                    self.kvm.extend_seq(r.slot, need - have)
                except OutOfBlocks:
                    if self._preempt(exclude=r.slot):
                        self.kvm.extend_seq(r.slot, need - have)
                tables = self.kvm.block_tables()
        src_valid = None
        if self.cfg.n_enc_layers:
            src_valid = (np.arange(self.src_cap)[None, :]
                         < self.src_lens[:, None]).astype(np.int32)
            src_valid = jnp.asarray(src_valid)
        # paused / inactive slots: zero ctx + scratch table rows (their
        # garbage KV write lands in the scratch block)
        tables = np.array(tables)
        step_ctx = np.asarray(self.ctx_lens, np.int64).copy()
        for slot in range(self.n_slots):
            if slot not in resident_slots:
                tables[slot, :] = self.scratch_block
                step_ctx[slot] = 0
        tables = np.where((tables < 0) | (tables >= self.scratch_block),
                          self.scratch_block, tables)
        next_tok, self.caches = self._decode(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.asarray(step_ctx, jnp.int32), jnp.asarray(tables),
            src_valid)
        next_tok = np.asarray(next_tok)
        self.metrics["decode_steps"] += 1
        for r in list(residents):
            self.ctx_lens[r.slot] += 1
            tok = int(next_tok[r.slot])
            r.out.append(tok)
            self.metrics["generated"] += 1
            if len(r.out) >= r.max_new or tok == self.eos_id:
                done[r.rid] = r.out[:r.max_new]
                self.kvm.free_seq(r.slot)
                self.ctx_lens[r.slot] = 0
                del self.active[r.rid]


# ----------------------------------------------------------------------
def _scatter_prefill(cfg: ArchConfig, rt: Runtime, caches, cols, table_row,
                     slot):
    """Write one request's prefill caches (B=1) into the slot grid.
    cols: per-period list of dicts with leaves stacked [NP, ...]."""
    period = cfg.period
    attn_js = [j for j in range(period) if cfg.layer_kind(j) == "attn"]
    ssm_js = [j for j in range(period) if cfg.layer_kind(j) == "mamba"]
    a_of = {j: i for i, j in enumerate(attn_js)}
    s_of = {j: i for i, j in enumerate(ssm_js)}
    page = rt.page_size
    caches = dict(caches)
    for j in range(period):
        col = cols[j]
        if "kv" in col:
            k, v = col["kv"]                  # [NP, 1, S, KV, hd]
            np_, _, s, kvh, hd = k.shape
            npages = -(-s // page)
            pad = npages * page - s
            kp = jnp.pad(k[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            kp = kp.reshape(np_, npages, page, kvh, hd)
            vp = vp.reshape(np_, npages, page, kvh, hd)
            rows = table_row[:npages]
            ai = a_of[j]
            # scatter: pool [NP, A, NB, P, KV, hd]
            caches["pool_k"] = caches["pool_k"].at[:, ai, rows].set(
                kp.astype(caches["pool_k"].dtype).transpose(0, 1, 2, 3, 4),
                mode="drop")
            caches["pool_v"] = caches["pool_v"].at[:, ai, rows].set(
                vp.astype(caches["pool_v"].dtype), mode="drop")
        if "ssm" in col:
            conv, ssm_st = col["ssm"]         # [NP,1,k,C], [NP,1,nh,hd,N]
            si = s_of[j]
            caches["conv"] = caches["conv"].at[:, si, slot].set(
                conv[:, 0].astype(caches["conv"].dtype))
            caches["ssm"] = caches["ssm"].at[:, si, slot].set(ssm_st[:, 0])
        if "cross_kv" in col:
            ck, cv = col["cross_kv"]          # [NP,1,Ss,KV,hd]
            cap = caches["cross_k"].shape[3]
            pad = cap - ck.shape[2]
            ckp = jnp.pad(ck[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            cvp = jnp.pad(cv[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            caches["cross_k"] = caches["cross_k"].at[:, j, slot].set(
                ckp.astype(caches["cross_k"].dtype))
            caches["cross_v"] = caches["cross_v"].at[:, j, slot].set(
                cvp.astype(caches["cross_v"].dtype))
    return caches
